"""State-space / recurrent sequence mixers: Mamba (hymba), mLSTM + sLSTM
(xLSTM).

Training paths avoid `lax.scan` over the sequence where feasible
(`associative_scan` lowers to log-depth unrolled HLO, so compiled cost
analysis is exact); the sLSTM keeps its defining recurrent memory mixing and
therefore scans — its cell is registered as a cost *fragment* for the
roofline combiner (see launch/dryrun.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import Params, dense_init, dtype_of

# ---------------------------------------------------------------------------
# Selective SSM (Mamba-style) — hymba's parallel head
# ---------------------------------------------------------------------------


def mamba_params(key, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    dt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    inner = s.expand * d
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * inner, dt),
        "conv_w": (jax.random.normal(ks[1], (s.conv_dim, inner)) * 0.2
                   ).astype(dt),
        "w_dt": dense_init(ks[2], d, inner, dt),
        "b_dt": jnp.full((inner,), -4.6, dt),     # softplus^-1(0.01)
        "w_bc": dense_init(ks[3], d, 2 * s.state_dim, dt),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, s.state_dim + 1,
                                             dtype=jnp.float32), (inner, 1))
                         ).astype(dt),
        "d_skip": jnp.ones((inner,), dt),
        "out_proj": dense_init(ks[4], inner, d, dt),
    }


def _causal_conv(x, w):
    """Depthwise causal conv over seq: x [B,S,C], w [K,C]."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out


def _ssm_scan(a, bx):
    """First-order linear recurrence h_t = a_t * h_{t-1} + bx_t along axis 1
    via associative scan (log-depth, no while loop)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


SSM_CHUNK = 256  # sequence chunk for the selective-scan reference path


def mamba_chunk_body(p, h0, dt_c, xin_c, b_c, c_c):
    """One chunk of the selective scan: carry h0 [B,inner,state]; chunk
    inputs [B,c,inner] / [B,c,state]. The [B,c,inner,state] discretized
    tensors live only inside this body (memory-bounded reference of the
    TPU-fused scan; also a roofline fragment)."""
    a = -jnp.exp(p["a_log"].astype(jnp.float32))              # [inner,state]
    abar = jnp.exp(dt_c[..., None].astype(jnp.float32) * a)   # [B,c,in,st]
    bx = (dt_c * xin_c)[..., None].astype(jnp.float32) \
        * b_c[:, :, None, :].astype(jnp.float32)
    h_in = _ssm_scan(abar, bx)                                # [B,c,in,st]
    a_cum = jnp.cumprod(abar, axis=1)
    h = h_in + a_cum * h0[:, None]
    y = jnp.einsum("bsit,bst->bsi", h, c_c.astype(jnp.float32))
    return h[:, -1], y


def mamba_apply(cfg: ModelConfig, p: Params, x):
    """x [B,S,d] -> [B,S,d]: chunked selective SSM (carry-passing scan over
    SSM_CHUNK-sized pieces keeps the discretized state tensor bounded)."""
    s = cfg.ssm
    cdt = dtype_of(cfg.compute_dtype)
    b, seq, d = x.shape
    x = x.astype(cdt)
    xz = x @ p["in_proj"].astype(cdt)
    xin, res = jnp.split(xz, 2, axis=-1)                     # [B,S,inner]
    xin = jax.nn.silu(_causal_conv(xin, p["conv_w"].astype(cdt)))

    dt_ = jax.nn.softplus((x @ p["w_dt"].astype(cdt))
                          + p["b_dt"].astype(cdt))            # [B,S,inner]
    bc = x @ p["w_bc"].astype(cdt)
    bmat, cmat = jnp.split(bc, 2, axis=-1)                    # [B,S,state]

    inner = xin.shape[-1]
    chunk = min(SSM_CHUNK, seq)
    nc = -(-seq // chunk)
    pad = nc * chunk - seq
    if pad:
        dt_p = jnp.pad(dt_, ((0, 0), (0, pad), (0, 0)))
        xin_p = jnp.pad(xin, ((0, 0), (0, pad), (0, 0)))
        b_p = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        c_p = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    else:
        dt_p, xin_p, b_p, c_p = dt_, xin, bmat, cmat

    h0 = jnp.zeros((b, inner, s.state_dim), jnp.float32)
    if nc == 1:
        _, y = mamba_chunk_body(p, h0, dt_p, xin_p, b_p, c_p)
    else:
        def to_chunks(t):
            return jnp.moveaxis(
                t.reshape(b, nc, chunk, t.shape[-1]), 1, 0)

        # remat: keep only chunk inputs for bwd, not [B,c,inner,state]
        body_ck = jax.checkpoint(lambda h, *xs: mamba_chunk_body(p, h, *xs))

        def body(h, xs):
            return body_ck(h, *xs)

        _, ys = jax.lax.scan(body, h0, tuple(map(to_chunks,
                                                 (dt_p, xin_p, b_p, c_p))))
        y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, inner)
    y = y[:, :seq]
    y = (y.astype(cdt) + xin * p["d_skip"].astype(cdt)) * jax.nn.silu(res)
    return y @ p["out_proj"].astype(cdt)


def mamba_init_state(cfg: ModelConfig, batch: int, layer_axes=()):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    cdt = dtype_of(cfg.compute_dtype)
    return {
        "conv": jnp.zeros(layer_axes + (batch, s.conv_dim - 1, inner), cdt),
        "h": jnp.zeros(layer_axes + (batch, inner, s.state_dim), jnp.float32),
    }


def mamba_decode_step(cfg: ModelConfig, p: Params, x, state):
    """x [B,1,d]; O(1) recurrent update."""
    s = cfg.ssm
    cdt = dtype_of(cfg.compute_dtype)
    b = x.shape[0]
    x = x.astype(cdt)
    xz = x @ p["in_proj"].astype(cdt)
    xin, res = jnp.split(xz, 2, axis=-1)                      # [B,1,inner]
    conv_buf = jnp.concatenate([state["conv"], xin], axis=1)  # [B,K,inner]
    w = p["conv_w"].astype(cdt)
    xin = jax.nn.silu(jnp.einsum("bki,ki->bi", conv_buf, w))[:, None, :]
    new_conv = conv_buf[:, 1:, :]

    dt_ = jax.nn.softplus((x @ p["w_dt"].astype(cdt))
                          + p["b_dt"].astype(cdt))            # [B,1,inner]
    bc = x @ p["w_bc"].astype(cdt)
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    abar = jnp.exp(dt_[..., None].astype(jnp.float32) * a)[:, 0]  # [B,in,st]
    bx = (dt_ * xin)[..., None].astype(jnp.float32) \
        * bmat[:, :, None, :].astype(jnp.float32)
    h = state["h"] * abar + bx[:, 0]                           # [B,in,st]
    y = jnp.einsum("bit,bt->bi", h, cmat[:, 0].astype(jnp.float32))
    y = (y[:, None, :].astype(cdt) + xin * p["d_skip"].astype(cdt)) \
        * jax.nn.silu(res)
    out = y @ p["out_proj"].astype(cdt)
    return out, {"conv": new_conv, "h": h}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM): matrix-memory LSTM, parallel (linear-attention) form
# ---------------------------------------------------------------------------

def mlstm_params(key, cfg: ModelConfig) -> Params:
    dt = dtype_of(cfg.param_dtype)
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim_
    pf = cfg.xlstm.mlstm_proj_factor
    inner = int(pf * d)
    ks = jax.random.split(key, 8)
    return {
        "up": dense_init(ks[0], d, 2 * inner, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.xlstm.conv_dim, inner))
                   * 0.2).astype(dt),
        "wq": dense_init(ks[2], inner, h * hd, dt),
        "wk": dense_init(ks[3], inner, h * hd, dt),
        "wv": dense_init(ks[4], inner, h * hd, dt),
        "w_if": dense_init(ks[5], inner, 2 * h, dt),
        "b_if": jnp.concatenate([jnp.zeros((h,)),
                                 jnp.full((h,), 3.0)]).astype(dt),
        "gn": jnp.ones((h * hd,), dt),            # per-head group norm gain
        "down": dense_init(ks[6], h * hd, d, dt),
        "skip": dense_init(ks[7], inner, h * hd, dt),
    }


def _mlstm_gates(p, xin, cdt):
    b, s, _ = xin.shape
    gif = xin @ p["w_if"].astype(cdt) + p["b_if"].astype(cdt)
    i_raw, f_raw = jnp.split(gif.astype(jnp.float32), 2, axis=-1)  # [B,S,H]
    logf = jax.nn.log_sigmoid(f_raw)
    return i_raw, logf


def _headwise_norm(h, gain, eps=1e-6):
    mu = h.mean(-1, keepdims=True)
    var = jnp.square(h - mu).mean(-1, keepdims=True)
    out = (h - mu) * jax.lax.rsqrt(var + eps)
    return out.reshape(*h.shape[:-2], -1) * gain


MLSTM_CHUNK = 256


def mlstm_chunk_body(carry, q, k, v, i_raw, logf):
    """Chunkwise-parallel stabilized mLSTM (the production linear-attention
    form): intra-chunk quadratic + inter-chunk recurrent state. All fp32.

    carry: (C [B,H,hd,hd], n [B,H,hd], m [B,H]);
    q/k/v [B,c,H,hd]; i_raw/logf [B,c,H]. Returns (new_carry, h [B,c,H,hd]).
    """
    C_prev, n_prev, m_prev = carry
    bsz, c, nh, hd = q.shape
    f32 = jnp.float32
    q, k, v = (t.astype(f32) for t in (q, k, v))
    i_raw, logf = i_raw.astype(f32), logf.astype(f32)

    F = jnp.cumsum(logf, axis=1)                         # [B,c,H] inclusive
    ftot = F[:, -1]                                      # [B,H]
    # intra-chunk log-decay D[j,s] = F_j - F_s + i_s  (valid for s<=j)
    D = F[:, :, None, :] - F[:, None, :, :] + i_raw[:, None, :, :]
    causal = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(causal[None, :, :, None], D, -jnp.inf)
    m_intra = jnp.max(D, axis=2)                         # [B,c,H]
    m_inter = F + m_prev[:, None, :]                     # [B,c,H]
    m_j = jnp.maximum(m_intra, m_inter)
    m_j = jnp.maximum(m_j, -1e30)                        # empty-past guard

    w_intra = jnp.exp(D - m_j[:, :, None, :])            # [B,c,c,H]
    scores = jnp.einsum("bjhd,bshd->bjsh", q, k) * w_intra
    num = jnp.einsum("bjsh,bshd->bjhd", scores, v)
    den = scores.sum(axis=2)                             # [B,c,H]

    w_inter = jnp.exp(m_inter - m_j)                     # [B,c,H]
    num = num + w_inter[..., None] * jnp.einsum("bjhd,bhde->bjhe", q, C_prev)
    den = den + w_inter * jnp.einsum("bjhd,bhd->bjh", q, n_prev)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_j))[..., None]

    # state update to end of chunk
    m_new = jnp.maximum(m_prev + ftot,
                        jnp.max(i_raw + ftot[:, None, :] - F, axis=1))
    w_c = jnp.exp(m_prev + ftot - m_new)                 # [B,H]
    w_s = jnp.exp(i_raw + ftot[:, None, :] - F
                  - m_new[:, None, :])                   # [B,c,H]
    C_new = w_c[..., None, None] * C_prev \
        + jnp.einsum("bsh,bshd,bshe->bhde", w_s, v, k)
    n_new = w_c[..., None] * n_prev \
        + jnp.einsum("bsh,bshd->bhd", w_s, k)
    return (C_new, n_new, m_new), h


def mlstm_apply(cfg: ModelConfig, p: Params, x):
    """Chunkwise mLSTM — numerically identical recurrence to the decode
    step (validated in tests/test_models_smoke.py)."""
    cdt = dtype_of(cfg.compute_dtype)
    b, s, d = x.shape
    nh, hd = cfg.num_heads, cfg.head_dim_
    x = x.astype(cdt)
    up, res = jnp.split(x @ p["up"].astype(cdt), 2, axis=-1)
    xin = jax.nn.silu(_causal_conv(up, p["conv_w"].astype(cdt)))

    q = (xin @ p["wq"].astype(cdt)).reshape(b, s, nh, hd)
    k = (xin @ p["wk"].astype(cdt)).reshape(b, s, nh, hd) / np.sqrt(hd)
    v = (up @ p["wv"].astype(cdt)).reshape(b, s, nh, hd)
    i_raw, logf = _mlstm_gates(p, xin, cdt)                    # [B,S,H]

    chunk = min(MLSTM_CHUNK, s)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (q, k, v))
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
    carry = (jnp.zeros((b, nh, hd, hd), jnp.float32),
             jnp.zeros((b, nh, hd), jnp.float32),
             jnp.full((b, nh), -1e30, jnp.float32))
    if nc == 1:
        _, h = mlstm_chunk_body(carry, q, k, v, i_raw, logf)
    else:
        def to_chunks(t):
            return jnp.moveaxis(
                t.reshape((b, nc, chunk) + t.shape[2:]), 1, 0)

        body_ck = jax.checkpoint(
            lambda cry, *xs: mlstm_chunk_body(cry, *xs))
        _, hs = jax.lax.scan(lambda cry, xs: body_ck(cry, *xs), carry,
                             tuple(map(to_chunks, (q, k, v, i_raw, logf))))
        h = jnp.moveaxis(hs, 0, 1).reshape(b, nc * chunk, nh, hd)
    h = h[:, :s]
    out = _headwise_norm(h.astype(cdt), p["gn"].astype(cdt))
    out = out + jax.nn.silu(xin @ p["skip"].astype(cdt))
    out = out * jax.nn.silu(res @ p["wv"].astype(cdt))  # output gate from res
    return out @ p["down"].astype(cdt)


def mlstm_init_state(cfg: ModelConfig, batch: int):
    nh, hd = cfg.num_heads, cfg.head_dim_
    inner = int(cfg.xlstm.mlstm_proj_factor * cfg.d_model)
    return {
        "conv": jnp.zeros((batch, cfg.xlstm.conv_dim - 1, inner),
                          dtype_of(cfg.compute_dtype)),
        "c": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), -jnp.inf, jnp.float32),
    }


def mlstm_decode_step(cfg: ModelConfig, p: Params, x, state):
    cdt = dtype_of(cfg.compute_dtype)
    b = x.shape[0]
    nh, hd = cfg.num_heads, cfg.head_dim_
    x = x.astype(cdt)
    up, res = jnp.split(x @ p["up"].astype(cdt), 2, axis=-1)   # [B,1,inner]
    conv_buf = jnp.concatenate([state["conv"], up], axis=1)
    w = p["conv_w"].astype(cdt)
    xin = jax.nn.silu(jnp.einsum("bki,ki->bi", conv_buf, w))[:, None, :]

    q = (xin @ p["wq"].astype(cdt)).reshape(b, nh, hd)
    k = (xin @ p["wk"].astype(cdt)).reshape(b, nh, hd) / np.sqrt(hd)
    v = (up @ p["wv"].astype(cdt)).reshape(b, nh, hd)
    i_raw, logf = _mlstm_gates(p, xin, cdt)
    i_raw, logf = i_raw[:, 0], logf[:, 0]                      # [B,H]

    m_new = jnp.maximum(logf + state["m"], i_raw)
    fprime = jnp.exp(logf + state["m"] - m_new)[..., None]
    iprime = jnp.exp(i_raw - m_new)[..., None]
    c = state["c"] * fprime[..., None] \
        + iprime[..., None] * jnp.einsum("bhd,bhe->bhde",
                                         v.astype(jnp.float32),
                                         k.astype(jnp.float32))
    n = state["n"] * fprime + iprime * k.astype(jnp.float32)
    num = jnp.einsum("bhde,bhe->bhd", c, q.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", n,
                                         q.astype(jnp.float32))),
                      jnp.exp(-m_new))[..., None]
    h = (num / den)[:, None]                                   # [B,1,H,hd]
    out = _headwise_norm(h.astype(cdt), p["gn"].astype(cdt))
    out = out + jax.nn.silu(xin @ p["skip"].astype(cdt))
    out = out @ p["down"].astype(cdt)
    return out, {"conv": conv_buf[:, 1:], "c": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM): scalar LSTM with exponential gating + recurrent mixing
# ---------------------------------------------------------------------------

def slstm_params(key, cfg: ModelConfig) -> Params:
    dt = dtype_of(cfg.param_dtype)
    d, nh, hd = cfg.d_model, cfg.num_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    pf = cfg.xlstm.slstm_proj_factor
    ff = int(pf * d)
    r = (jax.random.normal(ks[1], (4, nh, hd, hd)) / np.sqrt(hd)).astype(dt)
    return {
        "wx": dense_init(ks[0], d, 4 * nh * hd, dt),   # z, i, f, o from x
        "r": r,                                         # recurrent per head
        "b": jnp.zeros((4, nh, hd), dt),
        "gn": jnp.ones((nh * hd,), dt),
        "up": dense_init(ks[2], nh * hd, 2 * ff, dt),
        "down": dense_init(ks[3], ff, d, dt),
    }


def slstm_cell(p, carry, xg):
    """One sLSTM step. carry: (h, c, n, m) each [B,H,hd] (m is [B,H,hd]);
    xg: precomputed W x_t [B,4,H,hd]."""
    h, c, n, m = carry
    r = p["r"].astype(jnp.float32)
    rec = jnp.einsum("bhd,ghde->bghe", h, r)               # [B,4,H,hd]
    g = xg.astype(jnp.float32) + rec + p["b"].astype(jnp.float32)
    z = jnp.tanh(g[:, 0])
    o = jax.nn.sigmoid(g[:, 3])
    logi = g[:, 1]
    logf = jax.nn.log_sigmoid(g[:, 2])
    m_new = jnp.maximum(logf + m, logi)
    i_ = jnp.exp(logi - m_new)
    f_ = jnp.exp(logf + m - m_new)
    c_new = f_ * c + i_ * z
    n_new = f_ * n + i_
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return (h_new, c_new, n_new, m_new)


def slstm_apply(cfg: ModelConfig, p: Params, x):
    """Sequential scan over S (recurrent memory mixing is the point of the
    sLSTM). Registered as a roofline fragment with trip count S."""
    cdt = dtype_of(cfg.compute_dtype)
    b, s, d = x.shape
    nh, hd = cfg.num_heads, cfg.head_dim_
    xg = (x.astype(cdt) @ p["wx"].astype(cdt)).reshape(b, s, 4, nh, hd)
    init = tuple(jnp.zeros((b, nh, hd), jnp.float32) for _ in range(3)) \
        + (jnp.full((b, nh, hd), -1e30, jnp.float32),)

    def step(carry, xg_t):
        new = slstm_cell(p, carry, xg_t)
        return new, new[0]

    _, hs = jax.lax.scan(step, init, jnp.moveaxis(xg, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1)                             # [B,S,H,hd]
    out = _headwise_norm(hs.astype(cdt), p["gn"].astype(cdt))
    u, g = jnp.split(out @ p["up"].astype(cdt), 2, axis=-1)
    return (u * jax.nn.gelu(g, approximate=True)) @ p["down"].astype(cdt)


def slstm_init_state(cfg: ModelConfig, batch: int):
    nh, hd = cfg.num_heads, cfg.head_dim_
    z = lambda: jnp.zeros((batch, nh, hd), jnp.float32)
    return {"h": z(), "c": z(), "n": z(),
            "m": jnp.full((batch, nh, hd), -1e30, jnp.float32)}


def slstm_decode_step(cfg: ModelConfig, p: Params, x, state):
    cdt = dtype_of(cfg.compute_dtype)
    b = x.shape[0]
    nh, hd = cfg.num_heads, cfg.head_dim_
    xg = (x.astype(cdt) @ p["wx"].astype(cdt)).reshape(b, 4, nh, hd)
    carry = (state["h"], state["c"], state["n"], state["m"])
    h, c, n, m = slstm_cell(p, carry, xg)
    out = _headwise_norm(h[:, None].astype(cdt), p["gn"].astype(cdt))
    u, g = jnp.split(out @ p["up"].astype(cdt), 2, axis=-1)
    out = (u * jax.nn.gelu(g, approximate=True)) @ p["down"].astype(cdt)
    return out, {"h": h, "c": c, "n": n, "m": m}
