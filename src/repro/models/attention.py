"""Attention: GQA/MQA, sliding-window, MLA (DeepSeek), KV caches.

Reference implementations are pure jnp (the dry-run lowers these — identical
math to the Pallas kernels, which target TPU and are validated separately in
interpret mode; see DESIGN.md §6). ``impl="flash"`` routes full-sequence
attention through the Pallas flash kernel on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.layers import Params, dense_init, dtype_of

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def attn_params(key, cfg: ModelConfig) -> Params:
    dt = dtype_of(cfg.param_dtype)
    d, hd = cfg.d_model, cfg.head_dim_
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    if cfg.mla:
        m = cfg.mla
        ks = jax.random.split(key, 8)
        return {
            "wq_a": dense_init(ks[0], d, m.q_lora_rank, dt),
            "q_norm": jnp.zeros((m.q_lora_rank,), dt),
            "wq_b": dense_init(ks[1], m.q_lora_rank,
                               nq * (m.qk_nope_head_dim + m.qk_rope_head_dim),
                               dt),
            "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim,
                                dt),
            "kv_norm": jnp.zeros((m.kv_lora_rank,), dt),
            "wkv_b": dense_init(ks[3], m.kv_lora_rank,
                                nq * (m.qk_nope_head_dim + m.v_head_dim), dt),
            "wo": dense_init(ks[4], nq * m.v_head_dim, d, dt),
        }
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, nq * hd, dt),
        "wk": dense_init(ks[1], d, nkv * hd, dt),
        "wv": dense_init(ks[2], d, nkv * hd, dt),
        "wo": dense_init(ks[3], nq * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    return p


def init_cache(cfg: ModelConfig, batch: int, capacity: int, layer_axes=()):
    """KV cache pytree (per layer; callers stack over layers).

    ``pos`` records the absolute position held by each slot (-1 = empty),
    which uniformly supports linear caches and ring buffers for
    sliding-window layers (capacity = window).
    """
    cdt = dtype_of(cfg.compute_dtype)
    shape = lambda *s: layer_axes + s
    if cfg.mla:
        m = cfg.mla
        return {
            "ckv": jnp.zeros(shape(batch, capacity, m.kv_lora_rank), cdt),
            "kpe": jnp.zeros(shape(batch, capacity, m.qk_rope_head_dim), cdt),
            "pos": jnp.full(shape(batch, capacity), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros(shape(batch, capacity, cfg.num_kv_heads,
                             cfg.head_dim_), cdt),
        "v": jnp.zeros(shape(batch, capacity, cfg.num_kv_heads,
                             cfg.head_dim_), cdt),
        "pos": jnp.full(shape(batch, capacity), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------

def _window_bias(q_pos, k_pos, window, causal: bool):
    """[..., S_q, S_k] additive bias from absolute positions.

    window is a traced or static int32 scalar; 0 means full attention."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    ok = dk >= 0
    if causal:
        ok &= dk <= dq
    win_ok = (window <= 0) | (dq - dk < window)
    ok &= win_ok
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Core GQA attention (reference)
# ---------------------------------------------------------------------------

def gqa_attention(q, k, v, bias, compute_dtype):
    """q [B,Sq,nq,h], k/v [B,Sk,nkv,h], bias [B,Sq,Sk] -> [B,Sq,nq,h].

    Decode-path workhorse: KV heads are *not* materialized per query head;
    the einsum groups query heads over their shared KV head (KV-cache bytes
    dominate decode and must not be repeated)."""
    b, sq, nq, h = q.shape
    nkv = k.shape[2]
    g = nq // nkv
    q = q.reshape(b, sq, nkv, g, h)
    scores = jnp.einsum("bsngh,btnh->bngst", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    scores = scores / np.sqrt(h) + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
    out = jnp.einsum("bngst,btnh->bsngh", probs, v)
    return out.reshape(b, sq, nq, h)


def attn_q_chunks(seq: int, chunk: int = 512) -> int:
    """Number of query chunks the full-sequence reference path uses."""
    if seq <= chunk:
        return 1
    return -(-seq // chunk)


CHUNK_SCAN_THRESHOLD = 4  # python-unrolled up to here; lax.scan beyond


def chunked_mha(q, k, v, qpos, kpos, window, causal, compute_dtype,
                chunk: int = 512, scores_dtype=jnp.float32):
    """Full-sequence attention, chunked over queries (memory-bounded
    reference of the flash kernel: the live score buffer is
    [B, nq, chunk, S_kv] instead of [B, nq, S, S]).

    q/k/v are HEAD-ALIGNED ([B,S,n,h] with identical head counts — callers
    repeat KV for GQA so tensor-parallel head sharding propagates without
    resharding). Chunks beyond CHUNK_SCAN_THRESHOLD run under lax.scan; the
    body is exposed as a roofline fragment (lm.fragments)."""
    b, s, nq, h = q.shape
    nc = attn_q_chunks(s, chunk)
    if nc == 1:
        bias = _window_bias(qpos, kpos, window, causal)
        return _mha_one_chunk(q, k, v, bias, compute_dtype, scores_dtype)
    pad = nc * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad)), constant_values=-1)

    # each chunk is rematerialized: backward recomputes the [c, S] score
    # tile instead of keeping every chunk's scores live (flash-style remat;
    # without this the scan stacks [nc, B, n, c, S] fp32 residuals).
    one_chunk = jax.checkpoint(
        functools.partial(_chunk_with_bias, window=window, causal=causal,
                          compute_dtype=compute_dtype,
                          scores_dtype=scores_dtype))
    hv = v.shape[-1]   # value head dim can differ from qk dim (MLA)
    if nc <= CHUNK_SCAN_THRESHOLD:
        outs = []
        for i in range(nc):
            sl = slice(i * chunk, (i + 1) * chunk)
            outs.append(one_chunk(q[:, sl], qpos[:, sl], k, v, kpos))
        out = jnp.concatenate(outs, axis=1)
    else:
        qc = jnp.moveaxis(q.reshape(b, nc, chunk, nq, h), 1, 0)
        pc = jnp.moveaxis(qpos.reshape(b, nc, chunk), 1, 0)

        def body(_, xs):
            qi, pi = xs
            return (), one_chunk(qi, pi, k, v, kpos)

        _, out = jax.lax.scan(body, (), (qc, pc))
        out = jnp.moveaxis(out, 0, 1).reshape(b, nc * chunk, nq, hv)
    return out[:, :s]


def _chunk_with_bias(q, qpos, k, v, kpos, *, window, causal,
                     compute_dtype, scores_dtype=jnp.float32):
    bias = _window_bias(qpos, kpos, window, causal)
    return _mha_one_chunk(q, k, v, bias, compute_dtype, scores_dtype)


def _mha_one_chunk(q, k, v, bias, compute_dtype,
                   scores_dtype=jnp.float32):
    """q [B,c,n,h], k/v [B,T,n,h], bias [B,c,T] -> [B,c,n,h].

    scores_dtype=bfloat16 halves the S^2 score-tensor traffic (the dot still
    accumulates in fp32 on the MXU; softmax max-subtraction keeps bf16
    stable for O(10) logits)."""
    h = q.shape[-1]
    scores = jax.lax.dot_general(
        q, k, (((3,), (3,)), ((0, 2), (0, 2))),
        preferred_element_type=jnp.float32)            # [B,n,c,T] fp32 acc
    scores = (scores / np.sqrt(h)).astype(scores_dtype)
    scores = scores + bias[:, None, :, :].astype(scores_dtype)
    probs = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
    return jnp.einsum("bnst,btnh->bsnh", probs, v)


def full_attention(cfg: ModelConfig, p: Params, x, positions, window,
                   impl: str = "reference", causal: bool = True,
                   kv_positions=None, xkv=None):
    """Full-sequence attention (train / prefill / encoder / cross).

    xkv: source of K/V (cross-attention); defaults to x (self-attention).
    Returns (out [B,S,d], kv) where kv = (k, v) for cache priming.
    """
    cdt = dtype_of(cfg.compute_dtype)
    b, s, _ = x.shape
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    xkv = x if xkv is None else xkv
    kv_positions = positions if kv_positions is None else kv_positions

    from jax.ad_checkpoint import checkpoint_name
    x = x.astype(cdt)
    xkv = xkv.astype(cdt)
    q = checkpoint_name(x @ p["wq"].astype(cdt), "qkv")
    k = checkpoint_name(xkv @ p["wk"].astype(cdt), "qkv")
    v = checkpoint_name(xkv @ p["wv"].astype(cdt), "qkv")
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    q = q.reshape(b, s, nq, hd)
    k = k.reshape(b, xkv.shape[1], nkv, hd)
    v = v.reshape(b, xkv.shape[1], nkv, hd)

    if cfg.use_rope and (causal or xkv is x):  # rope only for self-attention
        if cfg.mrope:
            q = layers.apply_mrope(q, positions, cfg.rope_theta,
                                   cfg.mrope_sections)
            k = layers.apply_mrope(k, kv_positions, cfg.rope_theta,
                                   cfg.mrope_sections)
        else:
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, kv_positions, cfg.rope_theta)

    pos1 = positions[1] if cfg.mrope else positions  # temporal stream masks
    kpos1 = kv_positions[1] if cfg.mrope else kv_positions
    if impl == "flash" and causal:
        from repro.kernels.flash_attention import ops as flash_ops
        out = flash_ops.flash_attention(q, k, v, window=int(window))
    else:
        # repeat KV to full query heads: keeps TP head sharding aligned
        # through the einsums (no GSPMD resharding of S x S scores)
        g = nq // nkv
        kf = jnp.repeat(k, g, axis=2) if g > 1 else k
        vf = jnp.repeat(v, g, axis=2) if g > 1 else v
        out = chunked_mha(q, kf, vf, pos1, kpos1, window, causal, cdt,
                          scores_dtype=dtype_of(cfg.attn_scores_dtype))
    out = out.reshape(b, s, nq * hd) @ p["wo"].astype(cdt)
    return out, (k, v)


def decode_attention(cfg: ModelConfig, p: Params, x, cache, position,
                     window):
    """One-token decode with cache append. x [B,1,d]; position scalar int32.

    Ring-buffer write at ``position % capacity``; masking is driven by the
    per-slot absolute positions, so linear and ring caches share one path.
    """
    cdt = dtype_of(cfg.compute_dtype)
    b = x.shape[0]
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    cap = cache["pos"].shape[-1]
    slot = position % cap

    x = x.astype(cdt)
    q = (x @ p["wq"].astype(cdt))
    k = (x @ p["wk"].astype(cdt))
    v = (x @ p["wv"].astype(cdt))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"].astype(cdt), k + p["bk"].astype(cdt), \
            v + p["bv"].astype(cdt)
    q = q.reshape(b, 1, nq, hd)
    k = k.reshape(b, 1, nkv, hd)
    v = v.reshape(b, 1, nkv, hd)
    pos_b = jnp.full((b, 1), position, jnp.int32)
    if cfg.mrope:
        p3 = jnp.broadcast_to(position, (3, b, 1)).astype(jnp.int32)
        q = layers.apply_mrope(q, p3, cfg.rope_theta, cfg.mrope_sections)
        k = layers.apply_mrope(k, p3, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.use_rope:
        q = layers.apply_rope(q, pos_b, cfg.rope_theta)
        k = layers.apply_rope(k, pos_b, cfg.rope_theta)

    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], pos_b, slot, axis=1)
    bias = _window_bias(pos_b, cpos, window, causal=True)
    out = gqa_attention(q, ck, cv, bias, cdt)
    out = out.reshape(b, 1, nq * hd) @ p["wo"].astype(cdt)
    return out, {"k": ck, "v": cv, "pos": cpos}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): compressed-latent attention
# ---------------------------------------------------------------------------

def _mla_qkv(cfg, p, x, positions):
    m = cfg.mla
    cdt = dtype_of(cfg.compute_dtype)
    b, s, _ = x.shape
    nq = cfg.num_heads
    cq = layers.rmsnorm(x.astype(cdt) @ p["wq_a"].astype(cdt), p["q_norm"],
                        cfg.rms_eps)
    q = (cq @ p["wq_b"].astype(cdt)).reshape(
        b, s, nq, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_pe = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_pe = layers.apply_rope(q_pe, positions, cfg.rope_theta)

    kv = x.astype(cdt) @ p["wkv_a"].astype(cdt)
    ckv, k_pe = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    ckv = layers.rmsnorm(ckv, p["kv_norm"], cfg.rms_eps)
    k_pe = layers.apply_rope(k_pe[:, :, None, :], positions,
                             cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_pe, ckv, k_pe


def mla_attention(cfg: ModelConfig, p: Params, x, positions, window):
    """Training/prefill MLA: materialize per-head K/V from the latent, then
    run the q-chunked reference path (scale matches the concatenated
    [nope ; rope] head dim)."""
    m = cfg.mla
    cdt = dtype_of(cfg.compute_dtype)
    b, s, _ = x.shape
    nq = cfg.num_heads
    q_nope, q_pe, ckv, k_pe = _mla_qkv(cfg, p, x, positions)
    kvb = (ckv @ p["wkv_b"].astype(cdt)).reshape(
        b, s, nq, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvb, [m.qk_nope_head_dim], axis=-1)

    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :],
                                  (b, s, nq, m.qk_rope_head_dim))], axis=-1)
    out = chunked_mha(q_full, k_full, v, positions, positions, window,
                      True, cdt,
                      scores_dtype=dtype_of(cfg.attn_scores_dtype))
    out = out.reshape(b, s, nq * m.v_head_dim) @ p["wo"].astype(cdt)
    return out, (ckv, k_pe)


def mla_decode_attention(cfg: ModelConfig, p: Params, x, cache, position,
                         window):
    """Absorbed-matrix MLA decode: attention runs in the latent space, so the
    per-step cost is O(S * kv_lora_rank) and the cache holds only the latent
    (the technique that makes MLA decode cheap; arXiv:2412.19437 §2.1)."""
    m = cfg.mla
    cdt = dtype_of(cfg.compute_dtype)
    b = x.shape[0]
    nq = cfg.num_heads
    cap = cache["pos"].shape[-1]
    slot = position % cap
    pos_b = jnp.full((b, 1), position, jnp.int32)

    q_nope, q_pe, ckv_new, kpe_new = _mla_qkv(cfg, p, x, pos_b)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new, slot,
                                              axis=1)
    kpe = jax.lax.dynamic_update_slice_in_dim(cache["kpe"], kpe_new, slot,
                                              axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], pos_b, slot,
                                               axis=1)

    wkv_b = p["wkv_b"].astype(cdt).reshape(
        m.kv_lora_rank, nq, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[:, :, :m.qk_nope_head_dim]       # [r, n, hk]
    w_uv = wkv_b[:, :, m.qk_nope_head_dim:]       # [r, n, hv]

    # absorb K up-projection into the query
    q_lat = jnp.einsum("bsnh,rnh->bsnr", q_nope, w_uk)       # [b,1,n,r]
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bsnr,btr->bnst", q_lat.astype(jnp.float32),
                         ckv.astype(jnp.float32))
              + jnp.einsum("bsnh,bth->bnst", q_pe.astype(jnp.float32),
                           kpe.astype(jnp.float32))) * scale
    bias = _window_bias(pos_b, cpos, window, causal=True)
    probs = jax.nn.softmax(scores + bias[:, None], axis=-1).astype(cdt)
    out_lat = jnp.einsum("bnst,btr->bsnr", probs, ckv)        # [b,1,n,r]
    out = jnp.einsum("bsnr,rnh->bsnh", out_lat, w_uv)
    out = out.reshape(b, 1, nq * m.v_head_dim) @ p["wo"].astype(cdt)
    return out, {"ckv": ckv, "kpe": kpe, "pos": cpos}
