"""Shared layer primitives (raw JAX pytrees, no framework)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict  # nested dict pytree of jnp arrays


def dtype_of(name: str):
    return jnp.dtype(name)


# -- initializers -------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# -- norms ---------------------------------------------------------------------

def rmsnorm(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(dt)


def layernorm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    return out.astype(dt)


def norm_params(key, cfg, d: int) -> Params:
    if cfg.norm == "rmsnorm":
        return {"gamma": jnp.zeros((d,), dtype_of(cfg.param_dtype))}
    return {"gamma": jnp.ones((d,), dtype_of(cfg.param_dtype)),
            "beta": jnp.zeros((d,), dtype_of(cfg.param_dtype))}


def apply_norm(cfg, p: Params, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["gamma"], cfg.rms_eps)
    return layernorm(x, p["gamma"], p["beta"], cfg.rms_eps)


# -- activations ---------------------------------------------------------------

def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name in ("gelu", "geglu"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


# -- gated MLP -------------------------------------------------------------------

def mlp_params(key, cfg, d: int, ff: int) -> Params:
    ks = jax.random.split(key, 3)
    dt = dtype_of(cfg.param_dtype)
    p = {"w_up": dense_init(ks[0], d, ff, dt),
         "w_down": dense_init(ks[1], ff, d, dt)}
    if cfg.act in ("silu", "geglu"):
        p["w_gate"] = dense_init(ks[2], d, ff, dt)
    return p


def mlp_apply(cfg, p: Params, x):
    from jax.ad_checkpoint import checkpoint_name
    cdt = dtype_of(cfg.compute_dtype)
    x = x.astype(cdt)
    up = checkpoint_name(x @ p["w_up"].astype(cdt), "mlp_pre_up")
    if "w_gate" in p:
        gate = checkpoint_name(x @ p["w_gate"].astype(cdt), "mlp_pre_gate")
        up = act_fn(cfg.act)(gate) * up
    else:
        up = act_fn(cfg.act)(up)
    return up @ p["w_down"].astype(cdt)


# -- rotary embeddings ------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), dtype=jnp.float32)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: tuple[int, ...]):
    """Qwen2-VL M-RoPE: positions3 [3, B, S] (t, h, w); the head-dim halves
    are split into `sections` (summing to D/2), each rotated with its own
    position stream (arXiv:2409.12191)."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = jnp.asarray(rope_freqs(d, theta), dtype=jnp.float32)  # [D/2]
    # choose a position stream per frequency index
    sec_id = np.repeat(np.arange(len(sections)), sections)       # [D/2]
    pos = positions3[sec_id]                                      # [D/2, B, S]
    ang = jnp.einsum("dbs,d->bsd", pos.astype(jnp.float32), freqs)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- losses -----------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None):
    """Mean next-token cross-entropy; logits [..., V] fp32-stable."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def softmax_xent_fused(h, w_head, labels, chunk: int = 0):
    """CE from hidden states without materializing [B,S,V] logits: the head
    matmul + logsumexp run per sequence chunk, so the live buffer is
    [B,chunk,V] (sized to ~256 MiB per device assuming 16-way batch
    sharding — matters for replicated odd-sized vocabs like whisper's).

    h [B,S,d] (already aligned with labels [B,S]); returns mean NLL."""
    b, s, d = h.shape
    v = w_head.shape[-1]
    if chunk <= 0:
        budget = 2 ** 28  # fp32 logits bytes per device
        b_local = max(b // 16, 1)
        v_local = v // 16 if v % 16 == 0 else v  # vocab-sharded head
        chunk = max(8, min(s, budget // max(b_local * v_local * 4, 1)))
    nll_sum = jnp.zeros((), jnp.float32)
    n = 0
    for i in range(0, s, chunk):
        hc = h[:, i:i + chunk]
        lc = labels[:, i:i + chunk]
        logits = (hc @ w_head.astype(hc.dtype)).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll_sum = nll_sum + (logz - gold).sum()
        n += hc.shape[1] * b
    return nll_sum / n
