"""Whisper-style encoder-decoder (audio family).

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings [B, F, d] (what the two conv layers would emit).
Encoder: bidirectional attention with sinusoidal positions. Decoder: causal
self-attention + cross-attention with learned positions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention, blocks, layers
from repro.models.config import ModelConfig
from repro.models.layers import Params, dtype_of


def sinusoids(length: int, channels: int):
    log_timescale = np.log(10000) / (channels // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(channels // 2))
    t = np.arange(length)[:, None] * inv[None, :]
    return jnp.asarray(np.concatenate([np.sin(t), np.cos(t)], axis=1),
                       jnp.float32)


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        cfg.validate()
        self.cfg = cfg

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 6 + cfg.enc_layers + cfg.num_layers)
        dt = dtype_of(cfg.param_dtype)
        enc = [blocks.layer_params(ks[6 + i], cfg, "enc")
               for i in range(cfg.enc_layers)]
        dec = [blocks.layer_params(ks[6 + cfg.enc_layers + i], cfg, "dec")
               for i in range(cfg.num_layers)]
        return {
            "embed": layers.embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                                       dt),
            "pos_dec": (jax.random.normal(ks[1], (cfg.max_seq, cfg.d_model))
                        * 0.01).astype(dt),
            "enc": enc,
            "dec": dec,
            "enc_norm": layers.norm_params(ks[2], cfg, cfg.d_model),
            "final_norm": layers.norm_params(ks[3], cfg, cfg.d_model),
        }

    def param_specs(self) -> Params:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # -- encoder ------------------------------------------------------------

    def encode(self, params: Params, frames):
        cfg = self.cfg
        cdt = dtype_of(cfg.compute_dtype)
        b, f, _ = frames.shape
        x = frames.astype(cdt) + sinusoids(f, cfg.d_model).astype(cdt)
        pos = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))
        for p_l in params["enc"]:
            x, _, _ = blocks.layer_fwd(cfg, "enc", p_l, x, pos, jnp.int32(0))
        return layers.apply_norm(cfg, params["enc_norm"], x)

    # -- decoder ------------------------------------------------------------

    def loss(self, params: Params, batch: dict):
        cfg = self.cfg
        cdt = dtype_of(cfg.compute_dtype)
        tokens = batch["tokens"]
        b, s = tokens.shape
        enc_out = self.encode(params, batch["frames"])
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1], dtype=jnp.int32), (b,
                                                            enc_out.shape[1]))
        x = params["embed"][tokens].astype(cdt) \
            + params["pos_dec"][:s].astype(cdt)
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        for p_l in params["dec"]:
            x, _ = blocks.dec_layer_fwd(cfg, p_l, x, pos, enc_out, enc_pos)
        x = layers.apply_norm(cfg, params["final_norm"], x)
        ce = layers.softmax_xent_fused(x[:, :-1, :], params["embed"].T,
                                       tokens[:, 1:])
        return ce, {"ce": ce}

    def prefill(self, params: Params, batch: dict):
        cfg = self.cfg
        cdt = dtype_of(cfg.compute_dtype)
        tokens = batch["tokens"]
        b, s = tokens.shape
        enc_out = self.encode(params, batch["frames"])
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1], dtype=jnp.int32),
            (b, enc_out.shape[1]))
        x = params["embed"][tokens].astype(cdt) \
            + params["pos_dec"][:s].astype(cdt)
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        for p_l in params["dec"]:
            x, _ = blocks.dec_layer_fwd(cfg, p_l, x, pos, enc_out, enc_pos)
        x = layers.apply_norm(cfg, params["final_norm"], x)
        return x[:, -1:, :] @ params["embed"].T.astype(cdt)

    def init_cache(self, params_or_specs: Params, batch: int, max_len: int,
                   enc_frames: int):
        """Self-attention cache + cross K/V per decoder layer."""
        cfg = self.cfg
        cdt = dtype_of(cfg.compute_dtype)
        nkv, hd = cfg.num_kv_heads, cfg.head_dim_
        out = []
        for _ in range(cfg.num_layers):
            c = attention.init_cache(cfg, batch, max_len)
            c["ck"] = jnp.zeros((batch, enc_frames, nkv, hd), cdt)
            c["cv"] = jnp.zeros((batch, enc_frames, nkv, hd), cdt)
            out.append(c)
        return out

    def decode_step(self, params: Params, cache, tokens, position):
        cfg = self.cfg
        cdt = dtype_of(cfg.compute_dtype)
        b = tokens.shape[0]
        x = params["embed"][tokens].astype(cdt) \
            + jax.lax.dynamic_slice_in_dim(params["pos_dec"], position,
                                           1, axis=0).astype(cdt)
        new_cache = []
        for p_l, c_l in zip(params["dec"], cache):
            x, nc = blocks.dec_layer_decode(cfg, p_l, x, c_l, position)
            new_cache.append(nc)
        x = layers.apply_norm(cfg, params["final_norm"], x)
        return x @ params["embed"].T.astype(cdt), new_cache

    def fragments(self, mode: str, batch: int, seq: int):
        return []  # 4+4 layers are unrolled: full HLO cost is exact
