"""Model configuration schema covering the 10 assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # expert FFN hidden size
    num_shared_experts: int = 0   # DeepSeek-style always-on experts
    first_k_dense: int = 0        # leading layers with dense FFN
    d_ff_dense: int = 0           # hidden size of those dense FFNs
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.001
    router_scale: bool = True     # normalize top-k gate weights to sum 1


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention dims (arXiv:2412.19437)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM (used by hymba's parallel heads)."""
    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2               # inner = expand * d_model (attn+ssm share)
    dt_min: float = 1e-3
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_at: tuple[int, ...] = ()     # layer indices using sLSTM blocks
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv_dim: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    qkv_bias: bool = False
    tie_embeddings: bool = False
    act: Literal["silu", "gelu", "geglu"] = "silu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    rope_theta: float = 1e4
    rms_eps: float = 1e-6
    max_seq: int = 131072
    # sliding-window pattern: window size for "local" layers; every
    # `global_every`-th layer (0-based, i % global_every == global_every-1)
    # is global. global_every=0 -> all layers global (full attention).
    sliding_window: int = 0
    global_every: int = 0
    global_layers: tuple[int, ...] = ()   # explicit global-attention layers
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    xlstm: XLSTMConfig | None = None
    use_rope: bool = True          # whisper uses absolute positions instead
    embed_scale: bool = False      # gemma multiplies embeddings by sqrt(d)
    mrope: bool = False            # qwen2-vl multimodal rotary
    mrope_sections: tuple[int, ...] = (16, 24, 24)  # t/h/w splits (half-dim)
    mtp_depth: int = 0             # DeepSeek multi-token-prediction layers
    enc_dec: bool = False
    enc_layers: int = 0
    enc_frames: int = 1500         # whisper stub frame count (train/prefill)
    frontend: Literal["none", "vision_stub", "audio_stub"] = "none"
    vision_patches: int = 0        # vlm stub: leading patch-embedding slots
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: Literal["none", "full", "dots", "names"] = "full"
    train_accum_override: int = 0   # force gradient-accumulation steps
    attn_scores_dtype: str = "float32"   # bf16 halves S^2 score traffic
    # Megatron-SP-style: keep residual-stream activations (and the layer
    # scan stash) sharded over the model axis along the sequence dim;
    # GSPMD inserts the gather/reduce-scatter pairs around attention/MLP.
    seq_shard_activations: bool = False

    # -- derived -------------------------------------------------------------

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports O(seq) decode state (long_500k eligible):
        recurrent state and/or bounded attention windows on *every* layer."""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            # hymba: sliding-window attention + SSM; global layers are the
            # exception — eligible if windows bound every attention layer.
            return self.sliding_window > 0 and self.global_every == 0
        return False

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-bearing (enc-dec incl.)

    def layer_window(self, i: int) -> int:
        """Static per-layer attention window (0 = full/global attention)."""
        if self.sliding_window <= 0:
            return 0
        if i in self.global_layers:
            return 0
        if self.global_every and (i % self.global_every
                                  == self.global_every - 1):
            return 0
        return self.sliding_window

    def window_array(self):
        return tuple(self.layer_window(i) for i in range(self.num_layers))

    def validate(self) -> None:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if self.moe:
            assert self.moe.top_k <= self.moe.num_experts
        if self.family == "vlm":
            assert self.frontend == "vision_stub"
        if self.enc_dec:
            assert self.enc_layers > 0

    # -- parameter counting (for roofline MODEL_FLOPS) -----------------------

    def param_counts(self) -> dict[str, float]:
        """Approximate parameter counts: total and *active* (MoE-aware)."""
        d, hd = self.d_model, self.head_dim_
        nq, nkv = self.num_heads, self.num_kv_heads
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> float:
            if self.mla:
                m = self.mla
                q = d * m.q_lora_rank + m.q_lora_rank * nq * (
                    m.qk_nope_head_dim + m.qk_rope_head_dim)
                kv = d * (m.kv_lora_rank + m.qk_rope_head_dim) \
                    + m.kv_lora_rank * nq * (m.qk_nope_head_dim
                                             + m.v_head_dim)
                o = nq * m.v_head_dim * d
                return q + kv + o
            return d * nq * hd + 2 * d * nkv * hd + nq * hd * d

        def mlp_params(ff: int) -> float:
            mult = 3 if self.act in ("silu", "geglu") else 2
            return mult * d * ff

        total = embed
        active = embed
        for i in range(self.num_layers):
            a = attn_params()
            if self.moe and i >= self.moe.first_k_dense:
                e = mlp_params(self.moe.d_expert)
                total += a + e * (self.moe.num_experts
                                  + self.moe.num_shared_experts)
                active += a + e * (self.moe.top_k
                                   + self.moe.num_shared_experts)
            else:
                ff = (self.moe.d_ff_dense if self.moe and self.moe.d_ff_dense
                      else self.d_ff)
                if self.xlstm is not None:
                    pf = (self.xlstm.slstm_proj_factor if i in
                          self.xlstm.slstm_at else self.xlstm.mlstm_proj_factor)
                    blk = 4 * d * nq * hd + 2 * d * int(pf * d)
                    total += blk
                    active += blk
                    continue
                if self.ssm is not None:  # hybrid adds a parallel SSM path
                    inner = self.ssm.expand * d
                    a += 2 * d * inner + inner * (2 * self.ssm.state_dim + 1)
                total += a + mlp_params(ff)
                active += a + mlp_params(ff)
        if self.enc_dec:
            # encoder layers + decoder cross-attention
            enc = self.enc_layers * (attn_params() + mlp_params(self.d_ff))
            cross = self.num_layers * attn_params()
            total += enc + cross
            active += enc + cross
        return {"total": float(total), "active": float(active)}
