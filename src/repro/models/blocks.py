"""Transformer-family blocks: per-layer params + forward/decode bodies.

Each family has ONE scan body; per-layer heterogeneity (sliding-window vs
global attention) is carried as a scanned int32 array, so a whole layer stack
lowers to a single `lax.scan` (bounded HLO size — required for the 512-device
CPU dry-run; see DESIGN.md §6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe, ssm
from repro.models.config import ModelConfig
from repro.models.layers import Params, dtype_of


# ---------------------------------------------------------------------------
# per-layer parameters
# ---------------------------------------------------------------------------

def layer_params(key, cfg: ModelConfig, kind: str) -> Params:
    """kind: dense | moe | hybrid | mlstm | slstm | enc | dec."""
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Params = {"norm1": layers.norm_params(ks[0], cfg, d)}
    if kind in ("dense", "moe", "hybrid", "enc", "dec"):
        p["attn"] = attention.attn_params(ks[1], cfg)
        p["norm2"] = layers.norm_params(ks[2], cfg, d)
    if kind == "dense":
        p["mlp"] = layers.mlp_params(ks[3], cfg, d, cfg.d_ff)
    elif kind == "moe":
        p["moe"] = moe.moe_params(ks[3], cfg)
    elif kind == "moe_dense":   # leading dense layers of a MoE model
        p["attn"] = attention.attn_params(ks[1], cfg)
        p["norm2"] = layers.norm_params(ks[2], cfg, d)
        p["mlp"] = layers.mlp_params(ks[3], cfg, d, cfg.moe.d_ff_dense)
    elif kind == "hybrid":
        p["mamba"] = ssm.mamba_params(ks[4], cfg)
        p["norm_a"] = layers.norm_params(ks[5], cfg, d)
        p["norm_s"] = layers.norm_params(ks[6], cfg, d)
        p["mlp"] = layers.mlp_params(ks[3], cfg, d, cfg.d_ff)
    elif kind == "mlstm":
        p["mixer"] = ssm.mlstm_params(ks[1], cfg)
    elif kind == "slstm":
        p["mixer"] = ssm.slstm_params(ks[1], cfg)
    elif kind == "enc":
        p["mlp"] = layers.mlp_params(ks[3], cfg, d, cfg.d_ff)
    elif kind == "dec":
        p["cross"] = attention.attn_params(ks[4], cfg)
        p["norm3"] = layers.norm_params(ks[5], cfg, d)
        p["mlp"] = layers.mlp_params(ks[3], cfg, d, cfg.d_ff)
    return p


# ---------------------------------------------------------------------------
# full-sequence forward bodies (train / prefill)
# ---------------------------------------------------------------------------

def _self_attn(cfg, p, x, positions, window, want_cache):
    h = layers.apply_norm(cfg, p["norm1"], x)
    if cfg.mla:
        out, kv = attention.mla_attention(cfg, p["attn"], h, positions,
                                          window)
        cache = {"ckv": kv[0], "kpe": kv[1]} if want_cache else None
    else:
        out, kv = attention.full_attention(cfg, p["attn"], h, positions,
                                           window)
        cache = {"k": kv[0], "v": kv[1]} if want_cache else None
    return out, cache


def layer_fwd(cfg: ModelConfig, kind: str, p: Params, x, positions, window,
              want_cache: bool = False):
    """Returns (x_out, aux_loss, cache_entry_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if kind in ("dense", "moe", "moe_dense"):
        a, cache = _self_attn(cfg, p, x, positions, window, want_cache)
        x = x + a
        h = layers.apply_norm(cfg, p["norm2"], x)
        if kind == "moe":
            m, aux = moe.moe_apply(cfg, p["moe"], h)
        else:
            m = layers.mlp_apply(cfg, p["mlp"], h)
        x = x + m
    elif kind == "hybrid":
        h = layers.apply_norm(cfg, p["norm1"], x)
        a, kv = attention.full_attention(cfg, p["attn"], h, positions, window)
        s = ssm.mamba_apply(cfg, p["mamba"], h)
        mixed = 0.5 * (layers.apply_norm(cfg, p["norm_a"], a)
                       + layers.apply_norm(cfg, p["norm_s"], s))
        x = x + mixed
        h = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.mlp_apply(cfg, p["mlp"], h)
        if want_cache:
            cache = {"k": kv[0], "v": kv[1]}
    elif kind == "mlstm":
        h = layers.apply_norm(cfg, p["norm1"], x)
        x = x + ssm.mlstm_apply(cfg, p["mixer"], h)
    elif kind == "slstm":
        h = layers.apply_norm(cfg, p["norm1"], x)
        x = x + ssm.slstm_apply(cfg, p["mixer"], h)
    elif kind == "enc":
        h = layers.apply_norm(cfg, p["norm1"], x)
        a, _ = attention.full_attention(cfg, p["attn"], h, positions,
                                        jnp.int32(0), causal=False)
        x = x + a
        h = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.mlp_apply(cfg, p["mlp"], h)
    else:
        raise ValueError(kind)
    return x, aux, cache


def dec_layer_fwd(cfg: ModelConfig, p: Params, x, positions, enc_out,
                  enc_positions, want_cache: bool = False):
    """Whisper-style decoder layer: self-attn + cross-attn + MLP."""
    h = layers.apply_norm(cfg, p["norm1"], x)
    a, kv = attention.full_attention(cfg, p["attn"], h, positions,
                                     jnp.int32(0))
    x = x + a
    h = layers.apply_norm(cfg, p["norm3"], x)
    c, ckv = attention.full_attention(cfg, p["cross"], h, positions,
                                      jnp.int32(0), causal=False,
                                      xkv=enc_out, kv_positions=enc_positions)
    x = x + c
    h = layers.apply_norm(cfg, p["norm2"], x)
    x = x + layers.mlp_apply(cfg, p["mlp"], h)
    cache = None
    if want_cache:
        cache = {"k": kv[0], "v": kv[1], "ck": ckv[0], "cv": ckv[1]}
    return x, cache


# ---------------------------------------------------------------------------
# decode bodies (one token, cache/state update)
# ---------------------------------------------------------------------------

def layer_decode(cfg: ModelConfig, kind: str, p: Params, x, cache, position,
                 window):
    """Returns (x_out, new_cache). ``cache`` layout depends on kind."""
    if kind in ("dense", "moe", "moe_dense"):
        h = layers.apply_norm(cfg, p["norm1"], x)
        if cfg.mla:
            a, kv = attention.mla_decode_attention(cfg, p["attn"], h, cache,
                                                   position, window)
        else:
            a, kv = attention.decode_attention(cfg, p["attn"], h, cache,
                                               position, window)
        x = x + a
        h = layers.apply_norm(cfg, p["norm2"], x)
        if kind == "moe":
            m, _ = moe.moe_apply(cfg, p["moe"], h)
        else:
            m = layers.mlp_apply(cfg, p["mlp"], h)
        return x + m, kv
    if kind == "hybrid":
        h = layers.apply_norm(cfg, p["norm1"], x)
        a, kv = attention.decode_attention(cfg, p["attn"], h, cache["attn"],
                                           position, window)
        s, st = ssm.mamba_decode_step(cfg, p["mamba"], h, cache["ssm"])
        mixed = 0.5 * (layers.apply_norm(cfg, p["norm_a"], a)
                       + layers.apply_norm(cfg, p["norm_s"], s))
        x = x + mixed
        h = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.mlp_apply(cfg, p["mlp"], h)
        return x, {"attn": kv, "ssm": st}
    if kind == "mlstm":
        h = layers.apply_norm(cfg, p["norm1"], x)
        o, st = ssm.mlstm_decode_step(cfg, p["mixer"], h, cache)
        return x + o, st
    if kind == "slstm":
        h = layers.apply_norm(cfg, p["norm1"], x)
        o, st = ssm.slstm_decode_step(cfg, p["mixer"], h, cache)
        return x + o, st
    raise ValueError(kind)


def dec_layer_decode(cfg: ModelConfig, p: Params, x, cache, position):
    """Whisper decoder step: self-attn cache update + static cross-attn."""
    h = layers.apply_norm(cfg, p["norm1"], x)
    a, kv = attention.decode_attention(
        cfg, p["attn"], h, {k: cache[k] for k in ("k", "v", "pos")},
        position, jnp.int32(0))
    x = x + a
    h = layers.apply_norm(cfg, p["norm3"], x)
    # cross-attention against the cached encoder K/V (no update)
    b = x.shape[0]
    enc_len = cache["ck"].shape[1]
    q_pos = jnp.full((b, 1), position, jnp.int32)
    k_pos = jnp.broadcast_to(jnp.arange(enc_len, dtype=jnp.int32),
                             (b, enc_len))
    cdt = dtype_of(cfg.compute_dtype)
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    pc = p["cross"]
    q = (h.astype(cdt) @ pc["wq"].astype(cdt)).reshape(b, 1, nq, hd)
    bias = jnp.zeros((b, 1, enc_len), jnp.float32)
    c = attention.gqa_attention(q, cache["ck"], cache["cv"], bias, cdt)
    c = c.reshape(b, 1, nq * hd) @ pc["wo"].astype(cdt)
    x = x + c
    h = layers.apply_norm(cfg, p["norm2"], x)
    x = x + layers.mlp_apply(cfg, p["mlp"], h)
    return x, {**kv, "ck": cache["ck"], "cv": cache["cv"]}
