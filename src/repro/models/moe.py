"""Mixture-of-Experts: top-k router + capacity-grouped sorted dispatch.

Dispatch is ROW-LOCAL: routing/sort/capacity run independently per batch row
(vmapped sort), so under pjit everything stays batch-sharded — no global
argsort (which GSPMD can only lower by all-gathering the token stream; the
first dry-run iteration measured an 18 TB/step collective term from exactly
that). Expert weights are sharded per sharding/specs.py:
  - few big-model experts (deepseek 256e): E over 'model', FFN dim over
    'data' (FSDP-style weight gathers at use; EP all-to-all via shard_map is
    the §Perf upgrade path),
  - many small experts (granite 40e): replicated over E, TP over the FFN dim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.layers import Params, dense_init, dtype_of

#: Optional PartitionSpecs for the dispatch path, set by the launcher
#: (launch/dryrun.py) when experts are sharded E x (data, model).
#: The scatter/gather must stay BATCH-major (token-local; GSPMD's scatter
#: into an expert-major buffer falls back to full replication — measured
#: 9 TB/device of temps on deepseek-671b), while the expert einsums must be
#: EXPERT-major (aligned with the weights). The two constraints around the
#: reshape force GSPMD to emit the token all-to-all of production EP.
_BUF_SPEC_E = None     # [B, E, C, d] expert-major
_BUF_SPEC_B = None     # [B, slots, d] batch-major


def set_buf_spec(spec_e, spec_b=None):
    global _BUF_SPEC_E, _BUF_SPEC_B
    _BUF_SPEC_E = spec_e
    _BUF_SPEC_B = spec_b


def _constrain_e(x):
    if _BUF_SPEC_E is not None:
        return jax.lax.with_sharding_constraint(x, _BUF_SPEC_E)
    return x


def _constrain_b(x):
    if _BUF_SPEC_B is not None:
        return jax.lax.with_sharding_constraint(x, _BUF_SPEC_B)
    return x


def capacity(tokens_per_row: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(tokens_per_row * m.top_k / m.num_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def padded_experts(cfg: ModelConfig, align: int = 16) -> int:
    """Expert tensors are padded to a multiple of the model-axis size so the
    expert dim shards cleanly (granite's 40 -> 48; dead experts are never
    routed to — the router stays at the true expert count)."""
    e = cfg.moe.num_experts
    if e % align == 0 or e < align:
        return e
    return -(-e // align) * align


def moe_params(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    dt = dtype_of(cfg.param_dtype)
    d, f, e = cfg.d_model, m.d_expert, padded_experts(cfg)
    ks = jax.random.split(key, 5)

    def experts(k, d_in, d_out):
        s = 1.0 / jnp.sqrt(d_in)
        return (jax.random.normal(k, (e, d_in, d_out)) * s).astype(dt)

    p = {
        # router stays at the TRUE expert count (padded experts unreachable)
        "router": dense_init(ks[0], d, m.num_experts, jnp.float32),
        "w_gate": experts(ks[1], d, f),
        "w_up": experts(ks[2], d, f),
        "w_down": experts(ks[3], f, d),
    }
    if m.num_shared_experts:
        p["shared"] = layers.mlp_params(
            ks[4], cfg, d, f * m.num_shared_experts)
    return p


def _route_one_row(xf, router, cfg: ModelConfig, cap: int):
    """Routing for one row: xf [S, d] -> (dest [S*K], weights [S*K],
    counts [E]). dest == E*cap means 'dropped'."""
    m = cfg.moe
    k, e = m.top_k, m.num_experts
    s = xf.shape[0]
    logits = xf.astype(jnp.float32) @ router                   # [S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                     # [S, K]
    if m.router_scale:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_i.reshape(s * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    offsets = jnp.cumsum(counts) - counts
    rank = jnp.arange(s * k, dtype=jnp.int32) - offsets[sorted_e]
    keep = rank < cap
    dest_sorted = jnp.where(keep, sorted_e * cap + rank, e * cap)
    # un-sort so dest aligns with copy index (token t, choice j) = t*K+j
    dest = jnp.zeros((s * k,), jnp.int32).at[order].set(dest_sorted)
    return dest, top_p.reshape(s * k), counts, probs


def moe_apply(cfg: ModelConfig, p: Params, x):
    """x [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    m = cfg.moe
    cdt = dtype_of(cfg.compute_dtype)
    b, s, d = x.shape
    k, e = m.top_k, m.num_experts
    cap = capacity(s, cfg)

    e_pad = padded_experts(cfg)
    xf = x.astype(cdt)                                         # [B, S, d]
    dest, weights, counts, probs = jax.vmap(
        lambda row: _route_one_row(row, p["router"], cfg, cap))(xf)
    # dest [B, S*K]; weights [B, S*K]; counts [B, E]

    copies = jnp.repeat(xf, k, axis=1)                         # [B, S*K, d]
    buf = _constrain_b(jnp.zeros((b, e_pad * cap + 1, d), cdt))
    drop_slot = e * cap
    dest = jnp.where(dest >= drop_slot, e_pad * cap, dest)
    buf = _constrain_b(
        jax.vmap(lambda bb, dd, cc: bb.at[dd].set(cc))(buf, dest, copies))
    buf = _constrain_e(buf[:, :-1].reshape(b, e_pad, cap, d))

    act = layers.act_fn(cfg.act)
    h = act(jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(cdt))) \
        * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(cdt))
    y = _constrain_e(jnp.einsum("becf,efd->becd", h,
                                p["w_down"].astype(cdt)))

    y_flat = _constrain_b(
        jnp.concatenate([y.reshape(b, e_pad * cap, d),
                         jnp.zeros((b, 1, d), cdt)], axis=1))
    out_copies = jax.vmap(lambda yy, dd: yy[dd])(y_flat, dest)  # [B,S*K,d]
    out = (out_copies.reshape(b, s, k, d)
           * weights.reshape(b, s, k)[..., None].astype(cdt)).sum(axis=2)

    if m.num_shared_experts:
        out = out + layers.mlp_apply(cfg, p["shared"], xf)

    # load-balance aux loss (Switch-style), averaged over rows
    frac_tokens = counts.astype(jnp.float32) / jnp.maximum(s * k, 1)
    mean_prob = probs.mean(axis=1)                              # [B, E]
    aux = e * jnp.sum(frac_tokens * mean_prob, axis=-1).mean() \
        * m.aux_loss_weight
    return out.reshape(b, s, d), aux
