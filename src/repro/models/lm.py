"""Causal LM assembly: scan-over-layers, caches, losses, cost fragments.

One class covers dense / moe / hybrid / ssm / vlm families; whisper.py wraps
it for the enc-dec family. All public entry points are pure functions of
(params, batch) suitable for jax.jit with shardings.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention, blocks, layers, ssm
from repro.models.config import ModelConfig
from repro.models.layers import Params, dtype_of


@dataclasses.dataclass(frozen=True)
class LayerGroup:
    kind: str                  # blocks.layer_params kind
    indices: tuple[int, ...]   # absolute layer ids
    scanned: bool

    @property
    def size(self) -> int:
        return len(self.indices)


@dataclasses.dataclass(frozen=True)
class Fragment:
    """A compiled-cost fragment for the roofline combiner: the enclosed fn
    executes ``extra_trips`` more times at runtime than it is counted in the
    full step's HLO (scan bodies are counted once; see launch/dryrun.py).

    arg_kinds aligns with args: "params" (use the param sharding rules),
    "cache" (cache/state rules), or a trailing-dims tail tuple for
    sharding/specs._fit (e.g. ("data", None, "model", None))."""

    name: str
    fn: Callable
    args: tuple
    extra_trips: int
    arg_kinds: tuple = ()


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    if cfg.remat == "names":
        # "minimal" remat: stash QKV projections and MLP pre-activations so
        # the backward pass skips recomputing the projection matmuls;
        # attention scores stay rematerialized per q-chunk (flash-style).
        pol = jax.checkpoint_policies.save_only_these_names(
            "qkv", "mlp_pre_up", "mlp_pre_gate")
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def make_groups(cfg: ModelConfig) -> list[LayerGroup]:
    """Split layers into uniform-kind groups; scan groups of >= 4 layers."""
    kinds: list[str] = []
    for i in range(cfg.num_layers):
        if cfg.family == "moe":
            kinds.append("moe_dense" if i < cfg.moe.first_k_dense else "moe")
        elif cfg.family == "hybrid":
            kinds.append("hybrid")
        elif cfg.family == "ssm":
            kinds.append("slstm" if i in cfg.xlstm.slstm_at else "mlstm")
        else:
            kinds.append("dense")
    groups: list[LayerGroup] = []
    start = 0
    for i in range(1, cfg.num_layers + 1):
        if i == cfg.num_layers or kinds[i] != kinds[start]:
            idx = tuple(range(start, i))
            groups.append(LayerGroup(kinds[start], idx, len(idx) >= 4))
            start = i
    return groups


class LM:
    #: optional PartitionSpec for residual-stream activations — set by the
    #: launcher (seq-sharded stash, Megatron-SP style). None = compiler's
    #: choice. Only consulted on full-sequence paths.
    act_spec = None

    def __init__(self, cfg: ModelConfig):
        cfg.validate()
        self.cfg = cfg
        self.groups = make_groups(cfg)
        self.windows = np.asarray(cfg.window_array(), np.int32)

    def _constrain(self, x):
        if self.act_spec is not None:
            return jax.lax.with_sharding_constraint(x, self.act_spec)
        return x

    # -- parameters ----------------------------------------------------------

    def init(self, key) -> Params:
        cfg = self.cfg
        kg, ke, kh, km = jax.random.split(key, 4)
        dt = dtype_of(cfg.param_dtype)
        params: Params = {
            "embed": layers.embed_init(ke, cfg.vocab_size, cfg.d_model, dt),
            "final_norm": layers.norm_params(kh, cfg, cfg.d_model),
            "groups": [],
        }
        gkeys = jax.random.split(kg, len(self.groups))
        for g, gk in zip(self.groups, gkeys):
            lkeys = jax.random.split(gk, g.size)
            if g.scanned:
                params["groups"].append(
                    jax.vmap(lambda k: blocks.layer_params(k, cfg, g.kind))(
                        lkeys))
            else:
                params["groups"].append(
                    [blocks.layer_params(k, cfg, g.kind) for k in lkeys])
        if not cfg.tie_embeddings:
            params["head"] = layers.dense_init(km, cfg.d_model,
                                               cfg.vocab_size, dt)
        if cfg.mtp_depth:
            kp, kl = jax.random.split(km)
            params["mtp"] = {
                "proj": layers.dense_init(kp, 2 * cfg.d_model, cfg.d_model,
                                          dt),
                "layer": blocks.layer_params(kl, cfg, "moe_dense"
                                             if cfg.family == "moe"
                                             else "dense"),
                "norm_h": layers.norm_params(kp, cfg, cfg.d_model),
                "norm_e": layers.norm_params(kl, cfg, cfg.d_model),
            }
        return params

    def param_specs(self) -> Params:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # -- embedding -------------------------------------------------------------

    def embed(self, params: Params, batch: dict):
        cfg = self.cfg
        cdt = dtype_of(cfg.compute_dtype)
        tok = params["embed"][batch["tokens"]].astype(cdt)
        if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(cdt), tok], axis=1)
        else:
            x = tok
        if getattr(cfg, "embed_scale", False):
            x = x * np.sqrt(cfg.d_model)
        return x

    def _positions(self, batch: dict, seq: int, batchsz: int):
        cfg = self.cfg
        if cfg.mrope:
            if "positions" in batch:
                return batch["positions"]
            p = jnp.arange(seq, dtype=jnp.int32)
            return jnp.broadcast_to(p, (3, batchsz, seq))
        return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                                (batchsz, seq))

    # -- full-sequence forward ---------------------------------------------------

    def hidden(self, params: Params, x, positions, want_cache: bool = False):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        caches = []
        for g, gp in zip(self.groups, params["groups"]):
            wins = jnp.asarray(self.windows[list(g.indices)])
            if g.scanned:
                def body(x, xs, _kind=g.kind):
                    x = self._constrain(x)
                    p_l, win = xs
                    x, aux, cache = blocks.layer_fwd(
                        cfg, _kind, p_l, x, positions, win, want_cache)
                    return self._constrain(x), (aux, cache)
                body = _remat(cfg, body)
                x, (auxs, cache) = jax.lax.scan(body, x, (gp, wins))
                aux_total = aux_total + auxs.sum()
                caches.append(cache)
            else:
                group_cache = []
                for j, p_l in enumerate(gp):
                    x, aux, cache = blocks.layer_fwd(
                        cfg, g.kind, p_l, x, positions, wins[j], want_cache)
                    aux_total = aux_total + aux
                    group_cache.append(cache)
                caches.append(group_cache)
        x = layers.apply_norm(cfg, params["final_norm"], x)
        return x, aux_total, (caches if want_cache else None)

    def logits(self, params: Params, h):
        cfg = self.cfg
        cdt = dtype_of(cfg.compute_dtype)
        w = params["embed"].T if cfg.tie_embeddings else params["head"]
        return h.astype(cdt) @ w.astype(cdt)

    # -- losses --------------------------------------------------------------------

    def head_matrix(self, params: Params):
        return (params["embed"].T if self.cfg.tie_embeddings
                else params["head"])

    def loss(self, params: Params, batch: dict):
        cfg = self.cfg
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x = self.embed(params, batch)
        s = x.shape[1]
        positions = self._positions(batch, s, b)
        h, aux, _ = self.hidden(params, x, positions)
        # next-token CE on the text region (stub patches are not predicted);
        # fused head+CE avoids materializing [B,S,V] logits.
        vis = s - tokens.shape[1]
        ce = layers.softmax_xent_fused(h[:, vis:-1, :],
                                       self.head_matrix(params),
                                       tokens[:, 1:])
        total = ce + aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp_depth:
            mtp_ce = self._mtp_loss(params, h[:, vis:], tokens, positions)
            metrics["mtp_ce"] = mtp_ce
            total = total + 0.3 * mtp_ce
        return total, metrics

    def _mtp_loss(self, params: Params, h, tokens, positions):
        """DeepSeek-V3 multi-token prediction (depth 1): one extra layer
        predicts t+2 from [h_t ; embed(token_{t+1})]."""
        cfg = self.cfg
        cdt = dtype_of(cfg.compute_dtype)
        p = params["mtp"]
        h_in = layers.apply_norm(cfg, p["norm_h"], h[:, :-1])
        e_in = layers.apply_norm(
            cfg, p["norm_e"], params["embed"][tokens[:, 1:]].astype(cdt))
        x = jnp.concatenate([h_in, e_in], axis=-1) @ p["proj"].astype(cdt)
        pos = positions[..., :-1] if not cfg.mrope else positions[..., :-1]
        kind = "moe_dense" if cfg.family == "moe" else "dense"
        x, _, _ = blocks.layer_fwd(cfg, kind, p["layer"], x, pos,
                                   jnp.int32(0))
        return layers.softmax_xent_fused(x[:, :-1, :],
                                         self.head_matrix(params),
                                         tokens[:, 2:])

    # -- prefill / decode ------------------------------------------------------------

    def cache_capacity(self, layer_idx: int, max_len: int) -> int:
        w = self.cfg.layer_window(layer_idx)
        return min(max_len, w) if w else max_len

    def init_cache(self, batch: int, max_len: int):
        """Decode cache pytree, grouped like params["groups"]."""
        cfg = self.cfg
        out = []
        for g in self.groups:
            cap = max(self.cache_capacity(i, max_len) for i in g.indices)
            if g.kind in ("dense", "moe", "moe_dense"):
                entry = attention.init_cache(cfg, batch, cap,
                                             layer_axes=(g.size,)
                                             if g.scanned else ())
                out.append(entry if g.scanned else
                           [jax.tree.map(lambda x: x, entry)
                            for _ in range(g.size)])
            elif g.kind == "hybrid":
                mk = lambda n: {
                    "attn": attention.init_cache(cfg, batch, cap,
                                                 layer_axes=(n,) if n else ()),
                    "ssm": ssm.mamba_init_state(cfg, batch,
                                                layer_axes=(n,) if n else ()),
                }
                out.append(mk(g.size) if g.scanned else
                           [mk(0) for _ in range(g.size)])
            elif g.kind == "mlstm":
                e = [ssm.mlstm_init_state(cfg, batch) for _ in g.indices]
                out.append(jax.tree.map(lambda *x: jnp.stack(x), *e)
                           if g.scanned else e)
            elif g.kind == "slstm":
                e = [ssm.slstm_init_state(cfg, batch) for _ in g.indices]
                out.append(jax.tree.map(lambda *x: jnp.stack(x), *e)
                           if g.scanned else e)
        return out

    def decode_step(self, params: Params, cache, tokens, position):
        """tokens [B,1]; returns (logits [B,1,V], new_cache)."""
        cfg = self.cfg
        x = self.embed(params, {"tokens": tokens})
        new_cache = []
        for g, gp, gc in zip(self.groups, params["groups"], cache):
            wins = jnp.asarray(self.windows[list(g.indices)])
            if g.scanned:
                def body(x, xs, _kind=g.kind):
                    p_l, c_l, win = xs
                    x, nc = blocks.layer_decode(cfg, _kind, p_l, x, c_l,
                                                position, win)
                    return x, nc
                x, nc = jax.lax.scan(body, x, (gp, gc, wins))
                new_cache.append(nc)
            else:
                ncs = []
                for j, (p_l, c_l) in enumerate(zip(gp, gc)):
                    x, nc = blocks.layer_decode(cfg, g.kind, p_l, x, c_l,
                                                position, wins[j])
                    ncs.append(nc)
                new_cache.append(ncs)
        x = layers.apply_norm(cfg, params["final_norm"], x)
        return self.logits(params, x), new_cache

    def prefill(self, params: Params, batch: dict):
        """Full-sequence forward that also returns logits of the last token.
        (Cache-building prefill for serving lives in serve/; the dry-run
        lowers this pure forward as the prefill cost.)"""
        tokens = batch["tokens"]
        x = self.embed(params, batch)
        positions = self._positions(batch, x.shape[1], x.shape[0])
        h, _, _ = self.hidden(params, x, positions)
        return self.logits(params, h[:, -1:, :])

    # -- roofline fragments -------------------------------------------------------

    def fragments(self, mode: str, batch: int, seq: int) -> list[Fragment]:
        """Scan bodies whose HLO cost must be scaled by their trip counts:
        layer-scan bodies, attention q-chunk bodies, SSM chunk bodies, and
        sLSTM cells. mode: train | prefill | decode. See DESIGN.md §7 —
        total = full + sum_f extra_trips_f * frag_f is exact because each
        enclosing body counts its nested bodies exactly once."""
        cfg = self.cfg
        cdt = dtype_of(cfg.compute_dtype)
        frags: list[Fragment] = []
        pspecs = self.param_specs()
        sds = jax.ShapeDtypeStruct
        if cfg.mrope:
            pos = sds((3, batch, seq), jnp.int32)
        else:
            pos = sds((batch, seq), jnp.int32)
        x_spec = sds((batch, seq, cfg.d_model), cdt)
        dp = "data"

        for gi, g in enumerate(self.groups):
            gp = pspecs["groups"][gi]
            p1 = (jax.tree.map(lambda s: sds(s.shape[1:], s.dtype), gp)
                  if g.scanned else gp[0])
            if mode in ("train", "prefill") and g.scanned:
                def fwd(p_l, x, positions, _kind=g.kind):
                    # mirror the real scan body's layout constraints
                    x = self._constrain(x)
                    y, aux, _ = blocks.layer_fwd(cfg, _kind, p_l, x,
                                                 positions, jnp.int32(0))
                    return self._constrain(y), aux
                frags.append(Fragment(
                    f"layer_{g.kind}", _remat(cfg, fwd), (p1, x_spec, pos),
                    g.size - 1,
                    ("params", (dp, None, None),
                     (None, dp, None) if cfg.mrope else (dp, None))))
            if mode == "decode" and g.scanned:
                cap = max(self.cache_capacity(i, seq) for i in g.indices)
                cache1 = jax.eval_shape(
                    functools.partial(self._cache_one, g.kind, batch, cap))
                x1 = sds((batch, 1, cfg.d_model), cdt)

                def dec(p_l, x, c_l, _kind=g.kind):
                    return blocks.layer_decode(cfg, _kind, p_l, x, c_l,
                                               jnp.int32(0), jnp.int32(0))
                frags.append(Fragment(f"decode_{g.kind}", dec,
                                      (p1, x1, cache1), g.size - 1,
                                      ("params", (dp, None, None), "cache")))

        if mode not in ("train", "prefill"):
            return frags

        # ---- attention q-chunk bodies (inside every attn layer) ----------
        nc = attention.attn_q_chunks(seq)
        n_attn = sum(1 for g in self.groups
                     if g.kind in ("dense", "moe", "moe_dense", "hybrid")
                     for _ in g.indices)
        if nc > attention.CHUNK_SCAN_THRESHOLD and n_attn:
            chunk = -(-seq // nc)
            nq, hd = cfg.num_heads, cfg.head_dim_
            msize = 1
            if cfg.mla:
                m = cfg.mla
                qd = m.qk_nope_head_dim + m.qk_rope_head_dim
                qc = sds((batch, chunk, nq, qd), cdt)
                kf = sds((batch, seq, nq, qd), cdt)
                vf = sds((batch, seq, nq, m.v_head_dim), cdt)
            else:
                qc = sds((batch, chunk, nq, hd), cdt)
                kf = sds((batch, seq, nq, hd), cdt)
                vf = sds((batch, seq, nq, hd), cdt)
            pc = sds((batch, chunk), jnp.int32)
            kp = sds((batch, seq), jnp.int32)

            def attn_chunk(q, p_q, k, v, p_k):
                bias = attention._window_bias(p_q, p_k, jnp.int32(0), True)
                return attention._mha_one_chunk(q, k, v, bias, cdt)
            head_tail = lambda: (dp, None, "model", None) \
                if nq % 16 == 0 else (dp, None, None, None)
            frags.append(Fragment(
                "attn_chunk", _remat(cfg, attn_chunk), (qc, pc, kf, vf, kp),
                (nc - 1) * n_attn,
                (head_tail(), (dp, None), head_tail(), head_tail(),
                 (dp, None))))

        # ---- mamba chunk bodies -------------------------------------------
        if cfg.ssm is not None:
            nc_s = -(-seq // ssm.SSM_CHUNK)
            n_ssm = cfg.num_layers
            if nc_s > 1 and n_ssm:
                inner = cfg.ssm.expand * cfg.d_model
                pm = {"a_log": sds((inner, cfg.ssm.state_dim), jnp.dtype(
                    cfg.param_dtype))}
                h0 = sds((batch, inner, cfg.ssm.state_dim), jnp.float32)
                c = min(ssm.SSM_CHUNK, seq)
                dtc = sds((batch, c, inner), cdt)
                bc = sds((batch, c, cfg.ssm.state_dim), cdt)
                frags.append(Fragment(
                    "mamba_chunk", _remat(cfg, ssm.mamba_chunk_body),
                    (pm, h0, dtc, dtc, bc, bc), (nc_s - 1) * n_ssm,
                    ("params", (dp, "model", None), (dp, None, "model"),
                     (dp, None, "model"), (dp, None, None),
                     (dp, None, None))))

        # ---- mLSTM chunk bodies -------------------------------------------
        if cfg.xlstm is not None:
            n_m = len([i for i in range(cfg.num_layers)
                       if i not in cfg.xlstm.slstm_at])
            nc_m = -(-seq // ssm.MLSTM_CHUNK)
            if nc_m > 1 and n_m:
                nh, hd = cfg.num_heads, cfg.head_dim_
                c = min(ssm.MLSTM_CHUNK, seq)
                carry = (sds((batch, nh, hd, hd), jnp.float32),
                         sds((batch, nh, hd), jnp.float32),
                         sds((batch, nh), jnp.float32))
                qkv = sds((batch, c, nh, hd), cdt)
                gate = sds((batch, c, nh), jnp.float32)
                frags.append(Fragment(
                    "mlstm_chunk",
                    _remat(cfg, lambda cry, q, k, v, i, f:
                           ssm.mlstm_chunk_body(cry, q, k, v, i, f)),
                    (carry, qkv, qkv, qkv, gate, gate), (nc_m - 1) * n_m,
                    ("cache", (dp, None, None, None), (dp, None, None, None),
                     (dp, None, None, None), (dp, None, None),
                     (dp, None, None))))

        # ---- sLSTM sequential cells ----------------------------------------
        if cfg.xlstm is not None:
            n_slstm = len([i for i in range(cfg.num_layers)
                           if i in cfg.xlstm.slstm_at])
            if n_slstm and seq > 1:
                nh, hd = cfg.num_heads, cfg.head_dim_
                pl = jax.eval_shape(
                    lambda: ssm.slstm_params(jax.random.PRNGKey(0), cfg))
                carry = tuple(sds((batch, nh, hd), jnp.float32)
                              for _ in range(4))
                xg = sds((batch, 4, nh, hd), cdt)
                frags.append(Fragment(
                    "slstm_cell", lambda p, c, x: ssm.slstm_cell(p, c, x),
                    (pl, carry, xg), (seq - 1) * n_slstm,
                    ("params", "cache", (dp, None, None, None))))
        return frags

    def _cache_one(self, kind: str, batch: int, cap: int):
        cfg = self.cfg
        if kind in ("dense", "moe", "moe_dense"):
            return attention.init_cache(cfg, batch, cap)
        if kind == "hybrid":
            return {"attn": attention.init_cache(cfg, batch, cap),
                    "ssm": ssm.mamba_init_state(cfg, batch)}
        if kind == "mlstm":
            return ssm.mlstm_init_state(cfg, batch)
        return ssm.slstm_init_state(cfg, batch)
