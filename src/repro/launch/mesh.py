"""Production meshes. Import must never touch jax device state — meshes are
built by functions only (the dry-run sets XLA_FLAGS before first jax init)."""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model) — 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) = (pod, data, model) — 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_debug_mesh(devices: int = 8, model: int = 2):
    """Small mesh for CPU integration tests (requires the host-device flag)."""
    return compat.make_mesh((devices // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mp_axis(mesh) -> str:
    return "model"
