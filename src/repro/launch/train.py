"""Training launcher.

CPU (this container): runs a reduced config end-to-end with checkpointing:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 50

TPU pod (the target): the same entry point builds the production mesh and
full config; the dry-run path (--dry-run) lowers/compiles only.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile the production cell instead of "
                         "executing (see repro.launch.dryrun for the full "
                         "sweep)")
    args = ap.parse_args()

    from repro.configs import registry
    if args.dry_run:
        from repro.launch import dryrun
        rec = dryrun.run_cell(args.arch, "train_4k", multi_pod=False)
        print(rec.get("status"), rec.get("memory"))
        return

    cfg = registry.get_smoke_config(args.arch) if args.smoke \
        else registry.get_config(args.arch)
    model = registry.make_model(cfg)
    from repro.data.pipeline import ShardedTokenDataset
    from repro.train.loop import LoopConfig, Trainer
    from repro.train.optimizer import OptConfig
    from repro.train.trainstep import opt_config_for

    ds = ShardedTokenDataset(cfg.vocab_size, args.seq, num_shards=8)

    def batch_fn(step):
        if cfg.enc_dec:
            rng = np.random.default_rng(step)
            return {
                "frames": jnp.asarray(rng.normal(size=(
                    args.batch, cfg.enc_frames, cfg.d_model)),
                    jnp.dtype(cfg.compute_dtype)),
                "tokens": jnp.asarray(ds.batch(0, step, args.batch)),
            }
        if cfg.frontend == "vision_stub":
            rng = np.random.default_rng(step)
            p = min(cfg.vision_patches, args.seq // 2)
            return {
                "patch_embeds": jnp.asarray(
                    rng.normal(size=(args.batch, p, cfg.d_model)) * 0.02,
                    jnp.dtype(cfg.compute_dtype)),
                "tokens": jnp.asarray(ds.batch(0, step, args.batch)
                                      [:, :args.seq - p]),
            }
        return {"tokens": jnp.asarray(ds.batch(0, step, args.batch))}

    trainer = Trainer(model, opt_config_for(cfg, lr=1e-3,
                                            total_steps=args.steps),
                      LoopConfig(total_steps=args.steps, ckpt_every=25,
                                 log_every=10),
                      args.ckpt, batch_fn)
    step, _, _, metrics = trainer.run()
    print(f"finished at step {step}: loss={float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
