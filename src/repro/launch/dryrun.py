import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable (e)).

For every (architecture x input-shape x mesh) cell:
  jit(step).lower(**ShapeDtypeStructs).compile() under the production mesh,
  print memory_analysis() (proves it fits) and cost_analysis() (roofline),
  parse the optimized HLO for collective ops, lower each scan-body Fragment
  separately (XLA counts while bodies once — DESIGN.md §7), and persist a
  JSON record in benchmarks/results/dryrun/.

Meshes: single-pod (16,16)=(data,model), multi-pod (2,16,16)=(pod,data,model).
Run:  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models.config import ModelConfig
from repro.roofline import analyze
from repro.sharding import specs as sh
from repro.train import optimizer as opt
from repro.train.trainstep import (accum_steps_for, make_train_step,
                                   opt_config_for)

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" \
    / "results" / "dryrun"


def _sds_tree(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)


def frag_arg_sharding(cfg: ModelConfig, mesh, arg, kind):
    """Shardings for a roofline-fragment argument, per Fragment.arg_kinds."""
    dp = dp_axes(mesh)
    if kind == "params":
        return sh.param_shardings(cfg, mesh, arg)
    if kind == "cache":
        def leaf(path, x):
            if path and isinstance(arg, dict):
                return sh.cache_leaf_sharding(cfg, mesh, path, x)
            return NamedSharding(
                mesh, sh._fit(mesh, x.shape,
                              (dp,) + (None,) * (len(x.shape) - 1)))
        return jax.tree_util.tree_map_with_path(leaf, arg)
    # explicit trailing-dims tail
    tail = kind if kind else ()

    def bare(x):
        if tail:
            return NamedSharding(mesh, sh._fit(mesh, x.shape, tail))
        return NamedSharding(mesh, P(*(None,) * len(x.shape)))
    return jax.tree.map(bare, arg)


def _collect(compiled, chips_per_pod=analyze.CHIPS_PER_POD):
    from repro import compat
    ca = compat.cost_analysis(compiled)
    txt = compiled.as_text()
    colls = analyze.parse_collectives(txt, chips_per_pod)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collectives": [c.__dict__ for c in colls],
        "n_collectives": len(colls),
    }


def _memory(compiled):
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        out[k] = int(getattr(ma, k, 0) or 0)
    out["total_bytes_per_device"] = (
        out["argument_size_in_bytes"] + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"] - out["alias_size_in_bytes"])
    return out


def _grad_wrap(fn, stop_param_grads: bool = False):
    """Lower fn together with its backward pass (train-mode fragments).

    stop_param_grads=True stops gradients at the first (param) argument:
    used for the COLLECTIVE count only — inside the real layer scan, the
    per-layer dW stays a local partial sum (reduced once per step, which the
    full/microbatch HLO already counts), so the all-reduce a standalone vjp
    emits per call is an accounting artifact, not program traffic."""
    def wrapped(*args):
        if stop_param_grads:
            fn2 = lambda p, *rest: fn(jax.lax.stop_gradient(p), *rest)
        else:
            fn2 = fn
        out, vjp = jax.vjp(fn2, *args)
        cts = jax.tree.map(lambda o: jnp.ones(o.shape, o.dtype), out)
        return vjp(cts)
    return wrapped


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> dict:
    cfg = registry.get_config(arch)
    shape = registry.SHAPES[shape_name]
    ok, reason = registry.cell_supported(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "timestamp": time.time()}
    if not ok:
        rec.update(status="SKIP", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    model = registry.make_model(cfg)
    batch_specs = registry.input_specs(cfg, shape)
    pspecs = model.param_specs()

    t0 = time.time()
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))
    accum = 1
    with mesh:
        from repro.models import moe as moe_mod
        if cfg.seq_shard_activations and shape.kind in ("train", "prefill") \
                and not cfg.enc_dec:
            model.act_spec = P(dp_axes(mesh), "model", None)
        if cfg.moe and cfg.moe.num_experts % (
                mesh.shape["data"] * mesh.shape["model"]) == 0:
            # expert-major einsums + batch-major scatter/gather -> token
            # all-to-alls (production EP) instead of replication fallbacks
            moe_mod.set_buf_spec(P(None, ("data", "model"), None, None),
                                 P(dp_axes(mesh), None, None))
        else:
            moe_mod.set_buf_spec(None)
        pshard = sh.param_shardings(cfg, mesh, pspecs)
        bshard = sh.batch_shardings(cfg, mesh, batch_specs)
        if shape.kind == "train":
            accum = accum_steps_for(cfg, shape.global_batch, shape.seq_len,
                                    dp_size, mesh.shape["model"])
            rec["accum_steps"] = accum
            gspecs = (sh.grad_shardings(cfg, mesh, pspecs)
                      if accum > 1 else None)
            mb_sh = (jax.tree.map(
                lambda ns: NamedSharding(mesh, P(None, *ns.spec)), bshard)
                if accum > 1 else None)
            ocfg = opt_config_for(cfg)
            ospecs = opt.opt_state_specs(ocfg, pspecs)
            oshard = sh.opt_shardings(cfg, mesh, ospecs)
            step = make_train_step(model, ocfg, accum, gspecs, mb_sh)
            # explicit out_shardings: without them GSPMD replicates the
            # updated params/opt state (638 GiB/device of outputs + 11 TB
            # of temps measured on deepseek-671b) and donation can't alias
            jfn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                          out_shardings=(pshard, oshard, None),
                          donate_argnums=(0, 1))
            lowered = jfn.lower(pspecs, ospecs, batch_specs)
        elif shape.kind == "prefill":
            fn = registry.step_fn(cfg, shape, model)
            jfn = jax.jit(fn, in_shardings=(pshard, bshard))
            lowered = jfn.lower(pspecs, batch_specs)
        else:  # decode
            fn = registry.step_fn(cfg, shape, model)
            # cache-out shardings must match cache-in for donation to alias
            jfn = jax.jit(fn, in_shardings=(pshard, bshard),
                          out_shardings=(None, bshard["cache"]),
                          donate_argnums=(1,))
            lowered = jfn.lower(pspecs, batch_specs)
        compiled = lowered.compile()
        rec["lower_compile_s"] = time.time() - t0
        mem = _memory(compiled)
        full = _collect(compiled)
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] "
                  f"compile {rec['lower_compile_s']:.1f}s  "
                  f"mem/device {mem['total_bytes_per_device']/2**30:.2f} GiB "
                  f"flops/chip {full['flops']:.3e} "
                  f"colls {full['n_collectives']}")
            print("  memory_analysis:", mem)

        # ---- scan-body fragments --------------------------------------
        # trip accounting with gradient accumulation (see DESIGN.md §7):
        #   total = full + (accum-1) x microbatch + accum x Σ frag_extra x frag
        frag_parts = []
        if not cfg.enc_dec:
            mode = shape.kind if shape.kind != "prefill" else "prefill"
            b, s = _cell_bs(cfg, shape)
            b_frag = max(b // accum, 1) if shape.kind == "train" else b
            for frag in model.fragments(mode, b_frag, s):
                kinds = frag.arg_kinds or ("params",) + ((),) * (
                    len(frag.args) - 1)
                in_sh = tuple(
                    frag_arg_sharding(cfg, mesh, a, kinds[i])
                    for i, a in enumerate(frag.args))
                try:
                    if shape.kind == "train":
                        fc = jax.jit(_grad_wrap(frag.fn),
                                     in_shardings=in_sh).lower(
                            *frag.args).compile()
                        part = _collect(fc)
                        if kinds[0] == "params":
                            # collectives from the artifact-free lowering
                            fc2 = jax.jit(
                                _grad_wrap(frag.fn, stop_param_grads=True),
                                in_shardings=in_sh).lower(
                                *frag.args).compile()
                            part["collectives"] = _collect(fc2)["collectives"]
                            part["n_collectives"] = len(part["collectives"])
                    else:
                        fc = jax.jit(frag.fn, in_shardings=in_sh).lower(
                            *frag.args).compile()
                        part = _collect(fc)
                    part["mult"] = frag.extra_trips * accum
                    part["name"] = frag.name
                    frag_parts.append(part)
                except Exception as e:  # fragment failures are non-fatal
                    frag_parts.append({"name": frag.name, "error": str(e)[:500],
                                       "mult": frag.extra_trips * accum})
        if accum > 1:
            # the microbatch grad body itself (counted once in full HLO)
            mb_specs = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(
                    (x.shape[0] // accum,) + x.shape[1:], x.dtype),
                batch_specs)
            mb_shard = sh.batch_shardings(cfg, mesh, mb_specs)

            def mb_grad(params, mb):
                return jax.grad(lambda p, m: model.loss(p, m)[0])(params, mb)
            try:
                fc = jax.jit(mb_grad, in_shardings=(pshard, mb_shard)).lower(
                    pspecs, mb_specs).compile()
                part = _collect(fc)
                part["mult"] = accum - 1
                part["name"] = "microbatch_grad"
                frag_parts.append(part)
            except Exception as e:
                frag_parts.append({"name": "microbatch_grad",
                                   "error": str(e)[:500], "mult": accum - 1})
        rec.update(status="OK", chips=chips, memory=mem, full=full,
                   fragments=frag_parts)
    return rec


def _cell_bs(cfg, shape):
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return b, s
    if cfg.frontend == "vision_stub":
        return b, s  # embed-level seq is still s (patches + text)
    return b, s


def roofline_record(rec: dict) -> dict | None:
    if rec.get("status") != "OK":
        return None
    parts = [dict(rec["full"], mult=1)]
    for f in rec.get("fragments", []):
        if "error" not in f:
            parts.append(dict(f))
    parts = [
        dict(p, collectives=[analyze.CollectiveOp(**c) if isinstance(c, dict)
                             else c for c in p.get("collectives", [])])
        for p in parts]
    terms = analyze.terms_from_parts(parts, rec["chips"])
    return terms.as_dict()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args()

    archs = registry.ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(registry.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = f"{arch}__{shape}__{'multi' if mp else 'single'}.json"
                path = outdir / name
                if path.exists() and not args.force:
                    old = json.loads(path.read_text())
                    print(f"[cached] {name}: {old.get('status')}")
                    n_ok += old.get("status") == "OK"
                    n_skip += old.get("status") == "SKIP"
                    n_fail += old.get("status") == "FAIL"
                    continue
                try:
                    rec = run_cell(arch, shape, mp)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "FAIL", "error": str(e)[:2000]}
                rl = roofline_record(rec)
                if rl:
                    rec["roofline"] = rl
                    print(f"  roofline: compute {rl['t_compute']:.4f}s "
                          f"memory {rl['t_memory']:.4f}s "
                          f"collective {rl['t_collective']:.4f}s "
                          f"-> {rl['bottleneck']}-bound")
                path.write_text(json.dumps(rec, indent=1, default=float))
                n_ok += rec["status"] == "OK"
                n_skip += rec["status"] == "SKIP"
                n_fail += rec["status"] == "FAIL"
    print(f"\nDRY-RUN SUMMARY: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
