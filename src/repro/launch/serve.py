"""Serving launcher: BWAP-paged engine over a smoke config (CPU) —
see examples/serve_paged.py for the annotated walkthrough.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --requests 4 --new 16
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=8)
    args = ap.parse_args()

    from repro.configs import registry
    from repro.core.dwp import DWPConfig
    from repro.models.lm import LM
    from repro.serve.engine import ServeEngine
    from repro.placement.pool import BwapPagePool, MemoryDomain

    cfg = registry.get_smoke_config(args.arch)
    cfg = dataclasses.replace(cfg, num_layers=2, compute_dtype="float32")
    params = LM(cfg).init(jax.random.PRNGKey(0))
    pool = BwapPagePool(cfg, [
        MemoryDomain("hbm_local", 96, 819.0, True),
        MemoryDomain("hbm_peer", 64, 50.0, False),
        MemoryDomain("host", 128, 16.0, False),
    ], page_size=args.page_size, dwp_config=DWPConfig(n=6, c=1))
    eng = ServeEngine(cfg, params, pool, max_batch=4, max_new=args.new)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(rng.integers(1, cfg.vocab_size, 8).tolist())
    steps = 0
    while (eng.active or eng.waiting) and steps < 300:
        info = eng.step()
        steps += 1
    print(f"served {len(eng.finished)} sequences in {steps} engine steps; "
          f"final DWP {pool.tuner.dwp:.1f}; "
          f"occupancy {pool.occupancy()}")


if __name__ == "__main__":
    main()
