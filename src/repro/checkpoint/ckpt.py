"""Fault-tolerant checkpointing: atomic, hashed, mesh-independent.

Layout:  <dir>/step_00001230/
            manifest.json   — treedef, shapes/dtypes, sha256 per tensor file
            arr_<idx>.npy   — one file per leaf
         <dir>/LATEST       — atomic pointer file

Restores onto ANY mesh: leaves are stored unsharded, so an elastic restart
(different DP width after losing hosts) is a plain device_put with the new
shardings. Writes go to a temp dir + atomic rename; a crashed save never
corrupts LATEST. Optional async mode runs serialization on a worker thread.
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import hashlib
import io
import json
import os
import pathlib
import shutil
from typing import Any

import jax
import numpy as np

from repro.placement import policy as placement_policy


@dataclasses.dataclass(frozen=True)
class StagingTier:
    """A staging target for serialized checkpoint bytes (host DRAM, peer
    host over DCN, local NVMe, ...)."""

    name: str
    bw_gbps: float           # drain bandwidth from the accelerator
    capacity_bytes: int


STAGING_PAGE_BYTES = 1 << 20  # placement granularity for staging buffers


def plan_staging(leaf_bytes: list[int], tiers: list[StagingTier],
                 policy: str = "bwap_canonical", *,
                 page_bytes: int = STAGING_PAGE_BYTES) -> dict:
    """Spread serialized checkpoint buffers over staging tiers through the
    placement policy registry (the same Eq.-1 argument as weighted ZeRO:
    draining from all tiers in parallel hides the slow tier behind the fast
    one, rather than filling the fast tier first). Returns per-tier byte
    totals and the max-parallel-transfer drain-time estimate.

    ``page_bytes`` sets the placement granularity: checkpoints stage at
    ``STAGING_PAGE_BYTES``; the persistent tier's prefix/page-range exports
    reuse the same planner at KV-page granularity (``pool.page_bytes``)."""
    pages = max(1, int(-(-sum(leaf_bytes) // page_bytes)))
    ctx = placement_policy.PlacementContext(
        bandwidths=np.asarray([t.bw_gbps for t in tiers]),
        num_pages=pages, workers=(0,),
        capacities=np.asarray([t.capacity_bytes // page_bytes
                               for t in tiers]))
    counts = placement_policy.resolve(policy).counts(ctx)
    tier_bytes = counts * page_bytes
    drain = max(float(b) / (t.bw_gbps * 1e9)
                for b, t in zip(tier_bytes, tiers))
    return {
        "policy": policy,
        "page_bytes": page_bytes,
        "tiers": {t.name: int(b) for t, b in zip(tiers, tier_bytes)},
        "drain_time_s": drain,
    }


def publish_dir(tmp: pathlib.Path, final: pathlib.Path) -> None:
    """Atomic directory publish: replace ``final`` with ``tmp`` by rename.
    A crashed writer never leaves a partially-visible directory — the same
    contract ``CheckpointManager`` gives checkpoints, reused by the
    persistent tier's prefix store."""
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)


def _tree_paths(tree) -> list[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(path))
    return paths


def _sha256(b: bytes) -> str:
    return hashlib.sha256(b).hexdigest()


@dataclasses.dataclass
class CheckpointManager:
    directory: str | pathlib.Path
    keep_last: int = 3
    async_save: bool = False
    staging_tiers: list[StagingTier] | None = None
    staging_policy: str = "bwap_canonical"

    def __post_init__(self):
        self.directory = pathlib.Path(self.directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self._pool = cf.ThreadPoolExecutor(max_workers=1) \
            if self.async_save else None
        self._pending: cf.Future | None = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: Any, metadata: dict | None = None):
        """Snapshot (device->host copy) happens synchronously; file I/O is
        offloaded when async_save=True (training continues during write)."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self._pool is None:
            self._write(step, host_tree, metadata or {})
        else:
            self.wait()
            self._pending = self._pool.submit(self._write, step, host_tree,
                                              metadata or {})

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_tree, metadata: dict):
        name = f"step_{step:010d}"
        tmp = self.directory / f".tmp_{name}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, treedef = jax.tree_util.tree_flatten(host_tree)
        manifest = {
            "step": step,
            "metadata": metadata,
            "paths": _tree_paths(host_tree),
            "leaves": [],
        }
        leaf_sizes = []
        for i, leaf in enumerate(leaves):
            buf = io.BytesIO()
            np.save(buf, np.asarray(leaf), allow_pickle=False)
            raw = buf.getvalue()
            fname = f"arr_{i:05d}.npy"
            (tmp / fname).write_bytes(raw)
            leaf_sizes.append(len(raw))
            manifest["leaves"].append({
                "file": fname,
                "sha256": _sha256(raw),
                "shape": list(np.shape(leaf)),
                "dtype": str(np.asarray(leaf).dtype),
            })
        if self.staging_tiers:
            # advisory metadata: an unplaceable staging demand must never
            # abort the checkpoint itself
            try:
                manifest["staging"] = plan_staging(
                    leaf_sizes, self.staging_tiers, self.staging_policy)
            except ValueError as e:
                manifest["staging"] = {"policy": self.staging_policy,
                                       "error": str(e)}
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        publish_dir(tmp, self.directory / name)
        self._point_latest(name)
        self._gc()

    def _point_latest(self, name: str):
        ptr = self.directory / "LATEST"
        tmp = self.directory / ".LATEST.tmp"
        tmp.write_text(name)
        tmp.rename(ptr)

    def _gc(self):
        steps = sorted(self.directory.glob("step_*"))
        for old in steps[:-self.keep_last]:
            shutil.rmtree(old, ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def latest_step(self) -> int | None:
        ptr = self.directory / "LATEST"
        if not ptr.exists():
            return None
        name = ptr.read_text().strip()
        if not (self.directory / name / "manifest.json").exists():
            return None
        return int(name.split("_")[1])

    def restore(self, step: int | None = None, like: Any | None = None,
                shardings: Any | None = None, strict_hash: bool = True):
        """Returns (step, tree). ``like`` provides the treedef; ``shardings``
        (same structure) places leaves — pass shardings from a *different*
        mesh for an elastic restart."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = self.directory / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves = []
        for entry in manifest["leaves"]:
            raw = (d / entry["file"]).read_bytes()
            if strict_hash and _sha256(raw) != entry["sha256"]:
                raise IOError(f"checksum mismatch in {d / entry['file']} — "
                              "corrupt checkpoint")
            leaves.append(np.load(io.BytesIO(raw), allow_pickle=False))
        if like is None:
            raise ValueError("pass `like` (a pytree with the same structure)")
        treedef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return step, tree
