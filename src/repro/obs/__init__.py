"""Fabric observatory (DESIGN.md §10): span tracer, labeled metrics
registry, Eq.-1 drift ledger, and per-page heat map.

``metrics`` is imported eagerly — it has no ``repro`` dependencies and
``placement/telemetry.py`` builds on it. Everything else loads lazily
(PEP 562): the tracer/ledger/heat modules import placement internals, and
resolving them at package-import time would cycle back into a partially
initialized ``repro.placement.telemetry``.
"""

from repro.obs.metrics import DEFAULT_BUCKETS, MetricsRegistry

_LAZY = {
    "SpanTracer": "repro.obs.trace",
    "DriftLedger": "repro.obs.drift",
    "PageHeat": "repro.obs.heat",
    "Observatory": "repro.obs.observatory",
}

__all__ = ["MetricsRegistry", "DEFAULT_BUCKETS", "SpanTracer",
           "DriftLedger", "PageHeat", "Observatory"]


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
