"""Labeled metrics registry: counters, gauges, histograms (DESIGN.md §10).

The fabric observatory's storage layer. A :class:`MetricsRegistry` owns a
set of metric *families*; each family fans out into children keyed by a
label-value tuple (tenant, domain, priority class, tier, ...). Two export
surfaces:

- :meth:`MetricsRegistry.prometheus_text` — the Prometheus text exposition
  format (``# HELP`` / ``# TYPE`` + one sample line per child; histograms
  emit cumulative ``_bucket`` series plus ``_sum`` / ``_count``).
- :meth:`MetricsRegistry.snapshot` — a JSON-ready dict mirror of the same
  state for benchmarks and tests.

This module is deliberately dependency-free within ``repro`` (numpy only):
``placement/telemetry.py`` imports it to back its counters, so it must sit
below every other layer.
"""

from __future__ import annotations

import math
from typing import Sequence

import numpy as np

# log-spread seconds buckets: 10 µs .. 10 s covers virtual-clock latencies
DEFAULT_BUCKETS = (1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2,
                   1e-1, 3e-1, 1.0, 3.0, 10.0)


def _escape(value: str) -> str:
    return (str(value).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _labels_text(names: Sequence[str], values: Sequence,
                 extra: tuple = ()) -> str:
    pairs = [f'{n}="{_escape(v)}"' for n, v in zip(names, values)]
    pairs += [f'{n}="{_escape(v)}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def _fmt(value: float) -> str:
    if isinstance(value, bool):
        return str(int(value))
    f = float(value)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


class _Child:
    """One (family, label-values) time series."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def set(self, value: float) -> None:
        self.value = float(value)


class _HistogramChild:
    """Cumulative-bucket histogram series (Prometheus semantics)."""

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: np.ndarray):
        self.bounds = bounds                       # finite upper edges
        self.counts = np.zeros(len(bounds) + 1, dtype=np.int64)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[int(np.searchsorted(self.bounds, v, side="left"))] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q: float) -> float:
        """Linear interpolation inside the bucket holding the q-th sample
        (the classic Prometheus ``histogram_quantile`` estimate). The +Inf
        bucket clamps to the largest finite edge."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        cum = 0
        lo = 0.0
        for i, c in enumerate(self.counts):
            nxt = cum + int(c)
            if nxt >= rank and c > 0:
                hi = (self.bounds[i] if i < len(self.bounds)
                      else self.bounds[-1])
                if i >= len(self.bounds):
                    return float(hi)
                frac = (rank - cum) / c
                return float(lo + (hi - lo) * frac)
            cum = nxt
            lo = self.bounds[i] if i < len(self.bounds) else lo
        return float(self.bounds[-1])


class _Family:
    """One named metric with a fixed label schema."""

    def __init__(self, name: str, help_text: str, kind: str,
                 labelnames: Sequence[str], buckets=None):
        self.name = name
        self.help = help_text
        self.kind = kind                           # counter|gauge|histogram
        self.labelnames = tuple(labelnames)
        self.buckets = (np.asarray(buckets, dtype=np.float64)
                        if kind == "histogram" else None)
        self._children: dict[tuple, object] = {}

    def labels(self, *values):
        assert len(values) == len(self.labelnames), \
            (self.name, self.labelnames, values)
        key = tuple(str(v) for v in values)
        child = self._children.get(key)
        if child is None:
            child = (_HistogramChild(self.buckets)
                     if self.kind == "histogram" else _Child())
            self._children[key] = child
        return child

    # unlabeled convenience: families with no labels act like one child
    def inc(self, amount: float = 1.0) -> None:
        self.labels().inc(amount)

    def set(self, value: float) -> None:
        self.labels().set(value)

    def observe(self, value: float) -> None:
        self.labels().observe(value)

    def value(self, *values) -> float:
        key = tuple(str(v) for v in values)
        child = self._children.get(key)
        return float(child.value) if child is not None else 0.0

    def total(self) -> float:
        return float(sum(c.value for c in self._children.values()))

    # -- export ---------------------------------------------------------------

    def prometheus_lines(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for key, child in self._children.items():
            if self.kind == "histogram":
                cum = 0
                for i, edge in enumerate(child.bounds):
                    cum += int(child.counts[i])
                    lt = _labels_text(self.labelnames, key,
                                      (("le", _fmt(edge)),))
                    lines.append(f"{self.name}_bucket{lt} {cum}")
                lt = _labels_text(self.labelnames, key, (("le", "+Inf"),))
                lines.append(f"{self.name}_bucket{lt} {child.count}")
                lt = _labels_text(self.labelnames, key)
                lines.append(f"{self.name}_sum{lt} {_fmt(child.sum)}")
                lines.append(f"{self.name}_count{lt} {child.count}")
            else:
                lt = _labels_text(self.labelnames, key)
                lines.append(f"{self.name}{lt} {_fmt(child.value)}")
        return lines

    def snapshot(self) -> dict:
        series = []
        for key, child in self._children.items():
            row: dict = {"labels": dict(zip(self.labelnames, key))}
            if self.kind == "histogram":
                row.update(sum=child.sum, count=child.count,
                           p50=child.quantile(0.5),
                           p95=child.quantile(0.95))
            else:
                row["value"] = child.value
            series.append(row)
        return {"type": self.kind, "help": self.help,
                "label_names": list(self.labelnames), "series": series}


class MetricsRegistry:
    """Registry of metric families; registration is idempotent by name
    (re-registering returns the existing family, schema must match)."""

    def __init__(self):
        self._families: dict[str, _Family] = {}

    def _register(self, name: str, help_text: str, kind: str,
                  labelnames, buckets=None) -> _Family:
        fam = self._families.get(name)
        if fam is not None:
            assert fam.kind == kind and fam.labelnames == tuple(labelnames), \
                f"metric {name!r} re-registered with a different schema"
            return fam
        fam = _Family(name, help_text, kind, labelnames, buckets)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help_text: str = "",
                labelnames: Sequence[str] = ()) -> _Family:
        return self._register(name, help_text, "counter", labelnames)

    def gauge(self, name: str, help_text: str = "",
              labelnames: Sequence[str] = ()) -> _Family:
        return self._register(name, help_text, "gauge", labelnames)

    def histogram(self, name: str, help_text: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> _Family:
        assert all(b > a for a, b in zip(buckets, buckets[1:])), \
            "histogram buckets must be strictly increasing"
        assert all(math.isfinite(b) for b in buckets), \
            "histogram buckets must be finite (+Inf is implicit)"
        return self._register(name, help_text, "histogram", labelnames,
                              buckets)

    def get(self, name: str) -> _Family | None:
        return self._families.get(name)

    def prometheus_text(self) -> str:
        lines: list[str] = []
        for fam in self._families.values():
            lines.extend(fam.prometheus_lines())
        return "\n".join(lines) + "\n" if lines else ""

    def snapshot(self) -> dict:
        return {name: fam.snapshot()
                for name, fam in self._families.items()}
