"""The fabric observatory: one object wiring tracer + metrics + drift
ledger + heat map into a fabric (DESIGN.md §10).

Construction subscribes to every fabric event and registers the
observatory on the fabric (``fabric.attach_obs``), from where the
scheduler, engine, and swap manager find it via ``view.fabric.obs`` —
no plumbing through constructors, and a fabric without an observatory
pays one ``is None`` check per hook site.

    obs = Observatory(pool)                  # or a fabric, or a view
    ... run the engine ...
    obs.tracer.export("trace.json")          # load in ui.perfetto.dev
    print(obs.metrics.prometheus_text())
    obs.drift.summary()                      # Eq.-1 drift + calibration

``probe`` (optional) supplies *measured* transfer times for the drift
ledger: called as ``probe(kind, bytes_per_domain)`` with kind in
``repro.obs.drift.KINDS``; return a scalar (total seconds), a per-domain
vector of seconds, or None to skip. On real NUMA hardware this is where
perf counters plug in; benchmarks use it to plant ground-truth latencies.
"""

from __future__ import annotations

import numpy as np

from repro.obs.drift import DriftLedger
from repro.obs.heat import PageHeat
from repro.obs.trace import SpanTracer


def _resolve_fabric(target):
    if hasattr(target, "pool") and hasattr(target, "emit"):
        return target                          # MemoryFabric
    if hasattr(target, "fabric"):
        return target.fabric                   # FabricView
    from repro.placement.fabric import as_view
    return as_view(target).fabric              # bare BwapPagePool


class Observatory:
    def __init__(self, target, *, tracer: bool = True, heat: bool = True,
                 drift: bool = True, probe=None, calibrate_every: int = 4,
                 heat_decay: float = 0.9):
        self.fabric = _resolve_fabric(target)
        # model-group prefix on view labels (DESIGN.md §12): zoo member
        # fabrics carry fabric.group, so one Prometheus scrape over many
        # groups stays unambiguous; single-group fabrics ("" — the whole
        # PR 1-8 surface) keep their labels bit-identical
        self._group = getattr(self.fabric, "group", "")
        self.metrics = self.fabric.telemetry.metrics
        self.tracer = SpanTracer() if tracer else None
        self.heat = PageHeat(self.fabric.pool, decay=heat_decay) if heat \
            else None
        self.drift = DriftLedger(self.fabric,
                                 calibrate_every=calibrate_every) \
            if drift else None
        self.probe = probe
        self._last_now: dict[str, float] = {}
        m = self.metrics
        self._events = m.counter(
            "repro_fabric_events_total",
            "Fabric bus events seen by the observatory.", ("event",))
        self._page_events = m.counter(
            "repro_page_events_total",
            "Page alloc/free events by tenant view and domain.",
            ("event", "view", "domain"))
        self._migrations = m.counter(
            "repro_obs_migrations_total",
            "Single-page migrations seen on the bus, by view.", ("view",))
        self._shares = m.counter(
            "repro_share_events_total",
            "Cross-tenant share events by kind (prefix/loan/reclaim).",
            ("kind",))
        self._tier_ops = m.counter(
            "repro_obs_tier_pages_total",
            "Pages moved by tier ops seen on the bus.", ("op", "view"))
        self._latency_hist = m.histogram(
            "repro_step_latency_seconds",
            "Per-step latency samples by tenant view.", ("view",))
        self._requests = m.counter(
            "repro_requests_total",
            "Request lifecycle transitions by view and priority class.",
            ("event", "view", "cls"))
        self._launches = m.counter(
            "repro_decode_launches_total",
            "Decode launches by view and bottleneck domain ('global' = "
            "one unpartitioned launch).", ("view", "domain"))
        self._rehomed = m.counter(
            "repro_rehomed_pages_total",
            "Hot shared pages re-homed into fast domains, by view.",
            ("view",))
        self._export_skips = m.counter(
            "repro_tier_export_skips_total",
            "Prefix-store chains dropped over the tier's byte cap, by "
            "view (evictions land in repro_obs_tier_pages_total).",
            ("view",))
        self._link_bytes = m.counter(
            "repro_link_bytes_total",
            "Cluster interconnect traffic by view and direction.",
            ("view", "direction"))
        self._link_chunks = m.counter(
            "repro_link_chunks_total",
            "Chunked wire sends on the cluster interconnect, by view.",
            ("view",))
        self._heat_gauge = m.gauge(
            "repro_page_heat",
            "Resolved per-page heat stats by domain "
            "(stat in pages/mean/p50/p95/max).", ("domain", "stat"))
        self._engine_steps = 0
        for ev in self.fabric._subs:
            self.fabric.subscribe(ev, self._bus_handler(ev))
        self.fabric.attach_obs(self)

    def _vlabel(self, view) -> str:
        name = view if isinstance(view, str) else \
            getattr(view, "name", str(view))
        return f"{self._group}/{name}" if self._group else (name or "")

    # -- virtual clock --------------------------------------------------------

    def _note_now(self, view: str, now: float) -> None:
        self._last_now[view] = float(now)

    def _now(self, view: str | None) -> float:
        if view in self._last_now:
            return self._last_now[view]
        return max(self._last_now.values(), default=0.0)

    # -- fabric event bus -----------------------------------------------------

    def _bus_handler(self, event: str):
        def handle(**kw):
            self._events.labels(event).inc()
            view = kw.get("view")
            if event in ("alloc", "free"):
                dom = self.fabric.pool.domains[kw["domain"]].name
                self._page_events.labels(event, self._vlabel(view or ""),
                                         dom).inc()
                if event == "free" and self.heat is not None:
                    self.heat.on_free(page=kw["page"])
            elif event == "migrate":
                self._migrations.labels(self._vlabel(view)).inc()
            elif event == "share":
                self._shares.labels(kw["kind"]).inc()
            elif event == "latency":
                self._latency_hist.labels(
                    self._vlabel(view)).observe(kw["seconds"])
            elif event in ("demote", "promote", "restore", "evict"):
                self._tier_ops.labels(event, self._vlabel(view)).inc(
                    kw["pages"])
                if self.tracer is not None:
                    self.tracer.on_fabric(
                        event, view, self._now(view),
                        dur_s=kw.get("seconds", 0.0),
                        args={"pages": kw["pages"]})
            elif event == "export_skip":
                self._export_skips.labels(self._vlabel(view)).inc(
                    kw["chains"])
                if self.tracer is not None:
                    self.tracer.on_fabric(
                        event, view, self._now(view),
                        args={"pages": kw["pages"],
                              "chains": kw["chains"]})
            elif event in ("link_send", "link_recv"):
                direction = "send" if event == "link_send" else "recv"
                self._link_bytes.labels(self._vlabel(view),
                                        direction).inc(kw["bytes"])
                if event == "link_send":
                    self._link_chunks.labels(self._vlabel(view)).inc(
                        kw["chunks"])
                if self.tracer is not None:
                    self.tracer.on_fabric(
                        event, view, self._now(view),
                        dur_s=kw.get("seconds", 0.0),
                        args={k: kw[k] for k in ("pages", "bytes",
                                                 "chunks") if k in kw})
        return handle

    # -- scheduler lifecycle hooks -------------------------------------------

    def on_admit(self, view, r, now: float) -> None:
        self._note_now(view.name, now)
        self._requests.labels("admit", self._vlabel(view), r.cls).inc()
        if self.tracer is not None:
            self.tracer.on_admit(view.name, r.sid, r.arrival_s, r.cls)

    def on_preempt(self, view, r, now: float, seconds: float,
                   pages: int) -> None:
        self._note_now(view.name, now)
        self._requests.labels("preempt", self._vlabel(view), r.cls).inc()
        if self.tracer is not None:
            self.tracer.on_swap_out(view.name, r.sid, now, seconds, pages)

    def on_resume(self, view, r, now: float, seconds: float) -> None:
        self._note_now(view.name, now)
        self._requests.labels("resume", self._vlabel(view), r.cls).inc()
        if self.tracer is not None:
            self.tracer.on_swap_in(view.name, r.sid, now, seconds)

    def on_finish(self, view, r, now: float) -> None:
        self._note_now(view.name, now)
        self._requests.labels("finish", self._vlabel(view), r.cls).inc()
        if self.tracer is not None:
            self.tracer.on_finish(view.name, r.sid, now, r.produced)

    # -- engine step hook -----------------------------------------------------

    def on_engine_step(self, view, plan, batch, read_pages,
                       predicted_s: float, t0: float, dt: float,
                       launches=None, read_weights=None) -> None:
        """One engine step just advanced the clock from ``t0`` by ``dt``:
        trace spans for its prefill chunks and decode batch, touch heat,
        and (with a probe) feed the drift ledger the batch-read pair(s).

        ``launches`` (micro-batch mode, DESIGN.md §11) is a list of
        ``(domain, launch_read_pages, launch_predicted_s)`` — each launch
        touches heat and bills drift *separately*, so a launch's
        bottleneck time is never credited to domains it did not read.
        ``read_weights`` maps pid -> fraction of the page the gather
        streamed (bytes-weighted heat; a partial tail page is cooler than
        a full interior page)."""
        self._note_now(view.name, t0 + dt)
        self._engine_steps += 1
        rw = read_weights or {}
        if self.heat is not None:
            for pages in ([rp for _, rp, _ in launches]
                          if launches is not None else [read_pages]):
                if pages:
                    self.heat.touch(
                        pages, weights=[rw.get(p, 1.0) for p in pages])
            self.heat.step()
            # periodic Prometheus refresh of the heat histograms — every
            # step would put an O(live pages) scan on the hot path
            if self._engine_steps % 16 == 0:
                self.refresh_heat_gauges()
        if batch:
            if launches is not None:
                for dom, _rp, _t in launches:
                    self._launches.labels(
                        self._vlabel(view),
                        self.fabric.pool.domains[dom].name).inc()
            else:
                self._launches.labels(self._vlabel(view), "global").inc()
        if self.tracer is not None:
            for seq, lo, hi in plan.prefill_chunks:
                self.tracer.on_prefill(view.name, seq.sid, t0, dt, lo, hi)
            for seq in batch:
                self.tracer.on_decode(view.name, seq.sid, t0, dt,
                                      seq.produced)
        if self.drift is not None and self.probe is not None and batch:
            if launches is not None:
                self.drift.observe_launches(
                    "batch_read",
                    [(view.footprint(rp), t) for _, rp, t in launches],
                    self.probe)
            else:
                bpd = view.footprint(read_pages)
                measured = self.probe("batch_read", bpd)
                if measured is not None:
                    self.drift.observe("batch_read", bpd, predicted_s,
                                       measured)

    def on_rehome(self, view, now: float, seconds: float,
                  pages: int) -> None:
        """The engine re-homed ``pages`` hot shared pages (DESIGN.md §11):
        count them and put the migration span on the fabric track."""
        self._note_now(view.name, now + seconds)
        self._rehomed.labels(self._vlabel(view)).inc(pages)
        if self.tracer is not None:
            self.tracer.on_fabric("rehome", view.name, now,
                                  dur_s=seconds, args={"pages": pages})

    # -- swap transfer hook ---------------------------------------------------

    def observe_transfer(self, bytes_per_domain,
                         predicted_s: float) -> None:
        if self.drift is None or self.probe is None:
            return
        bpd = np.asarray(bytes_per_domain, dtype=np.float64)
        if not bpd.any():
            return
        measured = self.probe("swap_transfer", bpd)
        if measured is not None:
            self.drift.observe("swap_transfer", bpd, predicted_s, measured)

    # -- reporting ------------------------------------------------------------

    def refresh_heat_gauges(self) -> None:
        """Fold the heat map's per-domain histograms into the labeled
        ``repro_page_heat`` gauges (Prometheus text export)."""
        if self.heat is None:
            return
        for dom, row in self.heat.per_domain().items():
            for stat, val in row.items():
                self._heat_gauge.labels(dom, stat).set(float(val))

    def snapshot(self) -> dict:
        self.refresh_heat_gauges()
        out = {"metrics": self.metrics.snapshot()}
        if self.drift is not None:
            out["drift"] = self.drift.summary()
        if self.heat is not None:
            out["heat"] = self.heat.snapshot()
        if self.tracer is not None:
            out["trace_events"] = len(self.tracer.events)
        return out
