"""Per-request span tracer on the virtual clock (DESIGN.md §10).

Records the request lifecycle — admit, queued wait, prefill chunks,
decode steps, preempt/swap-out/swap-in, tier demote/promote/restore,
finish — as Chrome/Perfetto trace events. Export with
:meth:`SpanTracer.export` and load the JSON in ``ui.perfetto.dev`` (or
``chrome://tracing``): one process row per fabric view (tenant), one
thread row per request, plus a ``fabric`` thread carrying migration and
tier activity.

Timestamps are the scheduler's *virtual* seconds converted to trace
microseconds, so a trace from a ``wall_clock=False`` run is byte-stable
across machines.
"""

from __future__ import annotations

import json
import pathlib

_FABRIC_TID = 0          # per-view bus track; request tids are sid + 1


class SpanTracer:
    """Accumulates Chrome trace events ("X" spans, "i" instants)."""

    def __init__(self):
        self.events: list[dict] = []
        self._pids: dict[str, int] = {}
        self._named_tids: dict[tuple[int, int], str] = {}
        self._admitted: dict[tuple[int, int], float] = {}

    # -- track bookkeeping ----------------------------------------------------

    def _pid(self, view: str) -> int:
        pid = self._pids.get(view)
        if pid is None:
            pid = len(self._pids) + 1
            self._pids[view] = pid
            self.events.append({"ph": "M", "name": "process_name",
                                "pid": pid, "tid": 0,
                                "args": {"name": f"view:{view}"}})
            self._name_tid(pid, _FABRIC_TID, "fabric")
        return pid

    def _name_tid(self, pid: int, tid: int, name: str) -> None:
        if (pid, tid) not in self._named_tids:
            self._named_tids[(pid, tid)] = name
            self.events.append({"ph": "M", "name": "thread_name",
                                "pid": pid, "tid": tid,
                                "args": {"name": name}})

    def _req_tid(self, pid: int, sid: int) -> int:
        tid = int(sid) + 1
        self._name_tid(pid, tid, f"req {sid}")
        return tid

    # -- low-level emitters ---------------------------------------------------

    def span(self, name: str, view: str, tid: int, ts_s: float,
             dur_s: float, args: dict | None = None) -> None:
        ev = {"ph": "X", "name": name, "cat": "repro",
              "pid": self._pid(view), "tid": tid,
              "ts": ts_s * 1e6, "dur": max(dur_s, 0.0) * 1e6}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, view: str, tid: int, ts_s: float,
                args: dict | None = None) -> None:
        ev = {"ph": "i", "name": name, "cat": "repro", "s": "t",
              "pid": self._pid(view), "tid": tid, "ts": ts_s * 1e6}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # -- request lifecycle (driven by the Observatory) ------------------------

    def on_admit(self, view: str, sid: int, ts_s: float, cls: str) -> None:
        pid = self._pid(view)
        tid = self._req_tid(pid, sid)
        self._admitted[(pid, tid)] = ts_s
        self.instant("admit", view, tid, ts_s, {"cls": cls})

    def _close_queued(self, view: str, tid: int, ts_s: float) -> None:
        """First unit of work for a request ends its queued wait."""
        t0 = self._admitted.pop((self._pid(view), tid), None)
        if t0 is not None and ts_s > t0:
            self.span("queued", view, tid, t0, ts_s - t0)

    def on_prefill(self, view: str, sid: int, ts_s: float, dur_s: float,
                   lo: int, hi: int) -> None:
        tid = self._req_tid(self._pid(view), sid)
        self._close_queued(view, tid, ts_s)
        self.span("prefill", view, tid, ts_s, dur_s,
                  {"lo": lo, "hi": hi, "tokens": hi - lo})

    def on_decode(self, view: str, sid: int, ts_s: float, dur_s: float,
                  produced: int) -> None:
        tid = self._req_tid(self._pid(view), sid)
        self._close_queued(view, tid, ts_s)
        self.span("decode", view, tid, ts_s, dur_s,
                  {"produced": produced})

    def on_swap_out(self, view: str, sid: int, ts_s: float, dur_s: float,
                    pages: int) -> None:
        tid = self._req_tid(self._pid(view), sid)
        self.span("swap_out", view, tid, ts_s, dur_s, {"pages": pages})

    def on_swap_in(self, view: str, sid: int, ts_s: float,
                   dur_s: float) -> None:
        tid = self._req_tid(self._pid(view), sid)
        self.span("swap_in", view, tid, ts_s, dur_s)

    def on_finish(self, view: str, sid: int, ts_s: float,
                  produced: int) -> None:
        tid = self._req_tid(self._pid(view), sid)
        self.instant("finish", view, tid, ts_s, {"produced": produced})

    # -- fabric bus activity (migrations, tier moves, shares) -----------------

    def on_fabric(self, name: str, view: str, ts_s: float,
                  dur_s: float = 0.0, args: dict | None = None) -> None:
        view = view or "fabric"
        if dur_s > 0.0:
            self.span(name, view, _FABRIC_TID, ts_s, dur_s, args)
        else:
            self.instant(name, view, _FABRIC_TID, ts_s, args)

    # -- export ---------------------------------------------------------------

    def spans(self, name: str | None = None,
              sid: int | None = None) -> list[dict]:
        """Query helper for tests: "X"/"i" events by name and request."""
        out = []
        for ev in self.events:
            if ev["ph"] not in ("X", "i"):
                continue
            if name is not None and ev["name"] != name:
                continue
            if sid is not None and ev["tid"] != sid + 1:
                continue
            out.append(ev)
        return out

    def to_dict(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def export(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict()) + "\n")
        return path
