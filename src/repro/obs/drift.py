"""Eq.-1 drift ledger: predictions vs measurements, feeding calibration
(DESIGN.md §10).

Every Eq.-1 prediction the stack makes — batch KV read time, swap
transfer time, persistent-tier copy — can be paired with a measured time
(wall clock on real hardware, or a ground-truth probe in benchmarks).
The ledger:

- keeps per-kind measured/predicted *ratio* rings with p50/p95 (via
  ``Ring.quantile``) — the drift histograms;
- keeps a per-domain EWMA drift factor (ratio of measured to predicted
  per-domain transfer rate);
- stages per-domain seconds-per-page samples and periodically calls
  ``fabric.calibrate()`` with their means — closing the loop that ROADMAP
  flagged ("calibrate exists but nothing feeds it").

Measurement attribution: Eq. 1 is a max-parallel-transfer model, so a
*scalar* measurement only constrains the bottleneck domain (the argmax of
predicted per-domain time); a per-domain *vector* measurement (e.g. a
hardware counter per NUMA node, or a benchmark probe) constrains every
domain it covers.
"""

from __future__ import annotations

import numpy as np

from repro.placement.telemetry import Ring

KINDS = ("batch_read", "swap_transfer", "tier_copy", "link_transfer")


class DriftLedger:
    def __init__(self, fabric, *, calibrate_every: int = 4,
                 drift_alpha: float = 0.25, ring_capacity: int = 256):
        self.fabric = fabric
        self.calibrate_every = int(calibrate_every)
        self.drift_alpha = float(drift_alpha)
        nd = len(fabric.pool.domains)
        # measured/predicted per-domain rate ratio, EWMA (1.0 = no drift)
        self.domain_drift = np.ones(nd, dtype=np.float64)
        self.domain_samples = np.zeros(nd, dtype=np.int64)
        self.ratio: dict[str, Ring] = {k: Ring(ring_capacity) for k in KINDS}
        self._staged: list[list[float]] = [[] for _ in range(nd)]
        self.observations = 0
        self.calibrations = 0

    # -- observation ----------------------------------------------------------

    def observe(self, kind: str, bytes_per_domain, predicted_s: float,
                measured) -> None:
        """Pair one Eq.-1 prediction with its measurement.

        ``measured`` is either a scalar (total seconds; attributed to the
        bottleneck domain) or a per-domain vector of seconds (every
        trafficked domain gets a calibration sample)."""
        assert kind in KINDS, kind
        bpd = np.asarray(bytes_per_domain, dtype=np.float64)
        pb = float(self.fabric.pool.page_bytes)
        m = np.asarray(measured, dtype=np.float64)
        if m.ndim == 0:                       # scalar: bottleneck domain
            per_dom_pred = bpd / (self.fabric.bw_effective * 1e9)
            d = int(np.argmax(per_dom_pred))
            doms = [d] if bpd[d] > 0 and float(m) > 0 else []
            per_dom_meas = {d: float(m)}
            measured_total = float(m)
        else:                                 # vector: all trafficked
            assert m.shape == bpd.shape, (m.shape, bpd.shape)
            doms = [d for d in range(len(bpd))
                    if bpd[d] > 0 and m[d] > 0]
            per_dom_meas = {d: float(m[d]) for d in doms}
            measured_total = float(m.max()) if len(m) else 0.0
        if predicted_s > 0 and measured_total > 0:
            self.ratio[kind].push(measured_total / predicted_s)
        for d in doms:
            # seconds per page in domain d under this measurement
            s_page = per_dom_meas[d] * pb / bpd[d]
            self._staged[d].append(s_page)
            self.domain_samples[d] += 1
            pred_d = bpd[d] / (self.fabric.bw_effective[d] * 1e9)
            if pred_d > 0:
                r = per_dom_meas[d] / pred_d
                a = self.drift_alpha
                self.domain_drift[d] = ((1 - a) * self.domain_drift[d]
                                        + a * r)
        self.observations += 1
        if self.observations % self.calibrate_every == 0:
            self.flush()

    def observe_launches(self, kind: str, launches, probe) -> int:
        """Bill a multi-launch (micro-batched) step one launch at a time.

        A compute-follows-data step issues several launches, each reading
        only its own domain-partitioned page set; attributing the *step's*
        measurement to the *global* byte vector would credit every launch's
        bottleneck time to domains it never touched, and calibration would
        drag their ``bw_effective`` toward fiction. Instead each launch is
        its own observation: ``launches`` is an iterable of
        ``(bytes_per_domain, predicted_s)`` and ``probe(kind, bpd)``
        measures that launch alone (scalar or per-domain vector; ``None``
        skips). Returns the number of observations recorded — a launch
        reading zero bytes bills nobody."""
        n = 0
        for bpd, predicted_s in launches:
            bpd = np.asarray(bpd, dtype=np.float64)
            if bpd.sum() <= 0:
                continue
            measured = probe(kind, bpd)
            if measured is None:
                continue
            self.observe(kind, bpd, predicted_s, measured)
            n += 1
        return n

    def observe_scalar(self, kind: str, predicted_s: float,
                       measured_s: float) -> None:
        """Ratio-only observation for costs outside the per-domain model
        (e.g. the persistent tier's single bandwidth row)."""
        assert kind in KINDS, kind
        if predicted_s > 0 and measured_s > 0:
            self.ratio[kind].push(measured_s / predicted_s)
        self.observations += 1

    # -- calibration ----------------------------------------------------------

    def flush(self) -> bool:
        """Fold staged per-domain samples into ``fabric.calibrate``;
        domains with no samples stay untouched. Returns True if a
        calibration happened."""
        samples = [float(np.mean(s)) if s else None for s in self._staged]
        if all(s is None for s in samples):
            return False
        self.fabric.calibrate(samples)
        self.calibrations += 1
        self._staged = [[] for _ in self._staged]
        return True

    # -- reporting ------------------------------------------------------------

    def summary(self) -> dict:
        return {
            "observations": self.observations,
            "calibrations": self.calibrations,
            "bw_effective_gbps": [float(b)
                                  for b in self.fabric.bw_effective],
            "domain_drift": [float(d) for d in self.domain_drift],
            "domain_samples": [int(n) for n in self.domain_samples],
            "kinds": {
                k: {"count": len(r), "ratio_p50": r.quantile(0.5),
                    "ratio_p95": r.quantile(0.95)}
                for k, r in self.ratio.items()
            },
        }
