"""Per-page heat counters with per-step decay (DESIGN.md §10).

Input signal for future migration policy (ROADMAP compute-follows-data):
every decode step *touches* the pages the batch read; heat decays
geometrically per step so stale pages cool off. Decay is lazy — each page
stores ``(value, last_step)`` and resolves ``value * decay**(step -
last_step)`` on access — so a step is O(pages touched), not O(live pages).

Freed pages drop out via the fabric's ``free`` event (the Observatory
subscribes :meth:`on_free`).
"""

from __future__ import annotations

import numpy as np


class PageHeat:
    def __init__(self, pool, *, decay: float = 0.9):
        assert 0.0 < decay <= 1.0
        self.pool = pool
        self.decay = float(decay)
        self._heat: dict[int, float] = {}
        self._stamp: dict[int, int] = {}
        self.step_count = 0
        self.touches = 0

    # -- hot path -------------------------------------------------------------

    def touch(self, pages, weight: float = 1.0, *, weights=None) -> None:
        """Record one read of ``pages``.

        ``weights`` (parallel to ``pages``) scales each page's increment by
        the fraction of the page actually read — a sequence's partial last
        page streams fewer bytes than an interior page and must not look
        equally hot to the re-homing policy. Omitted, every page counts
        ``weight`` (a full-page read).
        """
        ws = weights if weights is not None else (weight for _ in pages)
        for p, w in zip(pages, ws):
            p = int(p)
            if p < 0:                # persisted handle: not a live page
                continue
            self._heat[p] = self._resolve(p) + float(w)
            self._stamp[p] = self.step_count
            self.touches += 1

    def step(self) -> None:
        self.step_count += 1

    def _resolve(self, p: int) -> float:
        h = self._heat.get(p)
        if h is None:
            return 0.0
        age = self.step_count - self._stamp[p]
        return h * self.decay ** age if age else h

    def on_free(self, page: int = -1, **_) -> None:
        self._heat.pop(int(page), None)
        self._stamp.pop(int(page), None)

    # -- reporting ------------------------------------------------------------

    def value(self, page: int) -> float:
        return self._resolve(int(page))

    def live_pages(self) -> int:
        return len(self._heat)

    def hottest(self, n: int = 10) -> list[tuple[int, float]]:
        items = [(p, self._resolve(p)) for p in self._heat]
        items.sort(key=lambda pv: (-pv[1], pv[0]))
        return items[:n]

    def per_domain(self) -> dict[str, dict]:
        """Per-domain heat histograms: count / mean / p50 / p95 / max of
        the resolved heat of live pages resident in each domain."""
        by_dom: dict[int, list[float]] = {}
        for p in self._heat:
            by_dom.setdefault(self.pool.domain_of(p), []).append(
                self._resolve(p))
        out = {}
        for i, d in enumerate(self.pool.domains):
            vals = np.asarray(by_dom.get(i, []), dtype=np.float64)
            out[d.name] = {
                "pages": int(vals.size),
                "mean": float(vals.mean()) if vals.size else 0.0,
                "p50": float(np.quantile(vals, 0.5)) if vals.size else 0.0,
                "p95": float(np.quantile(vals, 0.95)) if vals.size else 0.0,
                "max": float(vals.max()) if vals.size else 0.0,
            }
        return out

    def snapshot(self) -> dict:
        return {"step": self.step_count, "live_pages": self.live_pages(),
                "touches": self.touches, "per_domain": self.per_domain()}
