"""Roofline terms from compiled dry-run artifacts.

compute   = HLO_FLOPs / (chips x 197e12)
memory    = HLO_bytes / (chips x 819e9)
collective= per-op bytes moved on the busiest link / link bandwidth, summed —
            parsed from the optimized HLO text (cost_analysis has no
            collective view). Ops whose replica groups cross pods are costed
            at DCI bandwidth, intra-pod ops at ICI bandwidth.

Scan-body correction: XLA's cost analysis counts a `while` body ONCE, so the
driver lowers each scan body separately (models expose them as Fragments)
and this module combines: total = full + sum_f extra_trips_f * frag_f.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Iterable

import numpy as np

V5E_PEAK_FLOPS = 197e12      # bf16 / chip
V5E_HBM_BW = 819e9           # B/s per chip
V5E_ICI_BW = 50e9            # B/s per link per direction (3D-torus: 2 links/axis usable)
V5E_DCI_BW = 12.5e9          # B/s effective per chip across pods
CHIPS_PER_POD = 256

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^=]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}|replica_groups=\[")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{(.*?)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    crosses_pod: bool
    count: int = 1

    def per_chip_link_bytes(self) -> float:
        """Bytes crossing the busiest link per chip (ring algorithms)."""
        n = max(self.group_size, 1)
        b = self.result_bytes
        if self.kind == "all-reduce":
            # in-place: result==operand size; ring moves 2(n-1)/n x size
            return 2.0 * b * (n - 1) / n
        if self.kind == "all-gather":
            # result is the gathered size; each chip receives (n-1)/n of it
            return b * (n - 1) / n
        if self.kind == "reduce-scatter":
            # result is the scattered shard; (n-1) shards pass per chip
            return b * (n - 1)
        if self.kind == "all-to-all":
            return b * (n - 1) / n
        if self.kind == "collective-permute":
            return float(b)
        return float(b)


def parse_collectives(hlo_text: str,
                      chips_per_pod: int = CHIPS_PER_POD
                      ) -> list[CollectiveOp]:
    """Extract collective ops (with result bytes and replica-group reach)
    from optimized HLO text. `-start` variants are counted once ( `-done`
    carries no shape of its own in post-optimization HLO dumps)."""
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLLECTIVE_RE.search(line)
        if not m or "-done" in line.split("=")[0]:
            continue
        shape_str, kind = m.groups()
        rb = _shape_bytes(shape_str)
        if rb == 0:
            continue
        group_size, crosses = _replica_group_info(line, chips_per_pod)
        ops.append(CollectiveOp(kind=kind, result_bytes=rb,
                                group_size=group_size, crosses_pod=crosses))
    return ops


def _replica_group_info(line: str, chips_per_pod: int) -> tuple[int, bool]:
    # iota-style groups: replica_groups=[16,16]<=[256] or <=[16,2,8]{1,0,2}
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]"
                  r"(?:\{([\d,]+)\})?", line)
    if m:
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(4).split(",")] if m.group(4)
                else list(range(len(dims))))
        total = int(np.prod(dims))
        crosses = False
        if total > chips_per_pod and gsize > 1:
            ids = np.arange(total).reshape(dims).transpose(perm).reshape(
                ngroups, gsize)
            pods = ids // chips_per_pod
            crosses = bool((pods != pods[:, :1]).any())
        return gsize, crosses
    # explicit groups: replica_groups={{0,1,2},{3,4,5}}
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        first = [int(x) for x in m.group(1).split(",") if x.strip()]
        gsize = max(len(first), 1)
        crosses = len({d // chips_per_pod for d in first}) > 1
        return gsize, crosses
    # collective-permute
    m = _SRC_TGT_RE.search(line)
    if m:
        pairs = re.findall(r"\{(\d+),(\d+)\}", "{" + m.group(1) + "}")
        crosses = any(int(a) // chips_per_pod != int(b) // chips_per_pod
                      for a, b in pairs)
        return 2, crosses
    return 1, False


@dataclasses.dataclass
class RooflineTerms:
    """flops / bytes are PER-CHIP (XLA SPMD cost analysis reports the
    per-device partitioned module — verified empirically), so
    flops_per_chip / peak == HLO_FLOPs_global / (chips x peak)."""

    flops: float                # per-chip
    bytes_hbm: float            # per-chip
    coll_ici_bytes: float       # per-chip busiest-link bytes, intra-pod ops
    coll_dci_bytes: float       # per-chip bytes crossing pods
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / V5E_PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / V5E_HBM_BW

    @property
    def t_collective(self) -> float:
        return (self.coll_ici_bytes / V5E_ICI_BW
                + self.coll_dci_bytes / V5E_DCI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes_hbm": self.bytes_hbm,
            "coll_ici_bytes": self.coll_ici_bytes,
            "coll_dci_bytes": self.coll_dci_bytes, "chips": self.chips,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
        }


def terms_from_parts(parts: Iterable[dict], chips: int) -> RooflineTerms:
    """Combine (cost_analysis, collectives, multiplier) parts.

    Each part: {"flops": F, "bytes": B, "collectives": [CollectiveOp],
    "mult": k}. flops/bytes come from the per-device SPMD module;
    multipliers implement the scan-body trip-count correction.
    """
    flops = bytes_hbm = ici = dci = 0.0
    for p in parts:
        k = p.get("mult", 1)
        flops += k * p.get("flops", 0.0)
        bytes_hbm += k * p.get("bytes", 0.0)
        for op in p.get("collectives", []):
            moved = op.per_chip_link_bytes()
            if op.crosses_pod:
                dci += k * moved
            else:
                ici += k * moved
    return RooflineTerms(flops=flops, bytes_hbm=bytes_hbm,
                         coll_ici_bytes=ici, coll_dci_bytes=dci, chips=chips)
