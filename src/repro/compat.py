"""Version-compat shims for the pinned jax (0.4.37).

Two API gaps bite on the pinned environment:

- ``jax.sharding.AxisType`` (and ``jax.make_mesh(..., axis_types=...)``)
  only exist from jax 0.5; meshes on 0.4.x take no axis types.
- top-level ``jax.shard_map`` (with the ``check_vma`` kwarg) replaced
  ``jax.experimental.shard_map.shard_map`` (``check_rep``) in 0.6.

Everything that builds meshes or shard_maps goes through here so the rest
of the tree is version-oblivious.
"""

from __future__ import annotations

from typing import Sequence

import jax

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _AXIS_TYPE is None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                         axis_types=(_AXIS_TYPE.Auto,) * len(axis_names))


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict: 0.4.x returns a
    one-element list of per-computation dicts, newer jax the dict itself."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def pallas_tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` across jax versions (0.4.x names it
    ``TPUCompilerParams``); kwargs — e.g. ``dimension_semantics`` — are
    identical on both."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Per-shard mapping across jax versions.

    ``check`` maps onto ``check_vma`` (new API) / ``check_rep`` (old API);
    callers in this repo always disable it (collectives are hand-checked).
    """
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    import inspect
    params = inspect.signature(sm).parameters
    kw = "check_vma" if "check_vma" in params else "check_rep"
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{kw: check})
