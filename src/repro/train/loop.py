"""Training loop with the large-scale runnability substrate:

- checkpoint/restart (atomic, hashed — checkpoint/ckpt.py), resume from
  LATEST after any crash;
- elastic restart: restore onto a different mesh (fewer data shards after
  losing hosts) — checkpoints are mesh-independent, so this is a re-shard
  at load;
- straggler mitigation: per-step wall-time EWMA; slow data hosts get their
  shards re-weighted away (data/pipeline.py BwapDataRouter — the DWP pattern
  on the input plane);
- optional int8 error-feedback gradient compression (train/compress.py);
- failure injection hooks for tests (fail_at_step).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import BwapDataRouter, ShardedTokenDataset
from repro.train import optimizer as opt_mod
from repro.train.trainstep import make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    keep_last: int = 3
    log_every: int = 10
    straggler_ewma: float = 0.3
    straggler_factor: float = 2.0
    fail_at_step: int = -1          # test hook: raise at this step


class SimulatedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(self, model, opt_cfg: opt_mod.OptConfig, loop: LoopConfig,
                 ckpt_dir: str, batch_fn: Callable[[int], dict],
                 mesh=None, shardings=None, accum: int = 1):
        """batch_fn(step) -> batch dict (the data pipeline boundary).
        shardings: optional (params, opt_state, batch) NamedSharding trees;
        passing a different mesh's shardings after restore = elastic."""
        self.model = model
        self.opt_cfg = opt_cfg
        self.loop = loop
        self.ckpt = CheckpointManager(ckpt_dir, keep_last=loop.keep_last)
        self.batch_fn = batch_fn
        self.mesh = mesh
        self.shardings = shardings
        step_fn = make_train_step(model, opt_cfg, accum_steps=accum)
        if shardings is not None:
            self.jstep = jax.jit(step_fn,
                                 in_shardings=shardings,
                                 donate_argnums=(0, 1))
        else:
            self.jstep = jax.jit(step_fn, donate_argnums=(0, 1))
        self.step_times: list[float] = []

    # -- state --------------------------------------------------------------

    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        opt_state = opt_mod.init_opt_state(self.opt_cfg, params)
        return 0, params, opt_state

    def restore_or_init(self, seed: int = 0):
        latest = self.ckpt.latest_step()
        if latest is None:
            return self.init_state(seed)
        _, params, opt_state = self.init_state(seed)
        step, tree = self.ckpt.restore(
            latest, like={"params": params, "opt": opt_state},
            shardings=None)
        return step, tree["params"], tree["opt"]

    # -- loop ---------------------------------------------------------------

    def run(self, start=None):
        step, params, opt_state = start or self.restore_or_init()
        metrics = {}
        while step < self.loop.total_steps:
            if step == self.loop.fail_at_step:
                raise SimulatedFailure(f"injected failure at step {step}")
            t0 = time.monotonic()
            batch = self.batch_fn(step)
            params, opt_state, metrics = self.jstep(params, opt_state,
                                                    batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            self.step_times.append(dt)
            step += 1
            if step % self.loop.ckpt_every == 0 \
                    or step == self.loop.total_steps:
                self.ckpt.save(step, {"params": params, "opt": opt_state},
                               metadata={"loss": float(metrics["loss"])})
            if step % self.loop.log_every == 0:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"{dt * 1e3:.0f} ms/step")
        return step, params, opt_state, metrics
