"""AdamW from scratch (no optax), with optional 8-bit block-quantized moments
and fp32 master params for bf16 models.

State layout is flat pytrees mirroring the params, so ZeRO-1 shardings from
sharding/specs.py apply leaf-by-leaf. 8-bit moments (deepseek-671b: bf16
params would not fit fp32 Adam in a single v5e pod — DESIGN.md §6) use
block-wise absmax scaling over trailing 256-element blocks.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized_moments: bool = False    # int8 block-quantized m/v
    master_fp32: bool = True           # fp32 master copy for bf16 params
    block: int = 256
    warmup_steps: int = 100
    schedule: str = "cosine"           # constant | cosine
    total_steps: int = 10_000


# -- 8-bit block quantization --------------------------------------------------

def _pad_to_block(x, block):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    return jnp.pad(flat, (0, pad)), pad


def quantize_q8(x, block: int):
    """Block-quantize along the LAST dim, preserving leading dims — so the
    int8 state inherits the parameter's sharding (a flat-blocks layout
    forces GSPMD to replicate the dequantized view: 812 GiB/op measured on
    deepseek's [58,256,7168,2048] expert moments). Falls back to flat
    blocks for tensors whose last dim doesn't divide."""
    if x.ndim >= 1 and x.shape[-1] % block == 0 and x.shape[-1] > 0:
        blocks = x.reshape(*x.shape[:-1], x.shape[-1] // block, block)
        scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
        scale = jnp.where(scale == 0, 1.0, scale)
        q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale[..., 0].astype(jnp.float32)}
    flat, _ = _pad_to_block(x, block)
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)[:, 0]}


def dequantize_q8(qs, shape):
    q, scale = qs["q"], qs["scale"]
    aligned = (q.ndim == len(shape) + 1
               and tuple(q.shape[:len(shape) - 1]) == tuple(shape[:-1])
               and q.shape[-2] * q.shape[-1] == shape[-1])
    if aligned:                       # sharding-aligned layout
        vals = q.astype(jnp.float32) * scale[..., None]
        return vals.reshape(shape)
    vals = q.astype(jnp.float32) * scale[:, None]
    return vals.reshape(-1)[:int(np.prod(shape))].reshape(shape)


# -- state ----------------------------------------------------------------------

def init_opt_state(cfg: OptConfig, params) -> dict:
    def zeros_like_moment(p):
        if cfg.quantized_moments:
            z = jnp.zeros(p.shape, jnp.float32)
            return quantize_q8(z, cfg.block)
        return jnp.zeros(p.shape, jnp.float32)

    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_moment, params),
        "v": jax.tree.map(zeros_like_moment, params),
    }
    # fp32 master copy for low-precision params (671B-scale models skip it:
    # bf16 update + int8 moments is the only layout that fits one pod)
    if cfg.master_fp32 and any(p.dtype != jnp.float32
                               for p in jax.tree.leaves(params)):
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def opt_state_specs(cfg: OptConfig, param_specs) -> dict:
    return jax.eval_shape(functools.partial(init_opt_state, cfg),
                          param_specs)


def _lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    else:
        decay = 1.0
    return cfg.lr * warm * (0.1 + 0.9 * decay)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = _lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    masters = state.get("master", params)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        if cfg.quantized_moments:
            m_f = dequantize_q8(m, p.shape)
            v_f = dequantize_q8(v, p.shape)
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
        upd_ = (m_f / b1c) / (jnp.sqrt(v_f / b2c) + cfg.eps)
        master = master.astype(jnp.float32)
        master = master - lr * (upd_ + cfg.weight_decay * master)
        if cfg.quantized_moments:
            m_o, v_o = quantize_q8(m_f, cfg.block), quantize_q8(v_f, cfg.block)
        else:
            m_o, v_o = m_f, v_f
        return master.astype(p.dtype), m_o, v_o, master

    out = jax.tree.map(upd, params, grads, state["m"], state["v"], masters,
                       is_leaf=lambda x: isinstance(x, jnp.ndarray))
    # tree of tuples -> tuple of trees
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"step": step, "m": new_m, "v": new_v}
    if "master" in state:
        new_state["master"] = jax.tree.map(lambda t: t[3], out,
                                           is_leaf=lambda x:
                                           isinstance(x, tuple))
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
