"""train_step / serve_step factories with full optimizer update."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.train import optimizer as opt


def opt_config_for(cfg: ModelConfig, **overrides) -> opt.OptConfig:
    """Per-arch optimizer layout: 671B-scale bf16 models get int8 moments and
    no fp32 master (the only layout that fits a single v5e pod)."""
    kw: dict[str, Any] = {}
    if cfg.param_counts()["total"] > 1e11:
        kw.update(quantized_moments=True, master_fp32=False)
    kw.update(overrides)
    return opt.OptConfig(**kw)


def make_train_step(model, opt_cfg: opt.OptConfig, accum_steps: int = 1,
                    grad_specs=None, mb_specs=None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    accum_steps > 1 runs gradient accumulation: the global batch is split
    into microbatches scanned sequentially, so the per-layer activation
    stash is sized by the microbatch (the standard fit mechanism for 1M-token
    global batches). The grad accumulator carries ZeRO-sharded layout
    (grad_specs, PartitionSpecs): GSPMD reduce-scatters each microbatch's
    gradients instead of keeping a replicated f32 accumulator.
    """

    def grad_fn(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, mb)
        return grads, {**metrics, "loss": loss}

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            grads, metrics = grad_fn(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]),
                batch)
            if mb_specs is not None:
                # the [accum, B/accum, ...] reshape loses the batch-dim
                # sharding; re-pin it or GSPMD replicates every microbatch
                mbs = jax.tree.map(jax.lax.with_sharding_constraint, mbs,
                                   mb_specs)

            def shard_grads(g):
                if grad_specs is None:
                    return g
                return jax.tree.map(jax.lax.with_sharding_constraint, g,
                                    grad_specs)

            def body(acc, mb):
                g, m = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc, shard_grads(g))
                return acc, m

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                 params)
            grads, ms = jax.lax.scan(body, shard_grads(zeros), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda m: m[-1], ms)
        params, opt_state, om = opt.adamw_update(opt_cfg, params, grads,
                                                 opt_state)
        return params, opt_state, {**metrics, **om}

    return train_step


#: per-device activation-stash budget for choosing accumulation steps
STASH_BUDGET_BYTES = 3.0 * 2**30
STASH_F32_HOIST_FACTOR = 3.0   # observed: XLA hoists an f32 copy of the stash


def accum_steps_for(cfg: ModelConfig, global_batch: int, seq: int,
                    dp_size: int, mp_size: int = 16) -> int:
    """Smallest power-of-two microbatch count keeping the per-layer scan
    stash under budget."""
    if cfg.train_accum_override:
        return cfg.train_accum_override
    b_local = max(global_batch // dp_size, 1)
    per_seq = cfg.num_layers * seq * cfg.d_model * 2 * STASH_F32_HOIST_FACTOR
    if cfg.seq_shard_activations and seq % mp_size == 0:
        per_seq /= mp_size
    n = 1
    while n < b_local and b_local / n * per_seq > STASH_BUDGET_BYTES:
        n *= 2
    return n


def make_eval_step(model):
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return {**metrics, "loss": loss}
    return eval_step
