"""Gradient compression: int8 error-feedback all-reduce.

Distributed-optimization trick for slow (cross-pod) gradient reduction:
quantize per-block to int8 before the data-parallel psum, keep the
quantization residual locally and add it back next step (error feedback —
Karimireddy et al. 2019 — preserves convergence). Implemented with shard_map
so the collective really moves int8 (4x less DCI traffic than fp32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def _blockwise_q8(x, block: int = 256):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def _deq(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compressed_psum_grads(grads, residuals, mesh, axis: str = "data",
                          block: int = 256):
    """All-reduce `grads` over `axis` in int8 with error feedback.

    grads/residuals: matching pytrees (residuals carry quantization error
    from the previous step). Returns (reduced_grads, new_residuals).
    """
    def one(g, r):
        shape = g.shape

        def body(gl, rl):
            val = gl.astype(jnp.float32) + rl
            q, scale = _blockwise_q8(val, block)
            # what we actually transmit:
            sent = _deq(q, scale, shape)
            new_r = val - sent
            red = jax.lax.psum(sent, axis)
            return red, new_r

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()))(g, r)

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    red = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    res = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    return red, res


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
