"""jit'd public wrapper for paged decode attention."""

from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_attention as _kernel
from repro.kernels.paged_attention.kernel import (
    paged_prefill_attention as _prefill_kernel)
from repro.kernels.paged_attention.kernel import (
    paged_prefill_attention_batch as _prefill_batch_kernel)
from repro.kernels.paged_attention.ref import (
    paged_attention_ref, paged_prefill_attention_batch_ref,
    paged_prefill_attention_ref)


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def paged_attention(q, k_pool, v_pool, page_table, lens, *,
                    impl: str = "pallas", interpret: bool = False):
    """Decode attention over a paged KV pool.

    impl="pallas": the TPU kernel (interpret=True executes it on CPU).
    impl="reference": the pure-jnp oracle (used by the CPU serve engine).
    """
    if impl == "reference":
        return paged_attention_ref(q, k_pool, v_pool, page_table, lens)
    return _kernel(q, k_pool, v_pool, page_table, lens, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def paged_prefill_attention(q, k_pool, v_pool, page_table, q_start, *,
                            impl: str = "pallas", interpret: bool = False):
    """Prefill-mode attention: one sequence's query chunk [T,nq,h] over its
    page table [mp], causal at absolute positions ``q_start + t``. Prior
    chunks' K/V is *read from the pool* (the O(n) incremental-prefill path —
    DESIGN.md §6); the chunk's own K/V must be scattered into its pages
    before the call."""
    if impl == "reference":
        return paged_prefill_attention_ref(q, k_pool, v_pool, page_table,
                                           q_start)
    return _prefill_kernel(q, k_pool, v_pool, page_table, q_start,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def paged_prefill_attention_batch(q, k_pool, v_pool, page_table, q_start, *,
                                  impl: str = "pallas",
                                  interpret: bool = False):
    """Batched prefill-mode attention: B sequences' query chunks [B,T,nq,h]
    (padded to a common T) over per-sequence page tables [B,mp], causal at
    absolute positions ``q_start[b] + t``. One launch fuses same-step
    prefill chunks of different sequences and the speculative verify step's
    draft chunks (DESIGN.md §7); each chunk's own K/V must be scattered
    into its pages before the call."""
    if impl == "reference":
        return paged_prefill_attention_batch_ref(q, k_pool, v_pool,
                                                 page_table, q_start)
    return _prefill_batch_kernel(q, k_pool, v_pool, page_table, q_start,
                                 interpret=interpret)
