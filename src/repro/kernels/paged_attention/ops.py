"""jit'd public wrapper for paged decode attention."""

from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_attention as _kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def paged_attention(q, k_pool, v_pool, page_table, lens, *,
                    impl: str = "pallas", interpret: bool = False):
    """Decode attention over a paged KV pool.

    impl="pallas": the TPU kernel (interpret=True executes it on CPU).
    impl="reference": the pure-jnp oracle (used by the CPU serve engine).
    """
    if impl == "reference":
        return paged_attention_ref(q, k_pool, v_pool, page_table, lens)
    return _kernel(q, k_pool, v_pool, page_table, lens, interpret=interpret)
