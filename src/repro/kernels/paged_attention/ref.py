"""Pure-jnp oracles for paged attention over a BWAP-placed page pool.

All three oracles walk the page table with the *same online-softmax
per-page accumulation the Pallas kernels use* rather than materializing one
dense [S] score row. Beyond matching the kernels' reduction structure, this
buys an exactness property the serving stack depends on: a fully-masked
trailing page updates the running (m, l, acc) state by *exactly* nothing
(alpha = exp(0) = 1, every prob = exp(-inf) = 0), so attention output is
bit-invariant to trailing table padding. Batch-padded decode tables, fused
prefill chunks of different lengths, and — critically — the speculative
verify step's lookahead pages (DESIGN.md §7: pages allocated for draft
tokens that may be rolled back) therefore cannot perturb committed results
even in the last bit; a dense softmax changes its reduction grouping with
the table width and breaks the rollback bit-identity guarantee.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0e38


def _page_walk(qf, k_pool, v_pool, page_table, mask_fn):
    """Online-softmax accumulation over one batched page table.

    qf [B, R, h] float32 query rows; page_table [B, mp]; ``mask_fn(b_pos)``
    maps per-page key positions [B, ps] to a validity mask [B, R, ps].
    Returns [B, R, h] float32 (unnormalized rows divided at the end).
    """
    b, r, h = qf.shape
    ps = k_pool.shape[1]
    nkv = k_pool.shape[2]
    mp = page_table.shape[1]
    g = r // nkv                      # query rows per KV head
    q5 = qf.reshape(b, nkv, g, h)
    scale = 1.0 / np.sqrt(h)
    m = jnp.full((b, nkv, g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((b, nkv, g, 1), jnp.float32)
    acc = jnp.zeros((b, nkv, g, h), jnp.float32)
    for pi in range(mp):
        k = k_pool[page_table[:, pi]].astype(jnp.float32)   # [B,ps,nkv,h]
        v = v_pool[page_table[:, pi]].astype(jnp.float32)
        s = jnp.einsum("bngh,bpnh->bngp", q5, k) * scale    # [B,nkv,g,ps]
        pos = pi * ps + jnp.arange(ps)[None, :]             # [B,ps]
        ok = mask_fn(pos).reshape(b, nkv, g, ps)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bngp,bpnh->bngh", p, v)
        m = m_new
    out = acc / jnp.where(l == 0.0, 1.0, l)
    return out.reshape(b, r, h)


def paged_attention_ref(q, k_pool, v_pool, page_table, lens):
    """q [B,nq,h]; pools [P,ps,nkv,h]; page_table [B,mp]; lens [B] -> [B,nq,h].

    Decode attention: query b sees pool positions < lens[b] through its
    page-table row — the semantics the kernel must match.
    """
    b, nq, h = q.shape
    g = nq // k_pool.shape[2]

    def mask(pos):                                   # pos [B,ps]
        ok = pos < lens[:, None]
        return jnp.broadcast_to(ok[:, None, :], (b, nq, pos.shape[1]))

    out = _page_walk(q.astype(jnp.float32), k_pool, v_pool, page_table,
                     mask)
    return out.astype(q.dtype)


def paged_prefill_attention_batch_ref(q, k_pool, v_pool, page_table,
                                      q_start):
    """Batched prefill-mode oracle: B sequences' query chunks, each at its
    own absolute start position, over their own page tables in one call.
    q [B,T,nq,h]; pools [P,ps,nkv,h]; page_table [B,mp]; q_start [B].
    Query (b, t) sits at position ``q_start[b] + t`` and sees pool positions
    <= its own through sequence b's table. This single shape serves both
    fused same-step chunked prefill of different sequences (pad short
    chunks; padded queries read garbage that callers discard) and the
    multi-token speculative *verify* step (chunk = last token + draft).
    Returns [B,T,nq,h].
    """
    b, t, nq, h = q.shape
    nkv = k_pool.shape[2]
    g = nq // nkv
    # rows grouped by KV head, then query position, then group — the
    # [nkv, T*g] layout the kernel accumulates in
    qf = jnp.transpose(q.reshape(b, t, nkv, g, h),
                       (0, 2, 1, 3, 4)).reshape(b, nkv * t * g, h)
    qpos = q_start[:, None] + jnp.repeat(jnp.arange(t), g)[None, :]  # [B,T*g]

    def mask(pos):                                   # pos [B,ps]
        ok = pos[:, None, :] <= qpos[:, :, None]     # [B,T*g,ps]
        return jnp.broadcast_to(ok[:, None, :, :],
                                (b, nkv, t * g, pos.shape[1])) \
            .reshape(b, nkv * t * g, pos.shape[1])

    out = _page_walk(qf.astype(jnp.float32), k_pool, v_pool, page_table,
                     mask)
    out = jnp.transpose(out.reshape(b, nkv, t, g, h), (0, 2, 1, 3, 4))
    return out.reshape(b, t, nq, h).astype(q.dtype)


def paged_prefill_attention_ref(q, k_pool, v_pool, page_table, q_start):
    """Prefill-mode oracle: one sequence's query *chunk* attends over its
    logically-mapped pool pages. q [T,nq,h]; pools [P,ps,nkv,h]; page_table
    [mp]; query t sits at absolute position ``q_start + t`` and sees pool
    positions <= its own (prior chunks' K/V — already resident via the page
    table — plus the causal intra-chunk triangle). This is what makes
    chunked prefill O(chunk) instead of recomputing the prefix: the chunk's
    own K/V is scattered into the pool *before* the call, so one gather
    covers old and new keys alike. Returns [T,nq,h].
    """
    out = paged_prefill_attention_batch_ref(
        q[None], k_pool, v_pool, page_table[None],
        jnp.asarray(q_start, jnp.int32).reshape(1))
    return out[0]
