"""Pure-jnp oracle for paged decode attention over a BWAP-placed page pool."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0e38


def paged_attention_ref(q, k_pool, v_pool, page_table, lens):
    """q [B,nq,h]; pools [P,ps,nkv,h]; page_table [B,mp]; lens [B] -> [B,nq,h].

    Reconstructs the dense KV per sequence by gathering pages, then runs
    masked softmax attention — the semantics the kernel must match.
    """
    b, nq, h = q.shape
    ps, nkv = k_pool.shape[1], k_pool.shape[2]
    mp = page_table.shape[1]
    g = nq // nkv

    k = k_pool[page_table].reshape(b, mp * ps, nkv, h)   # [B,T,nkv,h]
    v = v_pool[page_table].reshape(b, mp * ps, nkv, h)
    q5 = q.reshape(b, nkv, g, h)
    scores = jnp.einsum("bngh,btnh->bngt", q5.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(h)
    pos = jnp.arange(mp * ps)[None, :]
    ok = pos < lens[:, None]
    scores = jnp.where(ok[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngt,btnh->bngh", probs, v.astype(jnp.float32))
    return out.reshape(b, nq, h).astype(q.dtype)


def paged_prefill_attention_ref(q, k_pool, v_pool, page_table, q_start):
    """Prefill-mode oracle: one sequence's query *chunk* attends over its
    logically-mapped pool pages. q [T,nq,h]; pools [P,ps,nkv,h]; page_table
    [mp]; query t sits at absolute position ``q_start + t`` and sees pool
    positions <= its own (prior chunks' K/V — already resident via the page
    table — plus the causal intra-chunk triangle). This is what makes
    chunked prefill O(chunk) instead of recomputing the prefix: the chunk's
    own K/V is scattered into the pool *before* the call, so one gather
    covers old and new keys alike. Returns [T,nq,h].
    """
    t, nq, h = q.shape
    ps, nkv = k_pool.shape[1], k_pool.shape[2]
    mp = page_table.shape[0]
    g = nq // nkv

    k = k_pool[page_table].reshape(mp * ps, nkv, h)      # [S,nkv,h]
    v = v_pool[page_table].reshape(mp * ps, nkv, h)
    q5 = q.reshape(t, nkv, g, h)
    scores = jnp.einsum("tngh,snh->tngs", q5.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(h)
    kpos = jnp.arange(mp * ps)[None, :]
    qpos = q_start + jnp.arange(t)[:, None]
    ok = kpos <= qpos                                    # [T,S] causal
    scores = jnp.where(ok[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("tngs,snh->tngh", probs, v.astype(jnp.float32))
    return out.reshape(t, nq, h).astype(q.dtype)
