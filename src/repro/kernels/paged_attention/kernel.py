"""Paged decode-attention Pallas TPU kernel — the BWAP KV-cache consumer.

The KV pool is a page-granular buffer whose pages the BWAP placement layer
(serve/kvcache.py) distributes across memory domains with Alg.-1 weighted
interleaving; this kernel walks a sequence's page table (scalar-prefetched so
the next page's DMA is issued while the current tile computes) and performs
online-softmax attention per page.

VMEM working set per step: q [nq,h] + one K page + one V page
(page_size x nkv x h each) + fp32 accumulators — sized for ~16 MiB VMEM with
page_size 64..256 at h<=256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1.0e38


def _paged_kernel(table_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page_size: int, groups: int,
                  scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = lens_ref[b]
    page_start = pi * page_size

    @pl.when(page_start < seq_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # [nq, h]
        k = k_ref[0].astype(jnp.float32)            # [ps, nkv, h]
        v = v_ref[0].astype(jnp.float32)
        nq, h = q.shape
        nkv = k.shape[1]
        qg = q.reshape(nkv, groups, h)
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))))    # [nkv, g, ps]
        s = s * scale
        pos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, (nkv, groups, page_size), 2)
        s = jnp.where(pos < seq_len, s, NEG_INF)

        m_prev = m_ref[...]                          # [nkv, g, 1]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                       # [nkv, g, ps]
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=2, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))))      # [nkv, g, h]
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(pi == np_ - 1)
    def _finish():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = out.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def _paged_prefill_kernel(table_ref, qstart_ref, q_ref, k_ref, v_ref, o_ref,
                          m_ref, l_ref, acc_ref, *, page_size: int,
                          groups: int, chunk: int, scale: float):
    """Prefill-mode page walk: a [T,nq,h] query chunk of ONE sequence
    accumulates online softmax over its pages; query t at absolute position
    qstart+t sees keys at positions <= its own. The grid dimension walks
    pages exactly like the decode kernel; queries fold into the accumulator
    rows ([nkv, T*g, ·]) so both kernels share the update algebra."""
    pi = pl.program_id(0)
    np_ = pl.num_programs(0)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qstart_ref[0]
    page_start = pi * page_size

    # a page participates iff it holds a key visible to the *last* query
    @pl.when(page_start <= q_start + chunk - 1)
    def _compute():
        q = q_ref[...].astype(jnp.float32)           # [T, nq, h]
        k = k_ref[0].astype(jnp.float32)             # [ps, nkv, h]
        v = v_ref[0].astype(jnp.float32)
        t, nq, h = q.shape
        nkv = k.shape[1]
        qg = jnp.transpose(q.reshape(t, nkv, groups, h),
                           (1, 0, 2, 3)).reshape(nkv, t * groups, h)
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))))     # [nkv, T*g, ps]
        s = s * scale
        kpos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, (nkv, t * groups, page_size), 2)
        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (nkv, t * groups, page_size), 1) // groups
        s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev = m_ref[...]                          # [nkv, T*g, 1]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=2, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))))      # [nkv, T*g, h]
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(pi == np_ - 1)
    def _finish():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        t, nq, h = o_ref.shape
        nkv = out.shape[0]
        out = jnp.transpose(out.reshape(nkv, t, groups, h), (1, 0, 2, 3))
        o_ref[...] = out.reshape(t, nq, h).astype(o_ref.dtype)


def _paged_prefill_batch_kernel(table_ref, qstart_ref, q_ref, k_ref, v_ref,
                                o_ref, m_ref, l_ref, acc_ref, *,
                                page_size: int, groups: int, chunk: int,
                                scale: float):
    """Batched prefill-mode page walk: grid (b, mp) — sequence b's [T,nq,h]
    chunk at absolute start ``qstart_ref[b]`` accumulates online softmax
    over *its own* page table row, exactly the single-sequence prefill
    kernel per grid row. One launch fuses same-step chunks of different
    sequences (batched incremental prefill) and the speculative verify
    step's draft chunks."""
    b = pl.program_id(0)
    pi = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qstart_ref[b]
    page_start = pi * page_size

    # a page participates iff it holds a key visible to the last query
    @pl.when(page_start <= q_start + chunk - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)             # [T, nq, h]
        k = k_ref[0].astype(jnp.float32)             # [ps, nkv, h]
        v = v_ref[0].astype(jnp.float32)
        t, nq, h = q.shape
        nkv = k.shape[1]
        qg = jnp.transpose(q.reshape(t, nkv, groups, h),
                           (1, 0, 2, 3)).reshape(nkv, t * groups, h)
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))))     # [nkv, T*g, ps]
        s = s * scale
        kpos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, (nkv, t * groups, page_size), 2)
        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (nkv, t * groups, page_size), 1) // groups
        s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev = m_ref[...]                          # [nkv, T*g, 1]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=2, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))))      # [nkv, T*g, h]
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(pi == np_ - 1)
    def _finish():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        _, t, nq, h = o_ref.shape
        nkv = out.shape[0]
        out = jnp.transpose(out.reshape(nkv, t, groups, h), (1, 0, 2, 3))
        o_ref[0] = out.reshape(t, nq, h).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention_batch(q, k_pool, v_pool, page_table, q_start, *,
                                  interpret: bool = False):
    """q [B,T,nq,h] (per-sequence chunks, padded to a common T); pools
    [P,ps,nkv,h]; page_table [B,mp] (pad with page 0); q_start [B] traced
    -> [B,T,nq,h]."""
    b, t, nq, h = q.shape
    ps, nkv = k_pool.shape[1], k_pool.shape[2]
    mp = page_table.shape[1]
    groups = nq // nkv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, mp),
        in_specs=[
            pl.BlockSpec((1, t, nq, h), lambda b, p, tbl, qs: (b, 0, 0, 0)),
            pl.BlockSpec((1, ps, nkv, h),
                         lambda b, p, tbl, qs: (tbl[b, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, nkv, h),
                         lambda b, p, tbl, qs: (tbl[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, nq, h),
                               lambda b, p, tbl, qs: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((nkv, t * groups, 1), jnp.float32),   # m
            pltpu.VMEM((nkv, t * groups, 1), jnp.float32),   # l
            pltpu.VMEM((nkv, t * groups, h), jnp.float32),   # acc
        ],
    )
    kernel = functools.partial(_paged_prefill_batch_kernel, page_size=ps,
                               groups=groups, chunk=t,
                               scale=1.0 / np.sqrt(h))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, t, nq, h), q.dtype),
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, jnp.asarray(q_start, jnp.int32).reshape(b),
      q, k_pool, v_pool)
    return out


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention(q, k_pool, v_pool, page_table, q_start, *,
                            interpret: bool = False):
    """q [T,nq,h] (one sequence's chunk); pools [P,ps,nkv,h]; page_table
    [mp] covering positions [0, q_start+T); q_start traced scalar ->
    [T,nq,h]."""
    t, nq, h = q.shape
    ps, nkv = k_pool.shape[1], k_pool.shape[2]
    mp = page_table.shape[0]
    groups = nq // nkv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(mp,),
        in_specs=[
            pl.BlockSpec((t, nq, h), lambda p, tbl, qs: (0, 0, 0)),
            pl.BlockSpec((1, ps, nkv, h),
                         lambda p, tbl, qs: (tbl[p], 0, 0, 0)),
            pl.BlockSpec((1, ps, nkv, h),
                         lambda p, tbl, qs: (tbl[p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((t, nq, h), lambda p, tbl, qs: (0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((nkv, t * groups, 1), jnp.float32),   # m
            pltpu.VMEM((nkv, t * groups, 1), jnp.float32),   # l
            pltpu.VMEM((nkv, t * groups, h), jnp.float32),   # acc
        ],
    )
    kernel = functools.partial(_paged_prefill_kernel, page_size=ps,
                               groups=groups, chunk=t,
                               scale=1.0 / np.sqrt(h))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, nq, h), q.dtype),
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(page_table, jnp.asarray(q_start, jnp.int32).reshape(1),
      q, k_pool, v_pool)
    return out


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, page_table, lens, *,
                    interpret: bool = False):
    """q [B,nq,h]; pools [P,ps,nkv,h]; page_table [B,mp] (pad with page 0);
    lens [B] -> [B,nq,h]."""
    b, nq, h = q.shape
    ps, nkv = k_pool.shape[1], k_pool.shape[2]
    mp = page_table.shape[1]
    groups = nq // nkv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, mp),
        in_specs=[
            pl.BlockSpec((1, nq, h), lambda b, p, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((1, ps, nkv, h),
                         lambda b, p, tbl, ln: (tbl[b, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, nkv, h),
                         lambda b, p, tbl, ln: (tbl[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nq, h), lambda b, p, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((nkv, groups, 1), jnp.float32),   # m
            pltpu.VMEM((nkv, groups, 1), jnp.float32),   # l
            pltpu.VMEM((nkv, groups, h), jnp.float32),   # acc
        ],
    )
    kernel = functools.partial(_paged_kernel, page_size=ps, groups=groups,
                               scale=1.0 / np.sqrt(h))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nq, h), q.dtype),
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, lens, q, k_pool, v_pool)
    return out
