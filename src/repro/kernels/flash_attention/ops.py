"""jit'd public wrapper: GQA head mapping + layout for the flash kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bh


@functools.partial(jax.jit, static_argnames=("window", "causal", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q, k, v, *, window: int = 0, causal: bool = True,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False):
    """q [B,S,nq,h], k/v [B,T,nkv,h] -> [B,S,nq,h].

    KV heads are repeated lazily into the batched-heads layout the kernel
    consumes; grouping happens on the [BH, S, h] view so each (batch, head)
    is an independent grid row.
    """
    b, s, nq, h = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    qb = q.transpose(0, 2, 1, 3).reshape(b * nq, s, h)
    kb = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * nq, t, h)
    vb = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * nq, t, h)
    out = flash_attention_bh(qb, kb, vb, window=window, causal=causal,
                             block_q=block_q, block_kv=block_kv,
                             interpret=interpret)
    return out.reshape(b, nq, s, h).transpose(0, 2, 1, 3)
