"""Pure-jnp oracle for the flash attention kernel (GQA + causal + window)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, *, window: int = 0, causal: bool = True):
    """q [B,S,nq,h], k/v [B,T,nkv,h] -> [B,S,nq,h]. fp32 softmax."""
    b, s, nq, h = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    q5 = q.reshape(b, s, nkv, g, h)
    scores = jnp.einsum("bsngh,btnh->bngst", q5.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(h)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    ok = jnp.ones((s, t), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= qpos - kpos < window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngst,btnh->bsngh", probs, v.astype(jnp.float32))
    return out.reshape(b, s, nq, h).astype(q.dtype)
