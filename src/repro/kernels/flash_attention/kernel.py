"""Flash attention Pallas TPU kernel (GQA, causal, sliding window).

VMEM tiling: one [block_q, head_dim] query tile and one [block_kv, head_dim]
key/value tile resident per step; fp32 online-softmax accumulators live in
VMEM scratch across the sequential kv grid dimension. Block sizes default to
MXU-aligned 128x128 tiles; the kv loop is the innermost ("arbitrary") grid
axis so q tiles stream while accumulators persist.

The TPU adaptation of the paper's hot loop: HBM->VMEM traffic is the
bandwidth term the BWAP-style placement optimizes; tiles are sized so the
working set (q + k + v + acc ~ 4 * 128 * hd * 4B) stays far under the
~16 MiB/core VMEM budget even at head_dim 256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_kv: int, window: int,
                  causal: bool, seq_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_kv

    # Skip fully-masked tiles (causal upper triangle / outside the window).
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window > 0:
        needed = needed & (q_start - (k_start + block_kv - 1) < window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)           # [bq, h]
        k = k_ref[0].astype(jnp.float32)           # [bkv, h]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_kv), 1)
        ok = kpos < seq_kv
        if causal:
            ok &= kpos <= qpos
        if window > 0:
            ok &= qpos - kpos < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                     # [bq, bkv]
        l_new = l_prev * alpha + jnp.sum(p, axis=1)[:, None]
        acc_ref[...] = acc_ref[...] * alpha \
            + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        # rows with no valid kv (shouldn't happen causally) stay zero
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "causal", "block_q",
                                             "block_kv", "interpret"))
def flash_attention_bh(q, k, v, *, window: int = 0, causal: bool = True,
                       block_q: int = 128, block_kv: int = 128,
                       interpret: bool = False):
    """Batched-heads layout: q [BH, S, h]; k/v [BH, T, h] (kv heads already
    aligned with q heads — ops.py handles the GQA head mapping)."""
    bh, s, h = q.shape
    t = k.shape[1]
    block_q = min(block_q, s)
    block_kv = min(block_kv, t)
    s_pad = -(-s // block_q) * block_q
    t_pad = -(-t // block_kv) * block_kv
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0)))
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0)))

    grid = (bh, s_pad // block_q, t_pad // block_kv)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / np.sqrt(h), block_q=block_q,
        block_kv=block_kv, window=window, causal=causal, seq_kv=t)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, h), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, h), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, h), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, h), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_pad, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # m
            pltpu.VMEM((block_q, 1), jnp.float32),    # l
            pltpu.VMEM((block_q, h), jnp.float32),    # acc
        ],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :s, :]
