"""Data pipeline: deterministic sharded token streams with BWAP-weighted
shard assignment and straggler mitigation.

The paper's placement idea applied to input data: shard files are assigned
to hosts proportionally to each host's *measured ingest bandwidth* (Alg. 1
weighted interleaving over hosts instead of uniform round-robin). At run
time, per-host fetch latencies feed an EWMA; hosts that degrade (stragglers)
get their weight reduced and shards re-interleaved — the DWP-tuner pattern
(measure -> adjust placement -> migrate) on the data plane.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Iterator, Sequence

import numpy as np

from repro.core import interleave


@dataclasses.dataclass
class HostState:
    bw_weight: float            # current assignment weight
    ewma_latency: float = 0.0   # seconds per batch fetch
    fetches: int = 0


class ShardedTokenDataset:
    """Deterministic synthetic token stream (seeded per shard) or
    memory-mapped tokenized files. Shard i yields batch b of [B_shard, S]."""

    def __init__(self, vocab_size: int, seq_len: int, num_shards: int,
                 seed: int = 0, files: Sequence[str] | None = None):
        self.vocab = vocab_size
        self.seq = seq_len
        self.num_shards = num_shards
        self.seed = seed
        self.files = list(files) if files else None
        self._mmaps = {}

    def batch(self, shard: int, step: int, batch_size: int) -> np.ndarray:
        if self.files:
            mm = self._mmaps.get(shard)
            if mm is None:
                mm = np.memmap(self.files[shard % len(self.files)],
                               dtype=np.int32, mode="r")
                self._mmaps[shard] = mm
            need = batch_size * self.seq
            off = (step * need) % max(len(mm) - need, 1)
            return np.asarray(mm[off:off + need]).reshape(batch_size,
                                                          self.seq)
        rng = np.random.default_rng(
            (self.seed, shard, step))  # deterministic & resumable
        return rng.integers(0, self.vocab, (batch_size, self.seq),
                            dtype=np.int32)


class BwapDataRouter:
    """Assigns dataset shards to hosts with weighted interleaving and
    re-balances when stragglers appear."""

    def __init__(self, num_shards: int, host_bws: Sequence[float],
                 straggler_factor: float = 2.0, ewma: float = 0.3):
        self.num_shards = num_shards
        self.hosts = [HostState(bw_weight=float(b)) for b in host_bws]
        self.straggler_factor = straggler_factor
        self.ewma = ewma
        self.assignment = interleave.weighted_interleave(
            num_shards, np.asarray([h.bw_weight for h in self.hosts]))
        self.migrations = 0

    def shards_of(self, host: int) -> np.ndarray:
        return np.nonzero(self.assignment == host)[0]

    def record_fetch(self, host: int, latency_s: float) -> bool:
        """Update EWMA; returns True if a rebalance was triggered."""
        h = self.hosts[host]
        h.fetches += 1
        h.ewma_latency = (latency_s if h.fetches == 1 else
                          (1 - self.ewma) * h.ewma_latency
                          + self.ewma * latency_s)
        return self._maybe_rebalance()

    def _maybe_rebalance(self) -> bool:
        lats = np.asarray([h.ewma_latency for h in self.hosts])
        if (lats <= 0).any() or min(h.fetches for h in self.hosts) < 2:
            return False
        median = float(np.median(lats))
        new_w = np.asarray([
            h.bw_weight * (median / h.ewma_latency
                           if h.ewma_latency > self.straggler_factor * median
                           else 1.0)
            for h in self.hosts])
        if np.allclose(new_w, [h.bw_weight for h in self.hosts]):
            return False
        for h, w in zip(self.hosts, new_w):
            h.bw_weight = float(w)
        plan = interleave.plan_migration(self.assignment, new_w)
        self.assignment = plan.new_assignment
        self.migrations += plan.num_moves
        return True


class PrefetchLoader:
    """Background-thread prefetcher over (dataset, router)."""

    def __init__(self, dataset: ShardedTokenDataset, router: BwapDataRouter,
                 host: int, batch_size: int, depth: int = 2,
                 fetch_delay: Callable[[int], float] | None = None):
        self.dataset = dataset
        self.router = router
        self.host = host
        self.batch_size = batch_size
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._fetch_delay = fetch_delay
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._step = 0
        self._thread.start()

    def _worker(self):
        step = 0
        while not self._stop.is_set():
            t0 = time.monotonic()
            shards = self.router.shards_of(self.host)
            shard = int(shards[step % max(len(shards), 1)]) if len(shards) \
                else 0
            batch = self.dataset.batch(shard, step, self.batch_size)
            if self._fetch_delay:          # test hook: simulated slowness
                time.sleep(self._fetch_delay(step))
            self.router.record_fetch(self.host, time.monotonic() - t0)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
