"""BWAP core: the paper's contribution (bandwidth-aware weighted page
placement) as a reusable, hardware-agnostic library. See DESIGN.md §1-3."""

from repro.core import bwmodel, canonical, dwp, interleave, simulator, topology
from repro.core.canonical import CanonicalTuner
from repro.core.dwp import CoScheduledTuner, DWPConfig, DWPTuner
from repro.core.interleave import (dwp_weights, plan_migration,
                                   weighted_interleave)
from repro.core.simulator import PAPER_WORKLOADS, NumaSimulator
from repro.core.topology import Topology, machine_a, machine_b

__all__ = [
    "bwmodel", "canonical", "dwp", "interleave", "simulator", "topology",
    "CanonicalTuner", "CoScheduledTuner", "DWPConfig", "DWPTuner",
    "dwp_weights", "plan_migration", "weighted_interleave",
    "PAPER_WORKLOADS", "NumaSimulator", "Topology", "machine_a", "machine_b",
]
