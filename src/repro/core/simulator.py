"""NUMA memory-throughput simulator — the paper's model, made executable.

This is the *substrate* for the faithful reproduction: the container has no
8-node Opteron, so the machine is replaced by the paper's own system model
(§III-A) plus the standard contention refinements the paper cites
(memory-controller saturation [30], interconnect congestion [24]). The BWAP
*algorithms* under test (canonical tuner, DWP tuner, Alg. 1) are the real
implementations from ``repro.core`` — only the hardware is simulated.

Model of one application run:

  T = T_compute + (1 - lam) * T_bw + lam * T_lat

  T_bw  — bandwidth-bound stall time: per worker node, the slowest parallel
          transfer of its read volume from each memory node (Eq. 3), with
          effective bandwidths from water-filling all concurrent demands
          (paper §III-A3 contention phenomena).
  T_lat — latency-bound stall time: volume-weighted mean relative access
          latency of the placement (remote hops cost more), scaled by the
          app's latency sensitivity ``lam`` (paper Obs. 2: some apps are
          BW-bound, others latency-sensitive).

Stall rate (what the DWP tuner measures) = (T - T_compute) / T.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core import bwmodel, interleave
from repro.core.topology import Topology


@dataclasses.dataclass(frozen=True)
class Workload:
    """A memory-intensive application (paper Table I characterization).

    read_gbps/write_gbps: aggregate demand of one fully-loaded worker node.
    private_frac: fraction of accesses to thread-private pages.
    latency_sensitivity: lam in the execution-time model.
    dataset_gb: shared + private resident set (fits one node, §IV).
    compute_time: non-memory execution time at the reference thread count.
    parallel_fraction: Amdahl fraction for scaling compute_time with workers.
    """

    name: str
    read_gbps: float
    write_gbps: float
    private_frac: float
    latency_sensitivity: float
    dataset_gb: float
    compute_time: float
    parallel_fraction: float = 0.95


# The five paper benchmarks (Table I, machine B, one full worker node).
# latency_sensitivity is a free parameter of the model, set per the paper's
# qualitative findings (SC is latency-leaning — Table II shows high optimal
# DWP; OC/ON are BW-bound — optimal DWP ~0).
PAPER_WORKLOADS: dict[str, Workload] = {
    "OC": Workload("Ocean_cp", 17.576, 6.492, 0.793, 0.05, 3.5, 6.0),
    "ON": Workload("Ocean_ncp", 16.053, 5.578, 0.867, 0.05, 3.5, 6.0),
    "SP.B": Workload("SP.B", 11.962, 5.352, 0.199, 0.20, 1.2, 8.0),
    "SC": Workload("Streamcluster", 10.055, 0.070, 0.002, 0.12, 0.8, 10.0),
    "FT.C": Workload("FT.C", 5.585, 4.715, 0.950, 0.04, 5.0, 9.0),
}

DEMAND_EXCESS = 1.8   # want/achieved ratio (see run())
LAT_COEF = 0.35       # latency-stall scale vs compute time

#: Relative access-latency multiplier per path, derived from the bandwidth
#: matrix (lower-BW paths are longer paths; calibrated so that local=1 and the
#: farthest machine-A hop ~2.5, in line with measured NUMA latency ratios).
def _latency_matrix(topo: Topology) -> np.ndarray:
    rel = topo.bw.diagonal()[None, :] / topo.bw  # >= 1 off-diagonal
    return 1.0 + 0.45 * (rel.T - 1.0)            # lat[src->dst] indexed [src,dst]


@dataclasses.dataclass(frozen=True)
class RunResult:
    time: float
    stall_rate: float
    t_bw: float
    t_lat: float
    per_worker_time: np.ndarray


class NumaSimulator:
    def __init__(self, topo: Topology, seed: int = 0):
        self.topo = topo
        self.lat = _latency_matrix(topo)
        self.rng = np.random.default_rng(seed)

    # -- placement policies (paper §II/§IV baselines) -----------------------

    def placement(self, policy: str, workers: Sequence[int],
                  weights: np.ndarray | None = None) -> np.ndarray:
        """Per-node page fractions for the *shared* segment."""
        n = self.topo.num_nodes
        w = np.zeros(n)
        if policy == "first_touch":
            w[workers[0]] = 1.0       # initializing thread's node (§IV-A)
        elif policy in ("uniform_workers", "autonuma"):
            # autonuma converges to locality-driven placement on the worker
            # set (it migrates pages toward accessing threads, §V)
            w[list(workers)] = 1.0 / len(workers)
        elif policy == "uniform_all":
            w[:] = 1.0 / n
        elif policy == "weighted":
            assert weights is not None
            w = interleave.normalize(weights)
        else:
            raise ValueError(policy)
        return w

    def private_placement(self, policy: str, workers: Sequence[int],
                          weights: np.ndarray | None = None) -> np.ndarray:
        """(W, N) page fractions of each worker's private pages.

        first_touch places private pages locally (ideal for them); the
        interleaving policies spread them like shared pages — including BWAP,
        which by design does not distinguish page classes (§IV-A discussion).
        """
        n = self.topo.num_nodes
        out = np.zeros((len(workers), n))
        if policy in ("first_touch", "autonuma"):
            for k, wnode in enumerate(workers):
                out[k, wnode] = 1.0   # autonuma places private pages locally
        else:
            shared = self.placement(policy, workers, weights)
            out[:] = shared[None, :]
        return out

    # -- execution model -----------------------------------------------------

    def run(self, app: Workload, workers: Sequence[int], policy: str,
            weights: np.ndarray | None = None, noise: float = 0.0,
            threads_per_worker: int | None = None) -> RunResult:
        topo = self.topo
        n = topo.num_nodes
        W = len(workers)
        tpw = threads_per_worker or topo.cores_per_node
        load = tpw / topo.cores_per_node          # node load factor

        shared_w = self.placement(policy, workers, weights)
        priv_w = self.private_placement(policy, workers, weights)

        # Per-worker read volume (GB) over the run: demand x stall-free time.
        # Splitting by Table-I private/shared ratios.
        vol = app.read_gbps * load * app.compute_time
        vol_shared = vol * (1.0 - app.private_frac)
        vol_priv = vol * app.private_frac
        vol_write = app.write_gbps * load * app.compute_time

        # Concurrent demand matrix: worker dst pulls from src at a rate
        # proportional to the bytes placed there (writes count toward
        # controller pressure on the destination node of the write).
        # Demands are the app's ACTUAL rates — an unsaturated machine has no
        # bandwidth stall (latency then dominates; Obs. 2's two regimes).
        # Table-I rates are *achieved* under the machine's constraints;
        # unconstrained demand is higher (DEMAND_EXCESS calibrated so
        # machine A saturates and machine B sits near the knee, per the
        # paper's relative gains)
        demand_rate = (app.read_gbps + app.write_gbps * 0.5) * load \
            * DEMAND_EXCESS
        demands = []
        bytes_from = np.zeros((W, n))
        want = np.zeros((W, n))
        for k, dst in enumerate(workers):
            bytes_from[k] = vol_shared * shared_w + vol_priv * priv_w[k] \
                + vol_write * shared_w * 0.5   # write-allocate traffic share
            total_k = max(bytes_from[k].sum(), 1e-12)
            for src in range(n):
                if bytes_from[k, src] > 1e-12:
                    want[k, src] = demand_rate * bytes_from[k, src] / total_k
                    demands.append(bwmodel.Demand(
                        src=src, dst=dst, gbps=float(want[k, src])))
        grant = bwmodel.effective_bandwidth(topo, demands)

        # BW stall: extra transfer time beyond the requested rate
        per_worker = np.zeros(W)
        for k, dst in enumerate(workers):
            t = 0.0
            for src in range(n):
                b = bytes_from[k, src]
                if b <= 1e-12:
                    continue
                g = max(grant[(src, dst)], 1e-9)
                t = max(t, b / g - b / max(want[k, src], 1e-9))
            per_worker[k] = max(t, 0.0)
        t_bw = float(per_worker.max()) if W else 0.0

        # Latency stall time: excess mean access latency vs all-local.
        t_lat = 0.0
        for k, dst in enumerate(workers):
            frac = (vol_shared * shared_w + vol_priv * priv_w[k])
            frac = frac / max(frac.sum(), 1e-12)
            mean_lat = float((frac * self.lat[:, dst]).sum())
            t_lat = max(t_lat, app.compute_time * LAT_COEF
                        * (mean_lat - 1.0))
        # compute scales with workers (Amdahl)
        speedup = 1.0 / ((1 - app.parallel_fraction)
                         + app.parallel_fraction / max(W * load, 1e-9))
        t_c = app.compute_time / min(speedup, W * load if W else 1)

        lam = app.latency_sensitivity
        total = t_c + (1 - lam) * t_bw + lam * t_lat
        if noise:
            total *= float(1.0 + self.rng.normal(0.0, noise))
        stall = (total - t_c) / total if total > 0 else 0.0
        return RunResult(time=total, stall_rate=stall, t_bw=t_bw, t_lat=t_lat,
                         per_worker_time=per_worker)

    # -- stall-rate stream for the DWP tuner ---------------------------------

    def stall_stream(self, app: Workload, workers: Sequence[int],
                     weights: np.ndarray, n_samples: int,
                     noise: float = 0.02) -> list[float]:
        base = self.run(app, workers, "weighted", weights).stall_rate
        return [float(base * (1.0 + self.rng.normal(0.0, noise)))
                for _ in range(n_samples)]


    # -- full BWAP run: canonical start + online DWP tuning -------------------

    def run_with_tuner(self, app: Workload, workers, canonical: np.ndarray,
                       dwp_config=None, noise: float = 0.01,
                       migration_bw: float = 12.0):
        """Simulated execution with the DWP tuner in the loop.

        Work model: the app needs 1 unit of work; at placement w it
        progresses at rate 1/T(w). Each tuner period costs n*t wall seconds
        at the current rate; page migrations cost moved_fraction *
        dataset_gb / migration_bw. Returns (total_time, final_dwp, tuner).
        """
        from repro.core import dwp as dwp_mod
        cfg = dwp_config or dwp_mod.DWPConfig()
        migration_cost = [0.0]

        def on_migrate(plan):
            migration_cost[0] += plan.moved_fraction() * app.dataset_gb \
                / migration_bw

        tuner = dwp_mod.DWPTuner(canonical, workers, num_pages=4096,
                                 config=cfg, on_migrate=on_migrate)
        work_done = 0.0
        elapsed = 0.0
        period_s = cfg.n * cfg.t
        while not tuner.done and work_done < 1.0:
            w = interleave.dwp_weights(canonical, tuner.workers, tuner.dwp)
            t_here = self.run(app, workers, "weighted", w).time
            rate = 1.0 / t_here
            stall = self.run(app, workers, "weighted", w).stall_rate
            for _ in range(cfg.n):
                tuner.record(stall * (1.0 + self.rng.normal(0.0, noise)))
            work_done += rate * period_s
            elapsed += period_s
        if work_done < 1.0:
            w = interleave.dwp_weights(canonical, tuner.workers, tuner.dwp)
            t_final = self.run(app, workers, "weighted", w).time
            elapsed += (1.0 - work_done) * t_final
        return elapsed + migration_cost[0], tuner.dwp, tuner


# ---------------------------------------------------------------------------
# Offline N-dimensional hill climbing (the paper's 15-hour baseline, §II)
# ---------------------------------------------------------------------------

def ndim_hill_climb(sim: NumaSimulator, app: Workload,
                    workers: Sequence[int], iters: int = 180,
                    step: float = 0.05, seed: int = 0,
                    top_k: int = 10) -> tuple[np.ndarray, float, list[float]]:
    """The paper's offline search (§II): hill climbing over the
    N-dimensional weight space, starting from uniform-workers. Candidate
    moves mix informed shaves (take weight from the node with the longest
    transfer time, give it to the shortest — the §III-A2 argument) with
    random mass moves. Returns the mean of the top-k weight vectors, the
    best time, and the trajectory."""
    rng = np.random.default_rng(seed)
    n = sim.topo.num_nodes
    start_points = [
        interleave.normalize(sim.placement("uniform_workers", workers)
                             + 1e-3),
        sim.placement("uniform_all", workers),
    ]
    seen: list[tuple[float, np.ndarray]] = []
    traj: list[float] = []

    def transfer_times(w):
        r = sim.run(app, workers, "weighted", w)
        # per-node worst-case transfer proxy: weight / minbw to workers
        mb = np.asarray([min(sim.topo.bw[i, d] for d in workers)
                         for i in range(n)])
        return r.time, w / mb

    per_seed = max(iters // len(start_points), 1)
    for cur in start_points:
        cur = cur.copy()
        cur_t = sim.run(app, workers, "weighted", cur).time
        seen.append((cur_t, cur.copy()))
        traj.append(min(traj[-1], cur_t) if traj else cur_t)
        for it in range(per_seed):
            cand = cur.copy()
            if it % 2 == 0:   # informed shave
                _, tt = transfer_times(cand)
                i = int(np.argmax(tt))
                j = int(np.argmin(tt + (cand <= 0) * 1e9))
            else:             # random exploration
                i, j = rng.integers(0, n, size=2)
            delta = min(step * rng.uniform(0.2, 1.0), cand[i])
            cand[i] -= delta
            cand[j] += delta
            cand = interleave.normalize(np.maximum(cand, 0.0))
            t = sim.run(app, workers, "weighted", cand).time
            seen.append((t, cand.copy()))
            if t < cur_t:
                cur, cur_t = cand, t
            traj.append(min(traj[-1], cur_t))
    seen.sort(key=lambda x: x[0])
    top = np.stack([w for _, w in seen[:top_k]], axis=0).mean(axis=0)
    top = interleave.normalize(top)
    return top, seen[0][0], traj
