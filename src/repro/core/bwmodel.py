"""Bandwidth estimation and the paper's throughput model (Eqs. 1-5).

The canonical tuner needs ``bw(n_src -> n_dst)`` under the *demand of a
BW-intensive canonical application* (paper §III-A3): nominal link numbers are
wrong because memory-controller saturation and interconnect congestion reshape
effective bandwidth. The paper profiles a canonical benchmark with hardware
counters; we reproduce that procedure against the contention model below
(`profile_bw`), which plays the role of the physical machine. On a real
deployment the same interface is fed by measured counters instead.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core.topology import Topology


@dataclasses.dataclass(frozen=True)
class Demand:
    """Aggregate read demand placed by worker node ``dst`` on memory node
    ``src`` (GB/s requested, before contention)."""

    src: int
    dst: int
    gbps: float


def effective_bandwidth(
    topo: Topology,
    demands: Sequence[Demand],
) -> dict[tuple[int, int], float]:
    """Contention model: progressive filling of links and memory controllers.

    Each (src, dst) path is capped by its nominal link bandwidth
    ``topo.bw[src, dst]``; each memory controller ``src`` caps the *sum* of
    granted bandwidth over all paths out of it to ``topo.mc_bw[src]``
    (cross-node contention on the controller, paper §III-A3); links shared
    between paths (``topo.link_groups``) cap the sum over the group.

    Water-filling: repeatedly grant each unfrozen path its fair share of the
    most-constrained resource until all paths are frozen. This mirrors how
    hardware arbitration equalises throughput between same-priority readers.
    """
    paths = [(d.src, d.dst) for d in demands]
    want = {(d.src, d.dst): d.gbps for d in demands}
    grant = {p: 0.0 for p in paths}
    frozen: set[tuple[int, int]] = set()

    link_of = topo.link_groups or {}

    def resources() -> list[tuple[str, object, float, list[tuple[int, int]]]]:
        """(kind, key, capacity, member paths) for every constrained resource."""
        out = []
        # per-path nominal link cap
        for p in paths:
            out.append(("path", p, float(topo.bw[p[0], p[1]]), [p]))
        # memory controllers
        for src in topo.nodes():
            members = [p for p in paths if p[0] == src]
            if members:
                out.append(("mc", src, float(topo.mc_bw[src]), members))
        # shared links
        groups: dict[object, list[tuple[int, int]]] = {}
        for p in paths:
            if p in link_of:
                groups.setdefault(link_of[p], []).append(p)
        for key, members in groups.items():
            cap = min(float(topo.bw[m[0], m[1]]) for m in members)
            out.append(("link", key, cap, members))
        return out

    for _ in range(len(paths) + 2):  # converges in <= #paths rounds
        active = [p for p in paths if p not in frozen]
        if not active:
            break
        # headroom per resource divided by its number of active members
        fair = {p: float("inf") for p in active}
        for _, _, cap, members in resources():
            used = sum(grant[m] for m in members)
            live = [m for m in members if m not in frozen]
            if not live:
                continue
            share = max(cap - used, 0.0) / len(live)
            for m in live:
                fair[m] = min(fair[m], share)
        progressed = False
        for p in active:
            head = min(fair[p], want[p] - grant[p])
            if head <= 1e-9:
                frozen.add(p)
                continue
            grant[p] += head
            progressed = True
            if grant[p] >= want[p] - 1e-9:
                frozen.add(p)
        if not progressed:
            break
    return grant


def profile_bw(
    topo: Topology,
    workers: Sequence[int],
) -> np.ndarray:
    """The paper's profiling procedure (§III-A3), simulated.

    Deploy the canonical benchmark (random traversal of a shared array,
    uniform-all interleave, one thread per hardware thread of the worker set)
    and record per-(src,dst) achieved throughput. The canonical application is
    *extremely* BW-intensive (paper §III-A1), so every path is driven to
    saturation and the achieved per-path throughput — which is what hardware
    counters report and what the paper feeds into Eq. 5 — reflects contended
    path capacity, not nominal link numbers.

    Returns an (N, W) matrix of profiled bandwidths bw[src, worker_index].
    """
    n = topo.num_nodes
    saturating = 1e9  # canonical app requests far more than any path can give
    demands = [Demand(src=src, dst=dst, gbps=saturating)
               for dst in workers for src in range(n)]
    grant = effective_bandwidth(topo, demands)
    out = np.zeros((n, len(workers)))
    for j, dst in enumerate(workers):
        for src in range(n):
            out[src, j] = grant[(src, dst)]
    return out


def minbw(bw_profiled: np.ndarray) -> np.ndarray:
    """Eq. 4's minbw: per memory node, the weakest path to any worker.

    ``bw_profiled`` is (N, W): rows = memory nodes, cols = worker nodes.
    """
    return bw_profiled.min(axis=1)


def optimal_weights(bw_profiled: np.ndarray) -> np.ndarray:
    """Eq. 5 (Eq. 2 when W=1): weights proportional to minbw."""
    m = minbw(bw_profiled)
    total = m.sum()
    assert total > 0
    return m / total


def stall_cost(bytes_per_domain: np.ndarray,
               bandwidths_gbps: np.ndarray,
               *,
               tier_bytes: float = 0.0,
               tier_bw_gbps: float | None = None,
               link_bytes: np.ndarray | None = None,
               link_bw_gbps: np.ndarray | None = None,
               link_latency_s: np.ndarray | None = None) -> float:
    """Eq. 1's max-parallel-transfer time for one access batch.

    ``bytes_per_domain[d]`` bytes stream from domain ``d`` at
    ``bandwidths_gbps[d]`` GB/s; transfers from distinct domains overlap, so
    the stall is the slowest domain's transfer. This single scalar is what
    the serving stack scores with: the engine's per-step KV read time, the
    swap manager's transfer estimates, and the scheduler's victim selection
    all call it with different byte vectors.

    ``tier_bytes``/``tier_bw_gbps`` append one extra row for the persistent
    tier below the memory domains, so demotion/promotion/restore transfers
    are priced by the same max — the tier is just one more (slow) domain in
    Eq. 1, not a special case.

    ``link_bytes``/``link_bw_gbps``/``link_latency_s`` append one row per
    *cluster interconnect link* (prefill/decode disaggregation,
    DESIGN.md §13): a striped KV handoff streams ``link_bytes[l]`` over
    link ``l`` concurrently with the domain rows, each paying a fixed
    propagation latency on top of its serialization time — so a page wire
    is priced like any other asymmetric domain read, latency included.
    """
    b = np.asarray(bytes_per_domain, dtype=np.float64)
    bw = np.asarray(bandwidths_gbps, dtype=np.float64)
    assert b.shape == bw.shape and (bw > 0).all()
    lat = np.zeros_like(b)
    if tier_bytes > 0:
        assert tier_bw_gbps is not None and tier_bw_gbps > 0
        b = np.append(b, float(tier_bytes))
        bw = np.append(bw, float(tier_bw_gbps))
        lat = np.append(lat, 0.0)
    if link_bytes is not None:
        lb = np.asarray(link_bytes, dtype=np.float64)
        lbw = np.asarray(link_bw_gbps, dtype=np.float64)
        llat = (np.zeros_like(lb) if link_latency_s is None
                else np.asarray(link_latency_s, dtype=np.float64))
        assert lb.shape == lbw.shape == llat.shape and (lbw > 0).all()
        # latency applies only to rows that actually move bytes
        b = np.append(b, lb)
        bw = np.append(bw, lbw)
        lat = np.append(lat, np.where(lb > 0, llat, 0.0))
    if b.sum() <= 0:
        return 0.0
    return float((b / (bw * 1e9) + lat).max())


def move_cost(bytes_per_src_domain: np.ndarray,
              bandwidths_gbps: np.ndarray,
              dst_domain: int) -> float:
    """Eq.-1 price of re-homing a batch of pages into ``dst_domain``.

    ``bytes_per_src_domain[d]`` bytes are read out of source domain ``d``;
    reads from distinct sources overlap (the same max-parallel-transfer
    shape as :func:`stall_cost`), while every moved byte funnels into the
    one destination, so the write side is the *total* over the destination
    bandwidth. The slower side gates. Re-homing targets fast domains, so
    the read out of the slow source is normally the bottleneck — but a
    many-source batch into a modest destination flips that, and this max
    keeps the budget honest either way.
    """
    b = np.asarray(bytes_per_src_domain, dtype=np.float64)
    bw = np.asarray(bandwidths_gbps, dtype=np.float64)
    assert b.shape == bw.shape and (bw > 0).all()
    if b.sum() <= 0:
        return 0.0
    read = float((b / (bw * 1e9)).max())
    write = float(b.sum()) / (bw[dst_domain] * 1e9)
    return max(read, write)


def transfer_time(
    shared_gb: float,
    weights: np.ndarray,
    bw_profiled: np.ndarray,
) -> float:
    """Eq. 3: execution time of the canonical application = the slowest
    parallel transfer experienced by the slowest worker."""
    n, w = bw_profiled.shape
    t = 0.0
    for j in range(w):
        for i in range(n):
            if weights[i] <= 0:
                continue
            t = max(t, shared_gb * float(weights[i]) / float(bw_profiled[i, j]))
    return t
