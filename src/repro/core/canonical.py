"""Canonical tuner (paper §III-A): offline, application-agnostic weights.

For each plausible worker set of a topology, profile the canonical
BW-intensive application and derive the canonical weight distribution via
Eq. 5. Results are cached ("at installation time on a given machine", §III-A3)
and symmetry-deduplicated.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import pathlib
from typing import Iterable, Sequence

import numpy as np

from repro.core import bwmodel
from repro.core.topology import Topology


@dataclasses.dataclass(frozen=True)
class CanonicalEntry:
    workers: tuple[int, ...]
    weights: np.ndarray            # (N,) sums to 1
    bw_profiled: np.ndarray        # (N, W) profiled bandwidth matrix
    minbw: np.ndarray              # (N,)

    @property
    def worker_mass(self) -> float:
        return float(self.weights[list(self.workers)].sum())


class CanonicalTuner:
    """Computes and caches canonical weight distributions per worker set."""

    def __init__(self, topo: Topology):
        self.topo = topo
        self._cache: dict[tuple[int, ...], CanonicalEntry] = {}

    def weights_for(self, workers: Sequence[int]) -> CanonicalEntry:
        key = tuple(sorted(workers))
        if key not in self._cache:
            prof = bwmodel.profile_bw(self.topo, key)
            w = bwmodel.optimal_weights(prof)
            self._cache[key] = CanonicalEntry(
                workers=key, weights=w, bw_profiled=prof,
                minbw=bwmodel.minbw(prof))
        return self._cache[key]

    # -- installation-time sweep ------------------------------------------

    def plausible_worker_sets(self, max_size: int | None = None) -> list[tuple[int, ...]]:
        """Enumerate worker sets a rational user would pick (§III-A3):
        contiguous-bandwidth clusters, deduplicated by bandwidth symmetry.

        A set is *plausible* if no excluded node has strictly higher aggregate
        bandwidth to the set than some member (i.e. the set is a top-k
        bandwidth cluster around its members).
        """
        n = self.topo.num_nodes
        max_size = max_size or n
        seen_signatures: set[tuple] = set()
        out: list[tuple[int, ...]] = []
        for size in range(1, max_size + 1):
            for combo in itertools.combinations(range(n), size):
                if not self._is_cluster(combo):
                    continue
                sig = self._signature(combo)
                if sig in seen_signatures:
                    continue
                seen_signatures.add(sig)
                out.append(combo)
        return out

    def _is_cluster(self, combo: tuple[int, ...]) -> bool:
        if len(combo) == 1:
            return True
        inside = min(self._agg_bw(a, combo) for a in combo)
        outside = [self._agg_bw(b, combo) for b in range(self.topo.num_nodes)
                   if b not in combo]
        return not outside or inside >= max(outside) - 1e-9

    def _agg_bw(self, node: int, combo: Iterable[int]) -> float:
        pairs = [c for c in combo if c != node]
        if not pairs:
            return float("inf")
        return sum(float(self.topo.bw[node, c]) + float(self.topo.bw[c, node])
                   for c in pairs) / len(pairs)

    def _signature(self, combo: tuple[int, ...]) -> tuple:
        """Bandwidth-spectrum signature; symmetric worker sets collide."""
        rows = sorted(
            tuple(sorted(np.round(self.topo.bw[:, c], 3))) for c in combo)
        cols = sorted(
            tuple(sorted(np.round(self.topo.bw[c, :], 3))) for c in combo)
        return (tuple(rows), tuple(cols))

    def install(self, path: str | pathlib.Path, max_size: int | None = None) -> int:
        """Run the installation-time sweep and persist the weight cache."""
        sets = self.plausible_worker_sets(max_size)
        blob = {}
        for ws in sets:
            e = self.weights_for(ws)
            blob[",".join(map(str, ws))] = {
                "weights": e.weights.tolist(),
                "minbw": e.minbw.tolist(),
            }
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps({"topology": self.topo.name, "entries": blob},
                                indent=1))
        return len(sets)

    @staticmethod
    def load(path: str | pathlib.Path) -> dict[tuple[int, ...], np.ndarray]:
        raw = json.loads(pathlib.Path(path).read_text())
        return {tuple(int(x) for x in k.split(",")): np.asarray(v["weights"])
                for k, v in raw["entries"].items()}
