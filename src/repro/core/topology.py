"""Topology descriptions: NUMA machines (paper's machine A/B) and TPU systems.

The paper models a NUMA system as N nodes with an asymmetric bandwidth
function ``bw(n_src -> n_dst)``: the bandwidth a thread running on *worker*
node ``dst`` can use when reading from memory node ``src`` (paper §III-A2).

We keep exactly that abstraction, and extend it to TPU systems where the
"nodes" are *memory domains* (a chip's local HBM, pod-peer HBM at k ICI hops,
cross-pod HBM over DCI, host DRAM over PCIe) — see DESIGN.md §2.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import numpy as np

GB = 1e9  # bandwidth unit: bytes/s expressed in GB/s throughout core/


@dataclasses.dataclass(frozen=True)
class Topology:
    """A set of memory nodes with an asymmetric bandwidth matrix.

    Attributes:
      name: human-readable identifier.
      bw: (N, N) array, ``bw[src, dst]`` = GB/s a thread at node ``dst``
        reads from memory at node ``src`` (nominal, uncontended).
      mc_bw: (N,) per-node memory-controller aggregate bandwidth (GB/s).
        Caps the sum of all demand served by node ``src``.
      cores_per_node: hardware threads per node (for the simulator).
      link_groups: optional mapping of (src, dst) -> link id; paths sharing a
        link id contend for that link's bandwidth (interconnect congestion,
        paper §III-A3). By default each directed pair is its own link.
    """

    name: str
    bw: np.ndarray
    mc_bw: np.ndarray
    cores_per_node: int
    link_groups: dict | None = None

    @property
    def num_nodes(self) -> int:
        return int(self.bw.shape[0])

    def nodes(self) -> range:
        return range(self.num_nodes)

    def local_bw(self, n: int) -> float:
        return float(self.bw[n, n])

    def validate(self) -> None:
        assert self.bw.ndim == 2 and self.bw.shape[0] == self.bw.shape[1]
        assert (self.bw > 0).all(), "bandwidths must be positive"
        assert self.mc_bw.shape == (self.num_nodes,)


def _hop_matrix_machine_a() -> np.ndarray:
    """Hop counts for an 8-node, 4-socket Opteron 6272 (2 dies per socket).

    Dies (2i, 2i+1) share a socket (fast internal HT link). Sockets form a
    partially-connected square — some die pairs are directly connected,
    others need 2 hops, matching the strongly asymmetric topology of the
    paper's Fig. 1a (amplitude: lowest path BW 5.8x below local).
    """
    n = 8
    hops = np.full((n, n), 2, dtype=np.int64)
    np.fill_diagonal(hops, 0)
    direct = [
        (0, 1), (2, 3), (4, 5), (6, 7),          # intra-socket
        (0, 2), (1, 3), (4, 6), (5, 7),          # intra-board neighbours
        (0, 4), (1, 5),                          # cross-board links (few)
        (2, 6),
    ]
    for a, b in direct:
        hops[a, b] = hops[b, a] = 1
    return hops


def machine_a() -> Topology:
    """The paper's machine A: 8-node AMD Opteron 6272, 8 cores/node, 64 GB.

    Reconstructed from the paper's constraints (§IV): local:nearest BW ratio
    1.7x, local:farthest 5.1x, global amplitude (max/min incl. asymmetric
    directions) 5.8x. Absolute scale ~ Opteron-era STREAM numbers.
    """
    local = 12.0  # GB/s per-node local memory bandwidth
    hops = _hop_matrix_machine_a()
    n = hops.shape[0]
    bw = np.zeros((n, n))
    for s, d in itertools.product(range(n), range(n)):
        if s == d:
            bw[s, d] = local
        elif hops[s, d] == 1:
            bw[s, d] = local / 1.7          # ~7.06
        else:
            bw[s, d] = local / 5.1          # ~2.35
    # Directional asymmetry: several HT links are narrower in one direction
    # (paper: "possibly distinct BWs for each communication direction").
    for s, d, f in [(3, 1, 0.85), (5, 4, 0.9), (7, 2, 0.88), (6, 0, 0.88),
                    (2, 7, 0.95), (1, 6, 0.92)]:
        bw[s, d] *= f
    # weakest direction hits local/5.8
    bw[7, 0] = local / 5.8
    mc = np.full(n, local * 1.6)  # controller serves local+remote readers
    return Topology(name="machineA", bw=bw, mc_bw=mc, cores_per_node=8)


def machine_b() -> Topology:
    """The paper's machine B: 2-socket Xeon E5-2660 v4, Cluster-on-Die,
    4 NUMA nodes, 7 cores/node, 32 GB. Milder asymmetry: local:nearest 1.8x,
    amplitude 2.3x.
    """
    local = 30.0
    n = 4
    bw = np.zeros((n, n))
    same_socket = {(0, 1), (1, 0), (2, 3), (3, 2)}
    for s, d in itertools.product(range(n), range(n)):
        if s == d:
            bw[s, d] = local
        elif (s, d) in same_socket:
            bw[s, d] = local / 1.8          # ~16.7
        else:
            bw[s, d] = local / 2.3          # ~13.0 (QPI cross-socket)
    mc = np.full(n, local * 1.3)
    return Topology(name="machineB", bw=bw, mc_bw=mc, cores_per_node=7)


# ---------------------------------------------------------------------------
# TPU memory-domain topologies
# ---------------------------------------------------------------------------

#: TPU v5e hardware constants (also used by roofline/).
V5E_PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
V5E_HBM_BW = 819.0               # GB/s per chip
V5E_ICI_BW = 50.0                # GB/s per ICI link per direction
V5E_DCI_BW = 12.5                # GB/s effective per-chip cross-pod (optical/DCN)
V5E_PCIE_BW = 16.0               # GB/s host<->chip


@dataclasses.dataclass(frozen=True)
class TpuDomainSpec:
    """One memory domain visible to a worker chip (DESIGN.md §2 table)."""

    name: str
    capacity_gb: float
    # bandwidth from this domain to each worker chip is derived by the
    # builder below and stored in the Topology matrix.


def tpu_domains_topology(
    *,
    num_pods: int = 2,
    worker_pod: int = 0,
    ici_hops_tiers: Sequence[int] = (1, 2, 4),
    hbm_gb: float = 16.0,
) -> tuple[Topology, list[str], list[int]]:
    """Build a BWAP ``Topology`` over TPU memory domains for one worker chip
    group.

    Domains (in order):
      0: local HBM of the worker chips            bw = HBM
      1..k: pod-peer HBM reachable at h ICI hops  bw = ICI / h
      k+1..: remote-pod HBM (per extra pod)       bw = DCI
      last: host DRAM                             bw = PCIe

    Returns (topology, domain names, worker domain indices). The Topology is
    degenerate-NUMA: every worker reads through the same domain list, so the
    bw matrix has identical columns — which is exactly the single-worker
    special case of the paper (Eq. 2). Multi-partition co-scheduling builds
    one topology per partition with shifted tiers.
    """
    names = ["hbm_local"]
    bws = [V5E_HBM_BW]
    caps = [hbm_gb]
    for h in ici_hops_tiers:
        names.append(f"hbm_peer_{h}hop")
        bws.append(V5E_ICI_BW / h)
        caps.append(hbm_gb)
    for p in range(num_pods):
        if p == worker_pod:
            continue
        names.append(f"hbm_pod{p}")
        bws.append(V5E_DCI_BW)
        caps.append(hbm_gb)
    names.append("host_dram")
    bws.append(V5E_PCIE_BW)
    caps.append(512.0)

    n = len(names)
    bw = np.tile(np.asarray(bws)[:, None], (1, n))  # bw[src, dst] same per dst
    mc = np.asarray([V5E_HBM_BW] * (n - 1) + [100.0])
    topo = Topology(name=f"tpu_v5e_{num_pods}pod", bw=bw, mc_bw=mc,
                    cores_per_node=1)
    return topo, names, [0]
