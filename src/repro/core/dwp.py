"""DWP tuner (paper §III-B): online 1-D hill climbing on a stall-rate stream.

The tuner is deliberately decoupled from *what* is being measured: the paper
reads hardware stall-cycle counters; our TPU serving integration feeds decode
step latencies; the simulator feeds modelled stall rates. Parameters follow
the paper (§IV): n=20 measurements per period, discard first/last c=5 as
outliers, t=0.2 s sampling interval, step x=10%.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Sequence

import numpy as np

from repro.core import interleave


class Phase(enum.Enum):
    MEASURING = "measuring"
    DONE = "done"


@dataclasses.dataclass
class DWPConfig:
    n: int = 20            # samples per measurement period
    c: int = 5             # discard the c smallest and c largest samples
    t: float = 0.2         # seconds between samples (informational on TPU)
    x: float = 0.10        # DWP step
    rel_tolerance: float = 0.0  # stall-rate must drop by > tol to continue


def filtered_mean(samples: Sequence[float], c: int) -> float:
    """Sort, drop the first and last c, average the rest (paper §III-B1)."""
    s = np.sort(np.asarray(samples, dtype=np.float64))
    if len(s) > 2 * c:
        s = s[c:len(s) - c]
    return float(s.mean())


@dataclasses.dataclass
class TunerStep:
    dwp: float
    stall_rate: float
    migrated_pages: int


class DWPTuner:
    """Incremental hill climbing over DWP, migrating pages at each step.

    Usage::

        tuner = DWPTuner(canonical_weights, workers, num_pages)
        while not tuner.done:
            tuner.record(measure_stall_rate())   # n times per period
        placement = tuner.assignment             # final page table

    ``on_migrate`` is called with each MigrationPlan so the embedding system
    (simulator page tables, KV-cache pools, ZeRO shards) can execute it.

    ``capacity_fractions`` (optional) are per-node shares of the allocatable
    pool; when set, every assignment the tuner produces is clamped to them
    (``interleave.capacity_capped_weights``) — the swap-aware fix: a page
    pool holding a swap reservation feeds its *effective* capacities here so
    a high DWP cannot promise pages the reservation took away.
    """

    def __init__(
        self,
        canonical_weights: np.ndarray,
        workers: Sequence[int],
        num_pages: int,
        config: DWPConfig | None = None,
        on_migrate: Callable[[interleave.MigrationPlan], None] | None = None,
        start_dwp: float = 0.0,
        min_dwp: float = 0.0,
        capacity_fractions: np.ndarray | None = None,
    ):
        self.cfg = config or DWPConfig()
        self.canonical = interleave.normalize(canonical_weights)
        self.workers = tuple(workers)
        self.on_migrate = on_migrate
        self.min_dwp = min_dwp
        self.dwp = max(start_dwp, min_dwp)
        self.capacity_fractions = capacity_fractions
        self.assignment = interleave.weighted_interleave(
            num_pages, self._capped(interleave.dwp_weights(
                self.canonical, self.workers, self.dwp)))
        self.phase = Phase.MEASURING
        self._samples: list[float] = []
        self._prev_rate: float | None = None
        self._prev_assignment: np.ndarray | None = None
        self.history: list[TunerStep] = []

    # -- measurement stream -------------------------------------------------

    @property
    def done(self) -> bool:
        return self.phase is Phase.DONE

    def record(self, stall_rate: float) -> None:
        """Feed one stall-rate sample; advances DWP when a period completes."""
        if self.done:
            return
        self._samples.append(float(stall_rate))
        if len(self._samples) >= self.cfg.n:
            rate = filtered_mean(self._samples, self.cfg.c)
            self._samples = []
            self._on_period(rate)

    # -- hill climbing --------------------------------------------------------

    def _on_period(self, rate: float) -> None:
        if self._prev_rate is not None and not self._improved(rate):
            # Local optimum found. Roll back the last (non-improving) step:
            # the paper stops at the previous DWP ("maximum error margin of
            # 1 iterative step", §IV-B); migration both ways is supported in
            # our implementation (unlike mbind), so we restore it.
            if self._prev_assignment is not None:
                self._apply_assignment(self._prev_assignment)
                self.dwp = self._prev_dwp
            self.phase = Phase.DONE
            return
        migrated = 0
        if self.dwp + self.cfg.x <= 1.0 + 1e-9:
            self._prev_rate = rate
            self._prev_assignment = self.assignment.copy()
            self._prev_dwp = self.dwp
            self.dwp = min(self.dwp + self.cfg.x, 1.0)
            migrated = self._migrate_to(self.dwp)
        else:
            self.phase = Phase.DONE
        self.history.append(TunerStep(self.dwp, rate, migrated))

    def _improved(self, rate: float) -> bool:
        assert self._prev_rate is not None
        return rate < self._prev_rate * (1.0 - self.cfg.rel_tolerance)

    def _capped(self, weights: np.ndarray) -> np.ndarray:
        if self.capacity_fractions is None:
            return weights
        return interleave.capacity_capped_weights(weights,
                                                  self.capacity_fractions)

    def set_capacity_fractions(self, fractions: np.ndarray) -> int:
        """Effective capacities changed (a swap reservation was carved out
        or released): re-clamp the current assignment. Returns pages moved
        (delivered to ``on_migrate`` like any tuner step)."""
        self.capacity_fractions = np.asarray(fractions, dtype=np.float64)
        return self._migrate_to(self.dwp)

    def _migrate_to(self, dwp: float) -> int:
        new_w = self._capped(
            interleave.dwp_weights(self.canonical, self.workers, dwp))
        plan = interleave.plan_migration(self.assignment, new_w)
        self.assignment = plan.new_assignment
        if self.on_migrate:
            self.on_migrate(plan)
        return plan.num_moves

    def _apply_assignment(self, assignment: np.ndarray) -> None:
        changed = np.nonzero(assignment != self.assignment)[0]
        moves = np.stack([changed, self.assignment[changed],
                          assignment[changed]], axis=1)
        plan = interleave.MigrationPlan(
            moves=moves, old_assignment=self.assignment,
            new_assignment=assignment)
        self.assignment = assignment
        if self.on_migrate:
            self.on_migrate(plan)


# ---------------------------------------------------------------------------
# Co-scheduled variant (paper §III-B3): 2-stage search
# ---------------------------------------------------------------------------

class CoScheduledTuner:
    """Two applications in disjoint partitions: a high-priority A (not
    memory-intensive) and a best-effort B (memory-intensive, uses BWAP).

    Stage 1: increase B's DWP while *A's* stall rate keeps decreasing; when A
    stabilises we have a lower bound on B's DWP (B must not push more pages
    onto A's nodes than that). Stage 2: standard DWP search for B, starting
    at — and never going below — the bound.
    """

    def __init__(self, canonical_weights: np.ndarray, workers_b: Sequence[int],
                 num_pages: int, config: DWPConfig | None = None,
                 on_migrate=None):
        self.cfg = config or DWPConfig()
        self.stage = 1
        self._tuner = DWPTuner(canonical_weights, workers_b, num_pages,
                               config=self.cfg, on_migrate=on_migrate)
        self._samples_a: list[float] = []
        self._prev_a: float | None = None
        self.dwp_lower_bound = 0.0

    @property
    def done(self) -> bool:
        return self.stage == 2 and self._tuner.done

    @property
    def dwp(self) -> float:
        return self._tuner.dwp

    @property
    def assignment(self) -> np.ndarray:
        return self._tuner.assignment

    def record(self, stall_a: float, stall_b: float) -> None:
        if self.done:
            return
        if self.stage == 1:
            self._samples_a.append(stall_a)
            if len(self._samples_a) >= self.cfg.n:
                rate_a = filtered_mean(self._samples_a, self.cfg.c)
                self._samples_a = []
                improving = self._prev_a is None or rate_a < self._prev_a * \
                    (1.0 - self.cfg.rel_tolerance)
                self._prev_a = rate_a
                if improving and self._tuner.dwp + self.cfg.x <= 1.0:
                    self._tuner.dwp += self.cfg.x
                    self._tuner._migrate_to(self._tuner.dwp)
                else:
                    # A stabilised: freeze the bound, hand over to stage 2.
                    self.dwp_lower_bound = self._tuner.dwp
                    self._tuner.min_dwp = self.dwp_lower_bound
                    self._tuner._prev_rate = None
                    self._tuner._prev_assignment = None
                    self.stage = 2
        else:
            self._tuner.record(stall_b)
