"""Weighted page interleaving (paper Alg. 1) + page tables + migration plans.

Mainstream kernels (and XLA's GSPMD, analogously) only provide *uniform*
interleaving over a node set. Alg. 1 emulates arbitrary weights by splitting
a segment into sub-ranges and uniformly interleaving sub-range k over the
nodes whose weight exceeds the k-th smallest weight; sub-range sizes are
chosen so aggregate per-node ratios match the target weights.

We implement it at page granularity: the unit is a page index, the output is
a page table ``assignment[page] -> node``. The same code places 4 KB NUMA
pages in the simulator and KV-cache / optimizer-state pages across TPU memory
domains.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


def normalize(weights: np.ndarray) -> np.ndarray:
    w = np.asarray(weights, dtype=np.float64)
    assert (w >= 0).all(), "weights must be non-negative"
    s = w.sum()
    assert s > 0, "at least one positive weight"
    return w / s


def uniform_interleave(num_pages: int, nodes: Sequence[int],
                       start_page: int = 0) -> np.ndarray:
    """Round-robin pages over ``nodes`` (the mbind/MPOL_INTERLEAVE analogue)."""
    nodes = np.asarray(list(nodes), dtype=np.int64)
    idx = (start_page + np.arange(num_pages)) % len(nodes)
    return nodes[idx]


def weighted_interleave(num_pages: int, weights: np.ndarray) -> np.ndarray:
    """Alg. 1: user-level weighted interleaving approximation.

    Walks nodes from the lowest weight upward; at each step, a sub-range of
    ``len(remaining) * (w_k - w_{k-1}) * num_pages`` pages is uniformly
    interleaved over the remaining node set, then the minimum-weight node is
    dropped. Telescoping guarantees the sub-range sizes sum to num_pages and
    per-node totals are proportional to the weights.
    """
    w = normalize(weights)
    n = len(w)
    order = np.argsort(w, kind="stable")           # getNodeWithMinWeight
    assignment = np.full(num_pages, -1, dtype=np.int64)
    remaining = list(order)                        # nodes, min weight first
    address = 0
    w_prev = 0.0
    exact = 0.0                                    # running exact boundary
    for k in range(n):
        node = remaining[0]
        step = float(w[node]) - w_prev
        exact += len(remaining) * step * num_pages
        size = (min(int(round(exact)), num_pages) - address) if k < n - 1 \
            else num_pages - address
        if size > 0:
            live = sorted(remaining)
            assignment[address:address + size] = uniform_interleave(
                size, live, start_page=address)
            address += size
        remaining.pop(0)
        w_prev = float(w[node])
    assert address == num_pages and (assignment >= 0).all()
    return assignment


def page_fractions(assignment: np.ndarray, num_nodes: int) -> np.ndarray:
    counts = np.bincount(assignment, minlength=num_nodes).astype(np.float64)
    return counts / max(len(assignment), 1)


# ---------------------------------------------------------------------------
# DWP-scaled weights and incremental migration (paper §III-B1/2)
# ---------------------------------------------------------------------------

def dwp_weights(canonical: np.ndarray, workers: Sequence[int],
                dwp: float) -> np.ndarray:
    """Scale the canonical distribution by the data-to-worker-proximity scalar.

    DWP=0 -> canonical weights. DWP=1 -> all pages on the worker set. The
    scaling preserves *relative* weights inside the worker and non-worker
    clusters (Observation 3): worker weights are multiplied by a common
    coefficient, and likewise the non-worker weights.
    """
    assert 0.0 <= dwp <= 1.0
    w = normalize(canonical)
    mask = np.zeros(len(w), dtype=bool)
    mask[list(workers)] = True
    ww = w[mask].sum()
    target_ww = ww + dwp * (1.0 - ww)
    out = np.zeros_like(w)
    if ww > 0:
        # divide first: w[mask]/ww is well-conditioned even for subnormal
        # cluster masses (target_ww/ww can overflow to inf)
        out[mask] = (w[mask] / ww) * target_ww
    else:  # degenerate: canonical put nothing on workers
        out[mask] = target_ww / mask.sum()
    nw = 1.0 - ww
    if nw > 0:
        out[~mask] = (w[~mask] / nw) * (1.0 - target_ww)
    return normalize(np.maximum(out, 0.0))  # guard fp cancellation at dwp=1


def capacity_capped_weights(weights: np.ndarray,
                            capacity_fractions: np.ndarray) -> np.ndarray:
    """Clamp a weight vector to per-node capacity fractions, water-filling
    the excess onto unclamped nodes (∝ their remaining weight).

    ``capacity_fractions[d]`` is node d's share of the *allocatable* pool
    (capacities sum to 1). The result never asks a node for more than its
    share — the swap-aware DWP fix: a high DWP must not promise fast-domain
    pages that a swap reservation (or small domain) cannot supply.
    """
    w = normalize(weights)
    cap = np.asarray(capacity_fractions, dtype=np.float64)
    assert w.shape == cap.shape and (cap >= 0).all()
    if cap.sum() < 1.0 - 1e-9:          # infeasible: fill to capacity shape
        return normalize(cap)
    fixed = np.zeros(len(w), dtype=bool)
    for _ in range(len(w)):
        over = (w > cap + 1e-12) & ~fixed
        if not over.any():
            break
        excess = float((w[over] - cap[over]).sum())
        w = w.copy()
        w[over] = cap[over]
        fixed |= over
        free = ~fixed
        mass = float(w[free].sum())
        if mass > 0:
            w[free] += excess * w[free] / mass
        else:                            # zero-weight free nodes: fill by
            head = cap[free] - w[free]   # remaining capacity headroom
            if np.isinf(head).any():     # uncapped nodes split it evenly
                even = np.isinf(head).astype(np.float64)
                w[free] += excess * even / even.sum()
            else:
                w[free] += excess * head / max(float(head.sum()), 1e-300)
    return normalize(w)


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    """Pages to move when re-interleaving from one weight vector to another.

    ``moves[i] = (page, src_node, dst_node)``. The plan is *incremental*: only
    pages whose assignment changed are touched (mbind MPOL_MF_MOVE semantics).
    """

    moves: np.ndarray            # (M, 3) int64
    old_assignment: np.ndarray
    new_assignment: np.ndarray

    @property
    def num_moves(self) -> int:
        return int(self.moves.shape[0])

    def moved_fraction(self) -> float:
        return self.num_moves / max(len(self.old_assignment), 1)


def plan_migration(old_assignment: np.ndarray,
                   new_weights: np.ndarray) -> MigrationPlan:
    """Re-run Alg. 1 for the new weights and diff the page tables."""
    new_assignment = weighted_interleave(len(old_assignment), new_weights)
    changed = np.nonzero(new_assignment != old_assignment)[0]
    moves = np.stack([changed, old_assignment[changed],
                      new_assignment[changed]], axis=1)
    return MigrationPlan(moves=moves, old_assignment=old_assignment,
                         new_assignment=new_assignment)
