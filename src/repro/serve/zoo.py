"""Zoo serving: per-group drivers over one byte arena (DESIGN.md §12).

The chat transformer runs the full :class:`~repro.serve.engine.ServeEngine`
(continuous batching, chunked prefill, PR 8's per-domain micro-batch decode
launches all ride along unchanged — the engine only ever sees its own
group's FabricView).  The other residents of the machine are not
transformers and need no scheduler: an SSM tenant's "sequence" is one
constant-size state page mutated in place every step, an ASR tenant's
encoder K/V is written once per utterance and then only read.  Each gets
a small deterministic driver that exercises exactly the placement surface
its geometry defines — allocate, touch, fork-by-copy, attach-by-refcount,
release — and produces a content digest read back from the actual pool
arrays, so the zoo benchmark can assert data integrity ("token identity"
for groups that emit no tokens) across market-driven funding moves.

:class:`ZooServer` steps everything and runs the capacity market: after
each round it reports every engine group's unfunded demand
(``scheduler.demand_pages()`` in bytes) to the
:class:`~repro.placement.zoo.PageFabricZoo` and ticks the market, so a
chat burst annexes idle ASR/SSM funding mid-run and repays it as it
drains — with ``market=False`` the same server is the static-partition
baseline.
"""

from __future__ import annotations

import numpy as np

from repro.placement.zoo import PageFabricZoo


class SSMStateDriver:
    """Constant-state tenant: ``sessions`` live recurrences, one state
    page each (the geometry pins ``fixed_pages=1``).  Every step folds a
    deterministic per-session injection into the state *in place* —
    never appending — so the page list never changes while the bytes do.
    The update depends only on (session index, step count), never on
    page ids or domains: digests are invariant under placement and
    funding changes, which is exactly what the zoo benchmark asserts."""

    def __init__(self, view, sessions: int):
        self.view = view
        self.sessions: list[list[int]] = []
        self.steps = 0
        for _ in range(sessions):
            pages: list[int] = []
            for _ in range(view.geometry.fixed_pages):
                view.append_page(pages)
            self.sessions.append(pages)

    def step(self) -> None:
        """One recurrence step over every session's state page."""
        self.steps += 1
        pids = np.asarray([p[0] for p in self.sessions], dtype=np.int32)
        inject = np.asarray(
            [((i + 1) * self.steps) % 7 * 0.125
             for i in range(len(self.sessions))], dtype=np.float32)
        k = self.view.k_pool
        bshape = (1, len(pids)) + (1,) * (k.ndim - 2)
        self.view.k_pool = k.at[:, pids].set(
            k[:, pids] * 0.5 + inject.reshape(bshape).astype(k.dtype))

    def fork(self, idx: int) -> list[int]:
        """Clone one session (state copy, not CoW — geometry is
        non-shareable) and track it as a new live session."""
        clone = self.view.fork_sequence(self.sessions[idx])
        self.sessions.append(clone)
        return clone

    def digests(self) -> list[float]:
        """Per-session state checksums read back from the pool arrays."""
        k = np.asarray(self.view.k_pool, dtype=np.float64)
        return [round(float(k[:, p[0]].sum()), 6) for p in self.sessions]

    def close(self) -> None:
        for pages in self.sessions:
            self.view.release(pages)
        self.sessions.clear()


class EncoderKVDriver:
    """Read-only encoder cross-attention K/V tier: each utterance is a
    fixed ``geometry.fixed_pages`` block written once (deterministic
    content from the utterance index), after which decode sessions
    attach by refcount (``fork_sequence`` on a shareable geometry) and
    detach by release — the shareable-tier analog of the prefix trie."""

    def __init__(self, view, utterances: int):
        self.view = view
        self.utterances: list[list[int]] = []
        self.readers: list[list[int]] = []
        for u in range(utterances):
            pages: list[int] = []
            for _ in range(view.geometry.fixed_pages):
                view.append_page(pages)
            pids = np.asarray(pages, dtype=np.int32)
            k = self.view.k_pool
            fill = np.float32((u + 1) * 0.0625)
            self.view.k_pool = k.at[:, pids].set(fill.astype(k.dtype))
            self.utterances.append(pages)

    def attach(self, u: int) -> list[int]:
        """A decode session starts reading utterance ``u``: refcount
        attach, no copy, no new pages."""
        reader = self.view.fork_sequence(self.utterances[u])
        self.readers.append(reader)
        return reader

    def digests(self) -> list[float]:
        k = np.asarray(self.view.k_pool, dtype=np.float64)
        return [round(float(sum(k[:, p].sum() for p in pages)), 6)
                for pages in self.utterances]

    def close(self) -> None:
        for reader in self.readers:
            self.view.release(reader)
        for pages in self.utterances:
            self.view.release(pages)
        self.readers.clear()
        self.utterances.clear()


class ZooServer:
    """Steps every group and runs the capacity market between them."""

    def __init__(self, zoo: PageFabricZoo, *, market: bool = True,
                 invariants_every: int = 8):
        self.zoo = zoo
        self.market = market
        self.engines: dict[str, object] = {}
        self.drivers: dict[str, object] = {}
        self.steps = 0
        self.invariants_every = invariants_every

    def add_engine(self, name: str, engine) -> None:
        assert name in self.zoo.groups, f"unknown zoo group {name!r}"
        self.engines[name] = engine

    def add_driver(self, name: str, driver) -> None:
        assert name in self.zoo.groups, f"unknown zoo group {name!r}"
        self.drivers[name] = driver

    def busy(self) -> bool:
        return any(eng.active or eng.waiting
                   for eng in self.engines.values())

    def demand_bytes(self, name: str) -> int:
        """An engine group's unfunded demand; driver groups (constant
        footprint, already resident) are always satisfied."""
        eng = self.engines.get(name)
        if eng is None:
            return 0
        return eng.scheduler.demand_pages() \
            * int(self.zoo.groups[name].page_bytes)

    def step(self) -> dict:
        """One zoo round: drivers tick, engines step, the market clears."""
        self.steps += 1
        for driver in self.drivers.values():
            if hasattr(driver, "step"):
                driver.step()
        for eng in self.engines.values():
            if eng.active or eng.waiting:
                eng.step()
        flows = {"granted_bytes": 0, "repaid_bytes": 0}
        if self.market:
            for name in self.zoo.groups:
                self.zoo.observe_demand(name, self.demand_bytes(name))
            flows = self.zoo.market_tick()
        if self.invariants_every \
                and self.steps % self.invariants_every == 0:
            self.zoo.check_invariants()
        return flows

    def drain(self, max_steps: int = 3000) -> int:
        """Step until every engine is idle (drivers are perpetual — they
        tick alongside but never gate completion)."""
        steps = 0
        while self.busy() and steps < max_steps:
            self.step()
            steps += 1
        if self.market:
            # burst over: let the market settle repayments
            for name in self.zoo.groups:
                self.zoo.observe_demand(name, self.demand_bytes(name))
            self.zoo.market_tick()
        self.zoo.check_invariants()
        return steps
