"""Deprecated compatibility shim: the physical page pool moved into the
placement package (``repro.placement.pool``) when the memory-fabric API
landed (DESIGN.md §8). Import sites in serve/scheduler go through
:class:`repro.placement.fabric.FabricView` now; this module only keeps the
old import path alive for external callers, tests, and benchmarks — and
warns once per process so they migrate."""

import warnings

from repro.placement.pool import (BwapPagePool, MemoryDomain,  # noqa: F401
                                  default_domains)

warnings.warn(
    "repro.serve.kvcache is deprecated: import BwapPagePool/MemoryDomain/"
    "default_domains from repro.placement.pool (serving code should go "
    "through repro.placement.fabric.FabricView, DESIGN.md §8)",
    DeprecationWarning, stacklevel=2)
