"""BWAP-paged KV cache: weighted page placement across memory domains.

The paper's mechanism, applied to serving: decode-time KV pages live in a
pool that spans memory *domains* of asymmetric bandwidth (local HBM, pod-peer
HBM over ICI, cross-pod HBM over DCI, host DRAM — topology.tpu_domains_topology).
Placement of new pages follows the canonical weights (Eq. 2/5: w_d ∝ bw_d);
the DWP tuner shifts the worker-local fraction online from measured decode
latencies, migrating pages between domains exactly like mbind page migration.

Physically the pool is one array [total_pages, page_size, nkv, hd] per layer;
domain d owns the contiguous page-id range [offset_d, offset_d + n_d), so the
paged_attention kernel (kernels/paged_attention) is domain-oblivious and the
page table *is* the placement.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bwmodel, interleave
from repro.core.dwp import DWPConfig, DWPTuner
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class MemoryDomain:
    name: str
    num_pages: int
    read_bw: float       # GB/s toward the worker chips
    is_worker: bool      # counts as "worker node" for DWP


def default_domains(total_pages: int) -> list[MemoryDomain]:
    """A 2-pod serving deployment's domain mix (DESIGN.md §2 table)."""
    from repro.core import topology as topo
    n = total_pages
    return [
        MemoryDomain("hbm_local", int(n * 0.35), topo.V5E_HBM_BW, True),
        MemoryDomain("hbm_peer_1hop", int(n * 0.25), topo.V5E_ICI_BW, False),
        MemoryDomain("hbm_peer_2hop", int(n * 0.20), topo.V5E_ICI_BW / 2,
                     False),
        MemoryDomain("hbm_pod1", int(n * 0.10), topo.V5E_DCI_BW, False),
        MemoryDomain("host_dram", n - int(n * 0.35) - int(n * 0.25)
                     - int(n * 0.20) - int(n * 0.10), topo.V5E_PCIE_BW,
                     False),
    ]


class BwapPagePool:
    """Paged KV storage with BWAP placement. One pool per model (layers
    stacked on axis 0 so a layer's pool is pool[l])."""

    def __init__(self, cfg: ModelConfig, domains: Sequence[MemoryDomain],
                 page_size: int = 16, dwp_config: DWPConfig | None = None,
                 seed: int = 0):
        self.cfg = cfg
        self.domains = list(domains)
        self.page_size = page_size
        self.total_pages = sum(d.num_pages for d in self.domains)
        self.offsets = np.cumsum([0] + [d.num_pages for d in self.domains])
        cdt = jnp.dtype(cfg.compute_dtype)
        nl, nkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim_
        self.k_pool = jnp.zeros((nl, self.total_pages, page_size, nkv, hd),
                                cdt)
        self.v_pool = jnp.zeros_like(self.k_pool)
        self.free: list[list[int]] = [
            list(range(self.offsets[i], self.offsets[i + 1]))
            for i in range(len(self.domains))]

        # canonical weights over domains (Eq. 2: single worker group)
        bw = np.asarray([d.read_bw for d in self.domains])
        self.canonical = bw / bw.sum()
        workers = [i for i, d in enumerate(self.domains) if d.is_worker]
        self.tuner = DWPTuner(self.canonical, workers,
                              num_pages=4096,  # allocation-cycle resolution
                              config=dwp_config or DWPConfig(n=8, c=2),
                              on_migrate=lambda plan: None)
        self._cycle_pos = 0
        # Alg. 1 lays sub-ranges out contiguously (uniform region first); an
        # allocation cycle must be stationary, so walk it in a fixed shuffle.
        self._perm = np.random.default_rng(seed).permutation(4096)

    # -- placement ----------------------------------------------------------

    @property
    def weights(self) -> np.ndarray:
        return interleave.dwp_weights(self.canonical, self.tuner.workers,
                                      self.tuner.dwp)

    def domain_of(self, page_id: int) -> int:
        return int(np.searchsorted(self.offsets, page_id, side="right") - 1)

    def alloc_page(self) -> int:
        """Next page id, following the weighted allocation cycle (Alg. 1
        pattern over the tuner's current assignment); falls back to the
        closest domain with free pages."""
        cycle = self.tuner.assignment
        for _ in range(len(cycle)):
            want = int(cycle[self._perm[self._cycle_pos % len(self._perm)]])
            self._cycle_pos += 1
            if self.free[want]:
                return self.free[want].pop()
        for i in np.argsort(-np.asarray(
                [d.read_bw for d in self.domains])):
            if self.free[i]:
                return self.free[int(i)].pop()
        raise RuntimeError("KV pool exhausted")

    def free_pages(self, pages: Sequence[int]):
        for pid in pages:
            self.free[self.domain_of(pid)].append(int(pid))

    # -- data path ------------------------------------------------------------

    def write_token(self, layer_slot_kv: tuple, page_id: int, slot: int):
        """Write one token's K/V across all layers: layer_slot_kv =
        (k [L,nkv,hd], v [L,nkv,hd])."""
        k, v = layer_slot_kv
        self.k_pool = self.k_pool.at[:, page_id, slot].set(k)
        self.v_pool = self.v_pool.at[:, page_id, slot].set(v)

    # -- DWP tuning / migration -------------------------------------------------

    def record_latency(self, seconds: float):
        """Feed a decode-step latency sample; executes migrations when the
        tuner moves DWP (pages are re-homed between domain ranges)."""
        before = self.tuner.assignment.copy()
        self.tuner.record(seconds)
        after = self.tuner.assignment
        if not np.array_equal(before, after):
            return True  # cycle changed; future allocations follow it
        return False

    def migrate_sequence(self, page_ids: list[int]) -> list[int]:
        """Re-place an existing sequence's pages per the current weights
        (the incremental migration of §III-B2): returns new page ids."""
        target = interleave.weighted_interleave(len(page_ids), self.weights)
        new_ids = []
        moved = 0
        for pid, dom in zip(page_ids, target):
            cur = self.domain_of(pid)
            if cur == int(dom) or not self.free[int(dom)]:
                new_ids.append(pid)
                continue
            nid = self.free[int(dom)].pop()
            self.k_pool = self.k_pool.at[:, nid].set(self.k_pool[:, pid])
            self.v_pool = self.v_pool.at[:, nid].set(self.v_pool[:, pid])
            self.free[cur].append(pid)
            new_ids.append(nid)
            moved += 1
        return new_ids

    # -- analytics ---------------------------------------------------------------

    def occupancy(self) -> dict[str, float]:
        out = {}
        for i, d in enumerate(self.domains):
            used = d.num_pages - len(self.free[i])
            out[d.name] = used / max(d.num_pages, 1)
        return out

    def expected_read_time(self, page_ids: Sequence[int]) -> float:
        """Analytic per-token KV read time for a sequence (the max-parallel-
        transfer model of Eq. 1): bytes per domain / domain bw, max."""
        nkv, hd = self.cfg.num_kv_heads, self.cfg.head_dim_
        bytes_per_page = 2 * self.page_size * nkv * hd * 2  # k+v bf16
        per_domain = np.zeros(len(self.domains))
        for pid in page_ids:
            per_domain[self.domain_of(pid)] += bytes_per_page
        per_domain *= self.cfg.num_layers
        times = per_domain / (np.asarray(
            [d.read_bw for d in self.domains]) * 1e9)
        return float(times.max()) if len(page_ids) else 0.0
