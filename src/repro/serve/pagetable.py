"""Deprecated compatibility shim: the logical page table moved into the
placement package (``repro.placement.pagetable``) when the memory-fabric
API landed (DESIGN.md §8). Import sites in serve/scheduler go through
:class:`repro.placement.fabric.FabricView` now; this module only keeps the
old import path alive for external callers, tests, and benchmarks — and
warns once per process so they migrate."""

import warnings

from repro.placement.pagetable import ROOT, PageTable  # noqa: F401

warnings.warn(
    "repro.serve.pagetable is deprecated: import ROOT/PageTable from "
    "repro.placement.pagetable (serving code should go through "
    "repro.placement.fabric.FabricView, DESIGN.md §8)",
    DeprecationWarning, stacklevel=2)
