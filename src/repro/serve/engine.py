"""Batched serving engine over the BWAP page pool (dense GQA archs).

CPU-runnable end-to-end: continuous batching, paged prefill + decode through
kernels/paged_attention (reference impl on CPU, Pallas on TPU), BWAP
placement of fresh pages, and online DWP tuning fed by measured step
latencies. examples/serve_paged.py drives it.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention import ops as paged_ops
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.lm import LM
from repro.serve.kvcache import BwapPagePool


@dataclasses.dataclass
class Sequence_:
    sid: int
    tokens: list
    pages: list            # page ids, in order
    prompt_len: int = 0
    length: int = 0        # tokens with K/V materialized in the pool
    done: bool = False

    @property
    def produced(self) -> int:
        return len(self.tokens) - self.prompt_len


class PagedDecoder:
    """Per-layer decode through the page pool (dense/GQA families)."""

    def __init__(self, cfg: ModelConfig, params, pool: BwapPagePool):
        assert cfg.family in ("dense", "vlm") and cfg.mla is None
        self.cfg = cfg
        self.params = params
        self.pool = pool
        gp = params["groups"][0]
        self.stacked = not isinstance(gp, list)

    def _layer(self, l: int):
        gp = self.params["groups"][0]
        if self.stacked:
            return jax.tree.map(lambda x: x[l], gp)
        return gp[l]

    def decode_step(self, tokens, tables, lens, positions):
        """tokens [B,1]; tables [B,MP]; lens [B]; positions [B]."""
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        b = tokens.shape[0]
        nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
        ps = self.pool.page_size
        x = self.params["embed"][tokens].astype(cdt)     # [B,1,d]
        if cfg.embed_scale:
            x = x * np.sqrt(cfg.d_model)
        pos_b = positions[:, None].astype(jnp.int32)

        for l in range(cfg.num_layers):
            p = self._layer(l)
            h = L.apply_norm(cfg, p["norm1"], x)
            q = (h @ p["attn"]["wq"].astype(cdt)).reshape(b, 1, nq, hd)
            k = (h @ p["attn"]["wk"].astype(cdt)).reshape(b, 1, nkv, hd)
            v = (h @ p["attn"]["wv"].astype(cdt)).reshape(b, 1, nkv, hd)
            if cfg.qkv_bias:
                q = q + p["attn"]["bq"].astype(cdt).reshape(nq, hd)
                k = k + p["attn"]["bk"].astype(cdt).reshape(nkv, hd)
                v = v + p["attn"]["bv"].astype(cdt).reshape(nkv, hd)
            if cfg.use_rope:
                q = L.apply_rope(q, pos_b, cfg.rope_theta)
                k = L.apply_rope(k, pos_b, cfg.rope_theta)
            # write the batch's K/V into its pages: one scatter per layer
            # (decode hot path — the per-sequence Python loop cost B whole-
            # pool copies per layer)
            pages = jnp.take_along_axis(tables, (positions // ps)[:, None],
                                        axis=1)[:, 0]
            self.pool.write_decode_batch(l, pages, positions % ps,
                                         k[:, 0], v[:, 0])
            att = paged_ops.paged_attention(
                q[:, 0], self.pool.k_pool[l], self.pool.v_pool[l],
                tables, lens + 1, impl="reference")
            x = x + (att.reshape(b, 1, nq * hd)
                     @ p["attn"]["wo"].astype(cdt))
            h = L.apply_norm(cfg, p["norm2"], x)
            x = x + L.mlp_apply(cfg, p["mlp"], h)
        x = L.apply_norm(cfg, self.params["final_norm"], x)
        w = (self.params["embed"].T if cfg.tie_embeddings
             else self.params["head"])
        return (x @ w.astype(cdt))[:, 0]                 # [B, V]


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, pool: BwapPagePool,
                 max_batch: int = 8, max_new: int = 32, seed: int = 0):
        self.cfg = cfg
        self.pool = pool
        self.model = LM(cfg)
        self.decoder = PagedDecoder(cfg, params, pool)
        self.params = params
        self.max_batch = max_batch
        self.max_new = max_new
        self._ids = itertools.count()
        self.waiting: list[Sequence_] = []
        self.active: list[Sequence_] = []
        self.finished: list[Sequence_] = []
        self.latencies: list[float] = []

    def submit(self, prompt: Sequence[int]) -> int:
        s = Sequence_(next(self._ids), list(prompt), [],
                      prompt_len=len(prompt))
        self.waiting.append(s)
        return s.sid

    # -- prefill: full forward, then scatter K/V into BWAP-placed pages -----

    def _prefill(self, seq: Sequence_):
        cfg = self.cfg
        ps = self.pool.page_size
        toks = jnp.asarray([seq.tokens], jnp.int32)
        x = self.model.embed(self.params, {"tokens": toks})
        pos = jnp.arange(len(seq.tokens), dtype=jnp.int32)[None]
        _, _, caches = self.model.hidden(self.params, x, pos,
                                         want_cache=True)
        kv = caches[0]  # single dense group: {"k": [L,1,S,nkv,hd] or list}
        if isinstance(kv, list):
            k = jnp.stack([c["k"][0] for c in kv])   # [L,S,nkv,hd]
            v = jnp.stack([c["v"][0] for c in kv])
        else:
            k, v = kv["k"][:, 0], kv["v"][:, 0]
        # Materialize K/V for all prompt tokens but the last: the first
        # decode step consumes tokens[-1] and writes its K/V at position
        # len-1 itself. (Writing it here too double-counted the last prompt
        # token and shifted the decode RoPE position by one.)
        n_filled = len(seq.tokens) - 1
        n_pages = -(-n_filled // ps)
        seq.pages = [self.pool.alloc_page() for _ in range(n_pages)]
        for pi, pid in enumerate(seq.pages):
            lo, hi = pi * ps, min((pi + 1) * ps, n_filled)
            self.pool.k_pool = self.pool.k_pool.at[:, pid, :hi - lo].set(
                k[:, lo:hi])
            self.pool.v_pool = self.pool.v_pool.at[:, pid, :hi - lo].set(
                v[:, lo:hi])
        seq.length = n_filled

    def step(self) -> dict:
        while self.waiting and len(self.active) < self.max_batch:
            s = self.waiting.pop(0)
            self._prefill(s)
            self.active.append(s)
        if not self.active:
            return {"active": 0}
        t0 = time.monotonic()
        ps = self.pool.page_size
        # grow pages where needed, then batch
        for s in self.active:
            if s.length % ps == 0:
                s.pages.append(self.pool.alloc_page())
        mp = max(len(s.pages) for s in self.active)
        tables = np.zeros((len(self.active), mp), np.int32)
        for i, s in enumerate(self.active):
            tables[i, :len(s.pages)] = s.pages
        lens = np.asarray([s.length for s in self.active], np.int32)
        toks = np.asarray([[s.tokens[-1]] for s in self.active], np.int32)
        logits = self.decoder.decode_step(
            jnp.asarray(toks), jnp.asarray(tables), jnp.asarray(lens),
            jnp.asarray(lens))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s, t in zip(self.active, nxt):
            s.tokens.append(int(t))
            s.length += 1          # the decoded token's K/V is now pooled
            if s.produced >= self.max_new:
                self._finish(s)
        self.active = [s for s in self.active if not s.done]

        wall = time.monotonic() - t0
        # latency signal = wall clock + analytic BWAP read time (the CPU
        # has no real memory-domain asymmetry; Eq.-1 model supplies it)
        sim = max(self.pool.expected_read_time(
            [p for s in self.active for p in s.pages]), 0.0)
        self.latencies.append(wall + sim)
        if self.pool.record_latency(wall + sim):
            # the tuner moved the allocation cycle: re-home live sequences
            # (batched gather/scatter through the migration executor)
            for s in self.active:
                s.pages = self.pool.migrate_sequence(s.pages)
        return {"active": len(self.active), "latency": wall + sim,
                "dwp": self.pool.tuner.dwp,
                "occupancy": self.pool.occupancy(),
                "telemetry": self.pool.telemetry.snapshot()}

    def remap_pages(self, id_map: np.ndarray) -> None:
        """Rewrite page tables after the pool was rebalanced (arbiter
        capacity change): old page id -> new page id."""
        for s in self.active:
            s.pages = [int(id_map[p]) for p in s.pages]
            assert all(p >= 0 for p in s.pages), "live page lost in rebalance"

    def _finish(self, s: Sequence_):
        s.done = True
        self.pool.free_pages(s.pages)
        s.pages = []
        self.finished.append(s)
