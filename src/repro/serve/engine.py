"""Batched serving engine over the BWAP page pool (dense GQA archs).

CPU-runnable end-to-end: priority continuous batching through the request
scheduler (admission, chunked prefill, preemption with KV swap to slow
domains — ``repro.scheduler``), paged prefill + decode through
kernels/paged_attention (reference impl on CPU, Pallas on TPU), BWAP
placement of fresh pages, and online DWP tuning fed by measured step
latencies. examples/serve_paged.py drives it.
"""

from __future__ import annotations

import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention import ops as paged_ops
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.lm import LM
from repro.placement.fabric import as_view
from repro.scheduler.scheduler import Request, RequestScheduler

# The per-sequence record moved into the scheduler subsystem; the old name
# stays importable (tests, examples).
Sequence_ = Request


class PagedDecoder:
    """Per-layer decode through the page pool (dense/GQA families)."""

    def __init__(self, cfg: ModelConfig, params, pool):
        assert cfg.family in ("dense", "vlm") and cfg.mla is None
        self.cfg = cfg
        self.params = params
        self.view = as_view(pool)        # placement + data plane surface
        gp = params["groups"][0]
        self.stacked = not isinstance(gp, list)

    def _layer(self, l: int):
        gp = self.params["groups"][0]
        if self.stacked:
            return jax.tree.map(lambda x: x[l], gp)
        return gp[l]

    def prefill_chunk(self, token_ids, pages, lo: int, hi: int):
        """Single-sequence incremental prefill (kept for callers/tests):
        one-chunk special case of :meth:`forward_chunks`."""
        self.forward_chunks([(list(token_ids[lo:hi]), pages, lo)])

    def forward_chunks(self, chunks, *, want_logits: bool = False):
        """Fused multi-sequence chunk forward: ``chunks`` is a list of
        ``(token_ids, pages, start)`` — one sequence's token chunk at
        absolute positions ``[start, start + len(token_ids))`` over its page
        view. Per layer, every chunk's K/V scatters into its pages first
        (one op for the whole batch), then all chunks' queries run *one*
        batched prefill-mode paged-attention launch — prior chunks' (and
        any trie-shared prefix's) K/V is read from the pool, never
        recomputed, and same-step chunks of different sequences no longer
        pay one dispatch each (ROADMAP: batched incremental prefill).

        Chunks are right-padded to the longest one; padded queries' K/V
        never lands in the pool and their outputs are discarded, so real
        positions are bit-identical to running each chunk alone. With
        ``want_logits`` the padded [B,T,V] logits are returned — the
        speculative verify step (DESIGN.md §7) reads the model's argmax at
        every draft position from them."""
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        b = len(chunks)
        nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
        ps = self.view.page_size
        t = max(len(toks) for toks, _, _ in chunks)
        toks_pad = np.zeros((b, t), np.int32)
        pos_pad = np.zeros((b, t), np.int32)
        starts = np.zeros(b, np.int32)
        mp = max(-(-(start + len(toks)) // ps) for toks, _, start in chunks)
        tables = np.zeros((b, mp), np.int32)
        seq_i: list[int] = []      # scatter coordinates of real positions
        tok_i: list[int] = []
        pids: list[int] = []
        slots: list[int] = []
        for i, (toks, pages, start) in enumerate(chunks):
            ti = len(toks)
            toks_pad[i, :ti] = toks
            pos_pad[i] = start + np.arange(t)
            starts[i] = start
            cover = -(-(start + ti) // ps)
            tables[i, :cover] = pages[:cover]
            seq_i.extend([i] * ti)
            tok_i.extend(range(ti))
            pids.extend(int(pages[p // ps]) for p in range(start, start + ti))
            slots.extend(p % ps for p in range(start, start + ti))
        seq_i = np.asarray(seq_i, np.int32)
        tok_i = np.asarray(tok_i, np.int32)
        pids = np.asarray(pids, np.int32)
        slots = np.asarray(slots, np.int32)
        tbl = jnp.asarray(tables)
        qs = jnp.asarray(starts)

        x = self.params["embed"][jnp.asarray(toks_pad)].astype(cdt)  # [B,T,d]
        if cfg.embed_scale:
            x = x * np.sqrt(cfg.d_model)
        pos = jnp.asarray(pos_pad)                       # [B,T]

        for l in range(cfg.num_layers):
            p = self._layer(l)
            h = L.apply_norm(cfg, p["norm1"], x)
            q = (h @ p["attn"]["wq"].astype(cdt)).reshape(b, t, nq, hd)
            k = (h @ p["attn"]["wk"].astype(cdt)).reshape(b, t, nkv, hd)
            v = (h @ p["attn"]["wv"].astype(cdt)).reshape(b, t, nkv, hd)
            if cfg.qkv_bias:
                q = q + p["attn"]["bq"].astype(cdt).reshape(nq, hd)
                k = k + p["attn"]["bk"].astype(cdt).reshape(nkv, hd)
                v = v + p["attn"]["bv"].astype(cdt).reshape(nkv, hd)
            if cfg.use_rope:
                q = L.apply_rope(q, pos, cfg.rope_theta)
                k = L.apply_rope(k, pos, cfg.rope_theta)
            # real positions' K/V lands before attention: the causal mask
            # then covers prefix and intra-chunk keys uniformly (padded
            # positions never land)
            self.view.k_pool = self.view.k_pool.at[l, pids, slots].set(
                k[seq_i, tok_i])
            self.view.v_pool = self.view.v_pool.at[l, pids, slots].set(
                v[seq_i, tok_i])
            att = paged_ops.paged_prefill_attention_batch(
                q, self.view.k_pool[l], self.view.v_pool[l], tbl, qs,
                impl="reference")
            x = x + (att.reshape(b, t, nq * hd)
                     @ p["attn"]["wo"].astype(cdt))
            h = L.apply_norm(cfg, p["norm2"], x)
            x = x + L.mlp_apply(cfg, p["mlp"], h)
        if not want_logits:
            return None
        x = L.apply_norm(cfg, self.params["final_norm"], x)
        w = (self.params["embed"].T if cfg.tie_embeddings
             else self.params["head"])
        return x @ w.astype(cdt)                         # [B,T,V]

    def decode_step(self, tokens, tables, lens, positions):
        """tokens [B,1]; tables [B,MP]; lens [B]; positions [B]."""
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        b = tokens.shape[0]
        nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
        ps = self.view.page_size
        x = self.params["embed"][tokens].astype(cdt)     # [B,1,d]
        if cfg.embed_scale:
            x = x * np.sqrt(cfg.d_model)
        pos_b = positions[:, None].astype(jnp.int32)

        for l in range(cfg.num_layers):
            p = self._layer(l)
            h = L.apply_norm(cfg, p["norm1"], x)
            q = (h @ p["attn"]["wq"].astype(cdt)).reshape(b, 1, nq, hd)
            k = (h @ p["attn"]["wk"].astype(cdt)).reshape(b, 1, nkv, hd)
            v = (h @ p["attn"]["wv"].astype(cdt)).reshape(b, 1, nkv, hd)
            if cfg.qkv_bias:
                q = q + p["attn"]["bq"].astype(cdt).reshape(nq, hd)
                k = k + p["attn"]["bk"].astype(cdt).reshape(nkv, hd)
                v = v + p["attn"]["bv"].astype(cdt).reshape(nkv, hd)
            if cfg.use_rope:
                q = L.apply_rope(q, pos_b, cfg.rope_theta)
                k = L.apply_rope(k, pos_b, cfg.rope_theta)
            # write the batch's K/V into its pages: one scatter per layer
            # (decode hot path — the per-sequence Python loop cost B whole-
            # pool copies per layer)
            pages = jnp.take_along_axis(tables, (positions // ps)[:, None],
                                        axis=1)[:, 0]
            self.view.write_decode_batch(l, pages, positions % ps,
                                         k[:, 0], v[:, 0])
            att = paged_ops.paged_attention(
                q[:, 0], self.view.k_pool[l], self.view.v_pool[l],
                tables, lens + 1, impl="reference")
            x = x + (att.reshape(b, 1, nq * hd)
                     @ p["attn"]["wo"].astype(cdt))
            h = L.apply_norm(cfg, p["norm2"], x)
            x = x + L.mlp_apply(cfg, p["mlp"], h)
        x = L.apply_norm(cfg, self.params["final_norm"], x)
        w = (self.params["embed"].T if cfg.tie_embeddings
             else self.params["head"])
        return (x @ w.astype(cdt))[:, 0]                 # [B, V]


class ServeEngine:
    """Model execution over the pool; the request lifecycle — admission,
    batch composition, chunked prefill pacing, preemption — is owned by the
    :class:`RequestScheduler` (pass one in to configure priority classes and
    KV swap; the default scheduler reproduces plain continuous batching)."""

    def __init__(self, cfg: ModelConfig, params, pool,
                 max_batch: int = 8, max_new: int = 32, seed: int = 0,
                 scheduler: RequestScheduler | None = None,
                 wall_clock: bool = True, sim_step_s: float = 0.0,
                 incremental_prefill: bool = True,
                 prefix_reuse: bool = True,
                 drafter=None,
                 rehome: bool | None = None,
                 rehome_budget_frac: float = 0.5):
        self.cfg = cfg
        self.view = as_view(pool)        # the only placement surface
        self.model = LM(cfg)
        self.decoder = PagedDecoder(cfg, params, self.view)
        self.params = params
        self.scheduler = scheduler if scheduler is not None else \
            RequestScheduler(self.view, max_batch=max_batch,
                             default_max_new=max_new)
        # wall_clock=False runs the virtual clock on the Eq.-1 analytic
        # terms only — deterministic SLO numbers for benchmarks/tests;
        # sim_step_s then stands in for per-step compute time
        self.wall_clock = wall_clock
        self.sim_step_s = sim_step_s
        # incremental_prefill=False falls back to prefix recompute (the
        # bit-exactness oracle); prefix_reuse=False disables trie matching
        # (the footprint baseline benchmarks compare against)
        self.incremental_prefill = incremental_prefill
        self.view.table.prefix_reuse = prefix_reuse
        # speculative multi-token decode (DESIGN.md §7): a drafter proposes
        # continuations, the verify step accepts only what the model's own
        # argmax confirms — outputs stay token-identical to greedy. The
        # scheduler must reserve page growth and token budget for the
        # lookahead, so its spec_tokens tracks the drafter's depth.
        self.drafter = drafter
        if drafter is not None:
            self.scheduler.spec_tokens = max(self.scheduler.spec_tokens,
                                             drafter.max_tokens)
        # heat-driven re-homing (DESIGN.md §11): after each decode step,
        # migrate the hottest shared slow-domain pages into fast domains
        # under an Eq.-1 budget of `rehome_budget_frac` of the step's
        # measured stall — migration can never exceed the stall it saves.
        # Default follows the view's policy (the `coda` policy turns it
        # on); an explicit bool overrides. Heat comes from the attached
        # observatory when it has one, else from a private PageHeat.
        self.rehome = (bool(rehome) if rehome is not None
                       else bool(getattr(self.view.placement_policy,
                                         "rehome", False)))
        self.rehome_budget_frac = float(rehome_budget_frac)
        self._heat = None
        self.rehomed_pages = 0
        # cluster handoff hook (DESIGN.md §13): callbacks fired for each
        # finishing request BEFORE scheduler.finish releases its pages —
        # the ClusterRouter exports the prompt range while the trie chain
        # still has a live holder
        self._finish_cbs: list = []
        self.prefill_tokens_computed = 0   # forward-pass tokens spent on
        self.prefill_chunks_run = 0        # prefill (the O(n) vs O(n²) gap)
        self.decode_steps = 0              # steps that ran a decode batch
        self.tokens_emitted = 0            # decode tokens committed
        self.latencies: list[float] = []

    # scheduler views under the pre-scheduler attribute names
    @property
    def active(self) -> list[Sequence_]:
        return self.scheduler.running

    @property
    def waiting(self) -> list[Sequence_]:
        return self.scheduler.pending

    @property
    def finished(self) -> list[Sequence_]:
        return self.scheduler.finished

    def submit(self, prompt: Sequence[int], *, cls: str | None = None,
               max_new: int | None = None,
               arrival_s: float | None = None) -> int:
        return self.scheduler.submit(prompt, cls=cls, max_new=max_new,
                                     arrival_s=arrival_s)

    def on_request_finish(self, cb) -> None:
        """Register ``cb(engine, seq)`` to run when a request finishes,
        *before* the scheduler releases its pages — the only window where
        a handoff can export the sequence's range (release may drop the
        last reference and the trie chain dies with it)."""
        self._finish_cbs.append(cb)

    # -- chunked prefill ------------------------------------------------------

    def _run_prefills(self, chunks) -> None:
        """Materialize K/V for this step's prompt chunks. Two paths:

        - **incremental** (default): O(hi-lo) per chunk — each chunk reads
          prior chunks' (and trie-shared prefix) K/V from the pool through
          the prefill-mode paged-attention op, and *all* same-step chunks
          of different sequences fuse into one batched launch
          (``PagedDecoder.forward_chunks``). Long-prompt admission is O(n)
          across chunks, and a step's prefill work is one dispatch.
        - **recompute**: forward over ``tokens[:hi]``, scatter [lo, hi) —
          O(hi) per chunk, O(n²) across chunks; kept as the exactness
          oracle (causal attention makes position p's K/V depend only on
          tokens[:p+1], so it equals one-shot prefill bit-for-bit).

        The last prompt token is never prefilled — the first decode step
        consumes it and writes its K/V at the true position (double-writing
        it shifted the decode RoPE position by one)."""
        chunks = [(s, lo, hi) for s, lo, hi in chunks if hi > lo]
        if not chunks:
            return
        if not self.incremental_prefill:
            for seq, lo, hi in chunks:
                self._prefill_chunk_recompute(seq, lo, hi)
            return
        fused = []
        for seq, lo, hi in chunks:
            # defensive CoW: prefill chunks land in freshly-allocated
            # exclusive pages, but a fork here is what keeps a mis-planned
            # write from corrupting another sequence's shared prefix
            self.view.ensure_writable(seq.pages, lo, hi)
            self.prefill_chunks_run += 1
            self.prefill_tokens_computed += hi - lo
            fused.append((seq.tokens[lo:hi], seq.pages, lo))
        self.decoder.forward_chunks(fused)
        for seq, lo, hi in chunks:
            seq.length = hi
            self._register_if_done(seq, hi)

    def _prefill_chunk_recompute(self, seq: Sequence_, lo: int, hi: int):
        self.view.ensure_writable(seq.pages, lo, hi)
        self.prefill_chunks_run += 1
        self.prefill_tokens_computed += hi
        ps = self.view.page_size
        toks = jnp.asarray([seq.tokens[:hi]], jnp.int32)
        x = self.model.embed(self.params, {"tokens": toks})
        pos = jnp.arange(hi, dtype=jnp.int32)[None]
        _, _, caches = self.model.hidden(self.params, x, pos,
                                         want_cache=True)
        kv = caches[0]  # single dense group: {"k": [L,1,S,nkv,hd] or list}
        if isinstance(kv, list):
            k = jnp.stack([c["k"][0] for c in kv])   # [L,S,nkv,hd]
            v = jnp.stack([c["v"][0] for c in kv])
        else:
            k, v = kv["k"][:, 0], kv["v"][:, 0]
        positions = np.arange(lo, hi)
        pids = np.asarray([seq.pages[p // ps] for p in positions], np.int32)
        slots = (positions % ps).astype(np.int32)
        # one scatter per pool array for the whole chunk
        self.view.k_pool = self.view.k_pool.at[:, pids, slots].set(k[:, lo:hi])
        self.view.v_pool = self.view.v_pool.at[:, pids, slots].set(v[:, lo:hi])
        seq.length = hi
        self._register_if_done(seq, hi)

    def _register_if_done(self, seq: Sequence_, hi: int) -> None:
        """Final chunk just landed: the prompt pages' bytes are now real —
        only now may they enter the prefix trie (registering any earlier
        lets a matcher reference pages that were never written)."""
        if hi >= seq.prefill_target:
            self.view.register_prefix(seq.tokens, seq.pages,
                                      seq.prefill_target)

    def step(self) -> dict:
        t0 = time.monotonic()
        plan = self.scheduler.schedule()
        self._run_prefills(plan.prefill_chunks)
        batch = plan.batch
        if not batch and not plan.prefill_chunks:
            self.scheduler.advance(plan.swap_seconds)
            return {"active": 0, "pending": len(self.scheduler.pending)}
        done: list[Sequence_] = []
        produced_before = {s.sid: s.produced for s in batch}
        groups = plan.launch_groups
        if batch:
            drafts = self._draft(batch)
            if drafts is not None:
                # the verify path fuses the whole batch into one
                # prefill-mode launch; micro-batching applies to plain
                # greedy decode only
                groups = None
                self._verify_step(batch, drafts)
            else:
                self._greedy_step(batch, groups)
            self.decode_steps += 1
            for s in batch:
                if s.produced >= s.max_new:
                    done.append(s)

        wall = time.monotonic() - t0
        # latency signal = wall clock + analytic BWAP read time + swap
        # transfer time (the CPU has no real memory-domain asymmetry;
        # the Eq.-1 model supplies it); prefill-only steps read no KV, and
        # sampling them would dilute the per-domain stall rings with zeros.
        # The read set is every *physical* page the decode batch gathered:
        # finishing sequences' pages count (the step that produced their
        # final token read them — dropping them fed the DWP tuner an
        # underestimated stall signal on every completing step), and a trie
        # page shared by several holders is billed once, not once per
        # holder (Eq. 1 models resident bytes, and the kernel reads each
        # physical page once per launch).
        read_pages = list(dict.fromkeys(
            p for s in batch for p in s.pages)) if batch else []
        launches = None
        if batch and groups is not None:
            # compute-follows-data: one Eq.-1 bill per launch — the step
            # stall is the max over per-launch bottlenecks, since launches
            # to different domain groups overlap (DESIGN.md §11)
            launches = []
            for dom, grp in groups:
                rp = list(dict.fromkeys(p for s in grp for p in s.pages))
                launches.append(
                    (dom, rp,
                     max(self.view.expected_read_time(rp), 0.0)))
            sim = max(t for _, _, t in launches)
        elif batch:
            sim = max(self.view.expected_read_time(read_pages), 0.0)
        else:
            sim = 0.0
        dt = ((wall if self.wall_clock else 0.0) + sim + plan.swap_seconds
              + (self.sim_step_s if batch else 0.0))
        v0 = self.scheduler.now
        self.scheduler.advance(dt)
        # bytes-weighted heat: a sequence's partial tail page streams
        # fewer bytes than an interior page and must not look equally hot
        read_weights = self._page_read_weights(batch) if batch else {}
        obs = self.view.fabric.obs
        if obs is not None:
            # spans for this step's prefill chunks + decode batch, page
            # heat touches, and (probe-equipped) the batch-read drift
            # pairs — one per launch in micro-batch mode
            obs.on_engine_step(self.view, plan, batch, read_pages,
                               sim, v0, dt, launches=launches,
                               read_weights=read_weights)
        for s in batch:
            if produced_before[s.sid] == 0 and s.produced > 0:
                self.scheduler.notice_first_token(s)
        for s in done:
            for cb in self._finish_cbs:
                cb(self, s)
            self.scheduler.finish(s)
        moved = False
        if batch:
            self.latencies.append(dt)
            # the DWP tuner judges *placement*: feed it the step latency
            # minus swap transfers — a preemption spike says nothing about
            # where the live pages sit and would trigger spurious re-homing
            if self.view.record_latency(dt - plan.swap_seconds):
                # the tuner moved the allocation cycle: re-home live
                # sequences (batched gather/scatter through the executor);
                # shared pages are pinned and refcounts follow the moves
                for s in self.scheduler.running:
                    s.pages = self.view.migrate(s.pages)
                moved = True
        rehomed = 0
        if self.rehome and batch:
            rehomed = self._rehome_step(obs, read_pages, read_weights, sim)
        tel = self.view.snapshot()
        return {"active": len(self.scheduler.running),
                "latency": dt, "migrated": moved, "rehomed": rehomed,
                "launches": (len(groups) if groups is not None
                             else (1 if batch else 0)),
                "dwp": self.view.dwp,
                "occupancy": self.view.occupancy(),
                "swapped": len(self.scheduler.swapped),
                "swapped_out": len(plan.swapped_out),
                "swapped_in": len(plan.swapped_in),
                # one stats() pass per step: the view snapshot carries
                # the page-table block alongside the domain counters
                "pagetable": tel["pagetable"],
                "prefill_tokens_computed": self.prefill_tokens_computed,
                "decode_steps": self.decode_steps,
                "tokens_emitted": self.tokens_emitted,
                "spec": tel["spec"],
                "telemetry": tel}

    # -- decode: greedy single-token and speculative multi-token --------------

    def _draft(self, batch) -> list[list[int]] | None:
        """Ask the drafter for each sequence's proposal, capped at the
        scheduler's reserved lookahead and the sequence's remaining token
        allowance (drafting past ``max_new`` would be rolled back anyway).
        Returns None when there is nothing to verify — the plain decode
        kernel is cheaper than a 1-token verify launch."""
        if self.drafter is None:
            return None
        k = self.scheduler.spec_tokens
        drafts = []
        for s in batch:
            allowed = s.max_new - s.produced     # >= 1: finished seqs left
            d = self.drafter.draft(s.tokens)[:min(k, allowed - 1)] \
                if allowed > 1 else []
            drafts.append([int(t) for t in d])
        return drafts if any(drafts) else None

    def _greedy_step(self, batch, groups=None) -> None:
        ps = self.view.page_size
        # grow pages where needed (the scheduler reserved capacity);
        # a decode write into a shared page — the full-prompt-match
        # case: position prompt_len-1 lives in a trie page — forks it.
        # Growth always runs over the FULL batch in global order — even in
        # micro-batch mode — so page ids (and therefore everything
        # downstream) are bit-identical to a single global launch.
        # Constant-footprint geometries (SSM state, DESIGN.md §12) never
        # append: their one state page absorbs every step in place.
        if self.view.geometry.grows:
            for s in batch:
                if s.length % ps == 0:
                    self.view.append_page(s.pages)
                else:
                    self.view.fork_for_write(s.pages, s.length // ps)
        if groups is not None:
            # compute-follows-data (DESIGN.md §11): one launch per domain
            # group. Each row's attention reads only its own page table and
            # argmax is per-row, so the partition cannot change tokens.
            for _dom, grp in groups:
                self._decode_launch(grp)
        else:
            self._decode_launch(batch)
        self.tokens_emitted += len(batch)

    def _decode_launch(self, seqs) -> None:
        """One decode launch over ``seqs`` (the whole batch, or one
        per-domain micro-batch)."""
        mp = max(len(s.pages) for s in seqs)
        tables = np.zeros((len(seqs), mp), np.int32)
        for i, s in enumerate(seqs):
            tables[i, :len(s.pages)] = s.pages
        lens = np.asarray([s.length for s in seqs], np.int32)
        toks = np.asarray([[s.tokens[-1]] for s in seqs], np.int32)
        logits = self.decoder.decode_step(
            jnp.asarray(toks), jnp.asarray(tables), jnp.asarray(lens),
            jnp.asarray(lens))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s, t in zip(seqs, nxt):
            s.tokens.append(int(t))
            s.length += 1          # the decoded token's K/V is now pooled

    # -- compute-follows-data: heat + re-homing (DESIGN.md §11) ---------------

    def _page_read_weights(self, batch) -> dict[int, float]:
        """Fraction of each physical page this step's gather actually
        streamed: interior pages read in full, a sequence's tail page only
        up to its committed length. A page that is one holder's partial
        tail but another's interior counts as a full read."""
        ps = self.view.page_size
        out: dict[int, float] = {}
        for s in batch:
            for i, p in enumerate(s.pages):
                if p < 0:
                    continue
                frac = min(1.0, max(0.0, (s.length - i * ps) / ps))
                if frac > out.get(p, 0.0):
                    out[p] = frac
        return out

    def _own_heat(self):
        """Private heat map for policy-driven re-homing when no
        observatory (or a heatless one) is attached."""
        if self._heat is None:
            from repro.obs.heat import PageHeat
            heat = PageHeat(self.view.pool)
            self.view.fabric.subscribe(
                "free", lambda page=-1, **_: heat.on_free(page=page))
            self._heat = heat
        return self._heat

    def _rehome_step(self, obs, read_pages, read_weights, sim) -> int:
        """Post-step re-homing: pull the hottest shared slow-domain pages
        into fast domains, spending at most ``rehome_budget_frac`` of this
        step's Eq.-1 stall. The spent seconds advance the virtual clock —
        migration traffic is real traffic."""
        if obs is not None and obs.heat is not None:
            heat = obs.heat          # the observatory already touched it
        else:
            heat = self._own_heat()
            heat.touch(read_pages,
                       weights=[read_weights.get(p, 1.0)
                                for p in read_pages])
            heat.step()
        budget = self.rehome_budget_frac * sim
        if budget <= 0.0:
            return 0
        moves, secs = self.view.rehome_hot(heat, budget_s=budget)
        if not moves:
            return 0
        v0 = self.scheduler.now
        self.scheduler.advance(secs)
        self.rehomed_pages += len(moves)
        if obs is not None:
            obs.on_rehome(self.view, v0, secs, len(moves))
        return len(moves)

    def _verify_step(self, batch, drafts) -> None:
        """Speculative multi-token decode (DESIGN.md §7). Per sequence the
        chunk ``[tokens[-1], draft...]`` writes K/V at positions
        ``[length, length + d]`` and runs through one batched prefill-mode
        attention launch; the longest draft prefix the model's own argmax
        confirms is accepted, plus one bonus token from the first
        disagreeing position — so every verify step emits >= 1 token and
        outputs are token-identical to greedy decoding.

        Rejected speculation rolls back *exactly*: snapshotted K/V bytes
        are scattered back, pages greedy would not yet have allocated
        return to the allocator LIFO with the allocation cycle rewound
        (``pool.undo_alloc``), and their references leave the table
        (``table.pop_page``). The unwind runs in **reverse batch order** —
        the step's allocations form one stack across sequences, so only a
        right-to-left unwind restores free-list order and lets the cycle
        rewinds chain. A single speculating sequence is then bit-identical
        to its greedy run (``tests/test_spec_decode.py`` drives this
        property); with several sequences speculating past page boundaries
        in one step, a kept page allocated between two rejected ones pins
        the cycle, so page *ids* may permute across sequences vs greedy —
        tokens, refcount structure, and leak-freedom still hold exactly
        (DESIGN.md §7.3). CoW forks never need undoing: the only forkable
        write position is ``length`` (the committed token — draft
        positions land in the forked clone or in fresh pages), and at
        least one token always commits."""
        ps = self.view.page_size
        recs = []                       # per seq: (appended allocs, snap base)
        chunks = []
        snap_pids: list[int] = []
        snap_slots: list[int] = []
        for s, d in zip(batch, drafts):
            lo = s.length
            if lo % ps:
                self.view.fork_for_write(s.pages, lo // ps)
            appended = []               # (pid, marker_before, marker_after)
            while len(s.pages) * ps <= lo + len(d):
                m0 = self.view.alloc_marker()
                pid = self.view.append_page(s.pages)
                appended.append((pid, m0, self.view.alloc_marker()))
            base = len(snap_pids)
            for p in range(lo + 1, lo + len(d) + 1):   # speculative slots
                snap_pids.append(int(s.pages[p // ps]))
                snap_slots.append(p % ps)
            recs.append((appended, base))
            chunks.append(([s.tokens[-1]] + d, s.pages, lo))
        snap_k = snap_v = None
        if snap_pids:
            # pre-write bytes of every speculative slot, all layers at once
            snap_k = self.view.k_pool[:, snap_pids, snap_slots]
            snap_v = self.view.v_pool[:, snap_pids, snap_slots]
        logits = self.decoder.forward_chunks(chunks, want_logits=True)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))   # [B,T]
        drafted = accepted = emitted = 0
        rest_idx: list[int] = []        # snapshot rows to scatter back
        rest_pids: list[int] = []
        rest_slots: list[int] = []
        for i, (s, d) in enumerate(zip(batch, drafts)):
            lo = s.length
            allowed = s.max_new - s.produced
            a = 0
            while a < len(d) and a + 1 < allowed and int(nxt[i, a]) == d[a]:
                a += 1
            emit = a + 1                # accepted drafts + the bonus token
            s.tokens.extend(int(nxt[i, j]) for j in range(emit))
            s.length = lo + emit        # committed K/V: positions lo..lo+a
            drafted += len(d)
            accepted += a
            emitted += emit
            appended, base = recs[i]
            for j in range(emit, len(d) + 1):   # rejected: lo+emit..lo+d
                rest_idx.append(base + j - 1)
                rest_pids.append(snap_pids[base + j - 1])
                rest_slots.append(snap_slots[base + j - 1])
        # unwind rejected page allocations strictly right-to-left: the
        # step's allocations are one stack across the whole batch, so only
        # reverse order puts pages back in LIFO position and keeps each
        # undo_alloc's cycle-marker check satisfied for the next one
        for s, (appended, _) in zip(reversed(batch), reversed(recs)):
            keep = -(-s.length // ps)   # pages greedy would hold right now
            while len(s.pages) > keep:
                pid, m0, m1 = appended.pop()
                popped = self.view.pop_page(s.pages)
                assert popped == pid, "speculative page stack out of order"
                self.view.undo_alloc(pid, m0, m1)
        if rest_idx:
            idx = np.asarray(rest_idx)
            self.view.k_pool = self.view.k_pool.at[
                :, rest_pids, rest_slots].set(snap_k[:, idx])
            self.view.v_pool = self.view.v_pool.at[
                :, rest_pids, rest_slots].set(snap_v[:, idx])
        self.tokens_emitted += emitted
        self.view.telemetry.record_spec(drafted, accepted, emitted)
