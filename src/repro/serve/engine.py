"""Batched serving engine over the BWAP page pool (dense GQA archs).

CPU-runnable end-to-end: priority continuous batching through the request
scheduler (admission, chunked prefill, preemption with KV swap to slow
domains — ``repro.scheduler``), paged prefill + decode through
kernels/paged_attention (reference impl on CPU, Pallas on TPU), BWAP
placement of fresh pages, and online DWP tuning fed by measured step
latencies. examples/serve_paged.py drives it.
"""

from __future__ import annotations

import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention import ops as paged_ops
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.lm import LM
from repro.scheduler.scheduler import Request, RequestScheduler
from repro.serve.kvcache import BwapPagePool

# The per-sequence record moved into the scheduler subsystem; the old name
# stays importable (tests, examples).
Sequence_ = Request


class PagedDecoder:
    """Per-layer decode through the page pool (dense/GQA families)."""

    def __init__(self, cfg: ModelConfig, params, pool: BwapPagePool):
        assert cfg.family in ("dense", "vlm") and cfg.mla is None
        self.cfg = cfg
        self.params = params
        self.pool = pool
        gp = params["groups"][0]
        self.stacked = not isinstance(gp, list)

    def _layer(self, l: int):
        gp = self.params["groups"][0]
        if self.stacked:
            return jax.tree.map(lambda x: x[l], gp)
        return gp[l]

    def prefill_chunk(self, token_ids, pages, lo: int, hi: int):
        """Incremental chunked prefill: materialize K/V for prompt positions
        [lo, hi) with O(hi-lo) compute. Per layer the chunk's K/V scatters
        into its pages first, then the chunk queries run prefill-mode paged
        attention over the sequence's page table — prior chunks' (and any
        trie-shared prefix's) K/V is *read from the pool*, never recomputed.
        Same per-layer algebra as ``decode_step`` with T tokens at once."""
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        t = hi - lo
        nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
        ps = self.pool.page_size
        toks = jnp.asarray([token_ids[lo:hi]], jnp.int32)
        x = self.params["embed"][toks].astype(cdt)       # [1,T,d]
        if cfg.embed_scale:
            x = x * np.sqrt(cfg.d_model)
        pos = jnp.arange(lo, hi, dtype=jnp.int32)[None]  # [1,T]
        positions = np.arange(lo, hi)
        pids = np.asarray([pages[p // ps] for p in positions], np.int32)
        slots = (positions % ps).astype(np.int32)
        tbl = jnp.asarray(pages[:-(-hi // ps)], jnp.int32)

        for l in range(cfg.num_layers):
            p = self._layer(l)
            h = L.apply_norm(cfg, p["norm1"], x)
            q = (h @ p["attn"]["wq"].astype(cdt)).reshape(1, t, nq, hd)
            k = (h @ p["attn"]["wk"].astype(cdt)).reshape(1, t, nkv, hd)
            v = (h @ p["attn"]["wv"].astype(cdt)).reshape(1, t, nkv, hd)
            if cfg.qkv_bias:
                q = q + p["attn"]["bq"].astype(cdt).reshape(nq, hd)
                k = k + p["attn"]["bk"].astype(cdt).reshape(nkv, hd)
                v = v + p["attn"]["bv"].astype(cdt).reshape(nkv, hd)
            if cfg.use_rope:
                q = L.apply_rope(q, pos, cfg.rope_theta)
                k = L.apply_rope(k, pos, cfg.rope_theta)
            # chunk K/V lands before attention: the causal mask then covers
            # prefix and intra-chunk keys uniformly
            self.pool.k_pool = self.pool.k_pool.at[l, pids, slots].set(k[0])
            self.pool.v_pool = self.pool.v_pool.at[l, pids, slots].set(v[0])
            att = paged_ops.paged_prefill_attention(
                q[0], self.pool.k_pool[l], self.pool.v_pool[l], tbl,
                jnp.int32(lo), impl="reference")
            x = x + (att.reshape(1, t, nq * hd)
                     @ p["attn"]["wo"].astype(cdt))
            h = L.apply_norm(cfg, p["norm2"], x)
            x = x + L.mlp_apply(cfg, p["mlp"], h)

    def decode_step(self, tokens, tables, lens, positions):
        """tokens [B,1]; tables [B,MP]; lens [B]; positions [B]."""
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        b = tokens.shape[0]
        nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
        ps = self.pool.page_size
        x = self.params["embed"][tokens].astype(cdt)     # [B,1,d]
        if cfg.embed_scale:
            x = x * np.sqrt(cfg.d_model)
        pos_b = positions[:, None].astype(jnp.int32)

        for l in range(cfg.num_layers):
            p = self._layer(l)
            h = L.apply_norm(cfg, p["norm1"], x)
            q = (h @ p["attn"]["wq"].astype(cdt)).reshape(b, 1, nq, hd)
            k = (h @ p["attn"]["wk"].astype(cdt)).reshape(b, 1, nkv, hd)
            v = (h @ p["attn"]["wv"].astype(cdt)).reshape(b, 1, nkv, hd)
            if cfg.qkv_bias:
                q = q + p["attn"]["bq"].astype(cdt).reshape(nq, hd)
                k = k + p["attn"]["bk"].astype(cdt).reshape(nkv, hd)
                v = v + p["attn"]["bv"].astype(cdt).reshape(nkv, hd)
            if cfg.use_rope:
                q = L.apply_rope(q, pos_b, cfg.rope_theta)
                k = L.apply_rope(k, pos_b, cfg.rope_theta)
            # write the batch's K/V into its pages: one scatter per layer
            # (decode hot path — the per-sequence Python loop cost B whole-
            # pool copies per layer)
            pages = jnp.take_along_axis(tables, (positions // ps)[:, None],
                                        axis=1)[:, 0]
            self.pool.write_decode_batch(l, pages, positions % ps,
                                         k[:, 0], v[:, 0])
            att = paged_ops.paged_attention(
                q[:, 0], self.pool.k_pool[l], self.pool.v_pool[l],
                tables, lens + 1, impl="reference")
            x = x + (att.reshape(b, 1, nq * hd)
                     @ p["attn"]["wo"].astype(cdt))
            h = L.apply_norm(cfg, p["norm2"], x)
            x = x + L.mlp_apply(cfg, p["mlp"], h)
        x = L.apply_norm(cfg, self.params["final_norm"], x)
        w = (self.params["embed"].T if cfg.tie_embeddings
             else self.params["head"])
        return (x @ w.astype(cdt))[:, 0]                 # [B, V]


class ServeEngine:
    """Model execution over the pool; the request lifecycle — admission,
    batch composition, chunked prefill pacing, preemption — is owned by the
    :class:`RequestScheduler` (pass one in to configure priority classes and
    KV swap; the default scheduler reproduces plain continuous batching)."""

    def __init__(self, cfg: ModelConfig, params, pool: BwapPagePool,
                 max_batch: int = 8, max_new: int = 32, seed: int = 0,
                 scheduler: RequestScheduler | None = None,
                 wall_clock: bool = True, sim_step_s: float = 0.0,
                 incremental_prefill: bool = True,
                 prefix_reuse: bool = True):
        self.cfg = cfg
        self.pool = pool
        self.table = pool.table
        self.model = LM(cfg)
        self.decoder = PagedDecoder(cfg, params, pool)
        self.params = params
        self.scheduler = scheduler if scheduler is not None else \
            RequestScheduler(pool, max_batch=max_batch,
                             default_max_new=max_new)
        # wall_clock=False runs the virtual clock on the Eq.-1 analytic
        # terms only — deterministic SLO numbers for benchmarks/tests;
        # sim_step_s then stands in for per-step compute time
        self.wall_clock = wall_clock
        self.sim_step_s = sim_step_s
        # incremental_prefill=False falls back to prefix recompute (the
        # bit-exactness oracle); prefix_reuse=False disables trie matching
        # (the footprint baseline benchmarks compare against)
        self.incremental_prefill = incremental_prefill
        self.table.prefix_reuse = prefix_reuse
        self.prefill_tokens_computed = 0   # forward-pass tokens spent on
        self.prefill_chunks_run = 0        # prefill (the O(n) vs O(n²) gap)
        self.latencies: list[float] = []

    # scheduler views under the pre-scheduler attribute names
    @property
    def active(self) -> list[Sequence_]:
        return self.scheduler.running

    @property
    def waiting(self) -> list[Sequence_]:
        return self.scheduler.pending

    @property
    def finished(self) -> list[Sequence_]:
        return self.scheduler.finished

    def submit(self, prompt: Sequence[int], *, cls: str | None = None,
               max_new: int | None = None,
               arrival_s: float | None = None) -> int:
        return self.scheduler.submit(prompt, cls=cls, max_new=max_new,
                                     arrival_s=arrival_s)

    # -- chunked prefill ------------------------------------------------------

    def _prefill_chunk(self, seq: Sequence_, lo: int, hi: int):
        """Materialize K/V for prompt positions [lo, hi). Two paths:

        - **incremental** (default): O(hi-lo) — the chunk reads prior
          chunks' (and trie-shared prefix) K/V from the pool through the
          prefill-mode paged-attention op. Long-prompt admission is O(n)
          across chunks.
        - **recompute**: forward over ``tokens[:hi]``, scatter [lo, hi) —
          O(hi) per chunk, O(n²) across chunks; kept as the exactness
          oracle (causal attention makes position p's K/V depend only on
          tokens[:p+1], so it equals one-shot prefill bit-for-bit).

        The last prompt token is never prefilled — the first decode step
        consumes it and writes its K/V at the true position (double-writing
        it shifted the decode RoPE position by one)."""
        if hi <= lo:
            return
        # defensive CoW: prefill chunks land in freshly-allocated exclusive
        # pages, but a fork here is what keeps a mis-planned write from
        # corrupting another sequence's shared prefix
        self.table.ensure_writable(seq.pages, lo, hi)
        self.prefill_chunks_run += 1
        if self.incremental_prefill:
            self.prefill_tokens_computed += hi - lo
            self.decoder.prefill_chunk(seq.tokens, seq.pages, lo, hi)
            seq.length = hi
            self._register_if_done(seq, hi)
            return
        self.prefill_tokens_computed += hi
        ps = self.pool.page_size
        toks = jnp.asarray([seq.tokens[:hi]], jnp.int32)
        x = self.model.embed(self.params, {"tokens": toks})
        pos = jnp.arange(hi, dtype=jnp.int32)[None]
        _, _, caches = self.model.hidden(self.params, x, pos,
                                         want_cache=True)
        kv = caches[0]  # single dense group: {"k": [L,1,S,nkv,hd] or list}
        if isinstance(kv, list):
            k = jnp.stack([c["k"][0] for c in kv])   # [L,S,nkv,hd]
            v = jnp.stack([c["v"][0] for c in kv])
        else:
            k, v = kv["k"][:, 0], kv["v"][:, 0]
        positions = np.arange(lo, hi)
        pids = np.asarray([seq.pages[p // ps] for p in positions], np.int32)
        slots = (positions % ps).astype(np.int32)
        # one scatter per pool array for the whole chunk
        self.pool.k_pool = self.pool.k_pool.at[:, pids, slots].set(k[:, lo:hi])
        self.pool.v_pool = self.pool.v_pool.at[:, pids, slots].set(v[:, lo:hi])
        seq.length = hi
        self._register_if_done(seq, hi)

    def _register_if_done(self, seq: Sequence_, hi: int) -> None:
        """Final chunk just landed: the prompt pages' bytes are now real —
        only now may they enter the prefix trie (registering any earlier
        lets a matcher reference pages that were never written)."""
        if hi >= seq.prefill_target:
            self.table.register_prefix(seq.tokens, seq.pages,
                                       seq.prefill_target)

    def step(self) -> dict:
        t0 = time.monotonic()
        plan = self.scheduler.schedule()
        for seq, lo, hi in plan.prefill_chunks:
            self._prefill_chunk(seq, lo, hi)
        batch = plan.batch
        if not batch and not plan.prefill_chunks:
            self.scheduler.advance(plan.swap_seconds)
            return {"active": 0, "pending": len(self.scheduler.pending)}
        ps = self.pool.page_size
        done: list[Sequence_] = []
        if batch:
            # grow pages where needed (the scheduler reserved capacity);
            # a decode write into a shared page — the full-prompt-match
            # case: position prompt_len-1 lives in a trie page — forks it
            for s in batch:
                if s.length % ps == 0:
                    self.table.append_page(s.pages)
                else:
                    self.table.fork_for_write(s.pages, s.length // ps)
            mp = max(len(s.pages) for s in batch)
            tables = np.zeros((len(batch), mp), np.int32)
            for i, s in enumerate(batch):
                tables[i, :len(s.pages)] = s.pages
            lens = np.asarray([s.length for s in batch], np.int32)
            toks = np.asarray([[s.tokens[-1]] for s in batch], np.int32)
            logits = self.decoder.decode_step(
                jnp.asarray(toks), jnp.asarray(tables), jnp.asarray(lens),
                jnp.asarray(lens))
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for s, t in zip(batch, nxt):
                s.tokens.append(int(t))
                s.length += 1      # the decoded token's K/V is now pooled
                if s.produced >= s.max_new:
                    done.append(s)

        wall = time.monotonic() - t0
        # latency signal = wall clock + analytic BWAP read time + swap
        # transfer time (the CPU has no real memory-domain asymmetry;
        # the Eq.-1 model supplies it); prefill-only steps read no KV, and
        # sampling them would dilute the per-domain stall rings with zeros
        sim = max(self.pool.expected_read_time(
            [p for s in batch if s not in done for p in s.pages]), 0.0) \
            if batch else 0.0
        dt = ((wall if self.wall_clock else 0.0) + sim + plan.swap_seconds
              + (self.sim_step_s if batch else 0.0))
        self.scheduler.advance(dt)
        for s in batch:
            if s.produced == 1:
                self.scheduler.notice_first_token(s)
        for s in done:
            self.scheduler.finish(s)
        moved = False
        if batch:
            self.latencies.append(dt)
            # the DWP tuner judges *placement*: feed it the step latency
            # minus swap transfers — a preemption spike says nothing about
            # where the live pages sit and would trigger spurious re-homing
            if self.pool.record_latency(dt - plan.swap_seconds):
                # the tuner moved the allocation cycle: re-home live
                # sequences (batched gather/scatter through the executor);
                # shared pages are pinned and refcounts follow the moves
                for s in self.scheduler.running:
                    s.pages = self.pool.migrate_sequence(s.pages,
                                                         table=self.table)
                moved = True
        tel = self.pool.telemetry.snapshot()
        return {"active": len(self.scheduler.running),
                "latency": dt, "migrated": moved,
                "dwp": self.pool.tuner.dwp,
                "occupancy": self.pool.occupancy(),
                "swapped": len(self.scheduler.swapped),
                "swapped_out": len(plan.swapped_out),
                "swapped_in": len(plan.swapped_in),
                # one stats() pass per step: the snapshot already carries
                # the page-table block via telemetry.attach_pagetable
                "pagetable": tel.get("pagetable", self.table.stats()),
                "prefill_tokens_computed": self.prefill_tokens_computed,
                "telemetry": tel}

    def remap_pages(self, id_map: np.ndarray) -> None:
        """Rewrite page tables after the pool was rebalanced (arbiter
        capacity change): old page id -> new page id. Covers running,
        prefilling, and swapped sequences plus the swap reservation."""
        self.scheduler.remap(id_map)
