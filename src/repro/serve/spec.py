"""Speculative decode drafting: deterministic CPU-runnable proposal models.

A drafter proposes the next few tokens of a sequence from information that is
already on the host — no extra model forward, no accelerator round-trip. The
engine then *verifies* the proposals in one batched multi-token step through
the prefill-mode paged-attention op (DESIGN.md §7): every accepted proposal
replaces a whole decode step, i.e. a full batched KV read across memory
domains — the dominant Eq.-1 serving cost BWAP balances.

Correctness contract: drafters only ever *propose*; the engine accepts a
proposal exactly when it equals the model's own greedy argmax at that
position. Output tokens are therefore identical to plain greedy decoding for
any drafter (``tests/test_spec_decode.py`` pins this), and a drafter's
quality only moves the acceptance rate / steps saved, never the text.

``PromptLookupDrafter`` is prompt-lookup / n-gram self-drafting: find the
most recent earlier occurrence of the sequence's trailing n-gram and propose
its historical continuation. Repetitive contexts — templated prompts,
code, the copy-heavy tails LLM serving traces are full of — make this
drafter accept at high rates for zero model cost.
"""

from __future__ import annotations

from typing import Sequence


class Drafter:
    """Interface: ``draft(tokens)`` -> proposed continuation (possibly
    empty), at most ``max_tokens`` long, deterministic in ``tokens``."""

    max_tokens: int = 0

    def draft(self, tokens: Sequence[int]) -> list[int]:
        raise NotImplementedError


class PromptLookupDrafter(Drafter):
    """N-gram self-drafting over the sequence's own history (prompt +
    generated tokens).

    Longest-match-first: try the trailing ``max_ngram``-gram, fall back to
    shorter n-grams down to ``min_ngram``; within one n, the *most recent*
    earlier occurrence wins (recency tracks the local pattern — loops,
    templates — better than the first occurrence). Proposes the tokens that
    historically followed the match, capped at ``max_tokens``.
    """

    def __init__(self, max_tokens: int = 4, max_ngram: int = 3,
                 min_ngram: int = 1, max_scan: int = 512):
        assert max_tokens >= 1 and 1 <= min_ngram <= max_ngram
        assert max_scan >= 1
        self.max_tokens = max_tokens
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        # backward-scan window: drafting runs on the decode hot path every
        # step, so an unbounded scan of a long history would be O(n) per
        # call with nothing to show for it on non-repetitive text — local
        # patterns (runs, cycles, templates) live near the tail anyway
        self.max_scan = max_scan

    def draft(self, tokens: Sequence[int]) -> list[int]:
        n_tok = len(tokens)
        k = self.max_tokens
        scan_lo = max(0, n_tok - self.max_scan)
        for n in range(min(self.max_ngram, n_tok - 1), self.min_ngram - 1,
                       -1):
            tail = tuple(tokens[n_tok - n:])
            # rightmost j with tokens[j:j+n] == tail; j == n_tok - n is the
            # trivial self-match
            for j in range(n_tok - n - 1, scan_lo - 1, -1):
                if tuple(tokens[j:j + n]) == tail:
                    # unroll from the match: position n_tok + m predicts
                    # tokens[j + n + m], reading back into just-predicted
                    # tokens once the continuation runs past the end of
                    # history — a constant run or short cycle extends to
                    # the full draft depth instead of stopping where the
                    # recorded continuation does
                    ext = list(tokens)
                    src = j + n
                    for _ in range(k):
                        ext.append(ext[src])
                        src += 1
                    return [int(t) for t in ext[n_tok:]]
        return []
