"""Disaggregated serving cluster (DESIGN.md §13).

Prefill/decode disaggregation over the fabric's page wire: the
interconnect between a prefill host and a decode host is one more
asymmetric, contended link in the paper's bandwidth model —
:mod:`interconnect` prices a KV handoff with Eq.-1 per-link rows and
stripes it Eq.-5-style across asymmetric links, :mod:`transport` carries
the PR-6 wire format between two fabrics that share no pool,
:mod:`convert` re-chunks/reshards a mismatched peer layout on import
instead of raising, and :mod:`router` splits each prompt into a prefill
admission and a decode handoff (falling back to single-host serving when
the wire is saturated).
"""

from repro.cluster.convert import convert_range
from repro.cluster.interconnect import Interconnect, Link
from repro.cluster.router import ClusterRouter
from repro.cluster.transport import PageChannel

__all__ = ["Interconnect", "Link", "PageChannel", "convert_range",
           "ClusterRouter"]
