"""Prefill/decode disaggregation router (DESIGN.md §13).

*Phoenix* (PAPERS.md) argues placement must be orchestrated with where
compute runs; the router is that decision for a two-host cluster. A
prompt is admitted to the **prefill host** with ``max_new=1`` — its
engine runs prefill-heavy steps with a small resident decode set, so
first tokens surface fast. The moment the first token lands, the
request's prompt KV range is exported over the
:class:`~repro.cluster.transport.PageChannel` and the remainder of the
request is submitted to the **decode host**, arriving (on the decode
clock) only after the wire transfer and import finish — the handoff
overlaps the prefill host's next prompts because the wire runs its own
virtual clock and never blocks the prefill engine.

The decode host's scheduler finds the imported range through the prefix
trie (``import_range`` rebuilds the chain keys), prefills only the
partial tail page, and decodes the remaining ``max_new - 1`` tokens —
token-identical to single-host serving, because prefill KV bytes move
bit-exactly and the tail recompute is deterministic.

When the wire is **saturated** (queueing delay beyond the router's
horizon, per the interconnect's Eq.-1 model), the router falls back to
single-host serving on the decode host — a handoff that arrives later
than local service is a loss, exactly the weighted-placement logic of
the paper applied to admission.
"""

from __future__ import annotations

import itertools
from typing import Sequence

import numpy as np


class ClusterRouter:
    """Admission + handoff across one prefill engine and one decode
    engine joined by a :class:`PageChannel`."""

    def __init__(self, prefill_engine, decode_engine, channel, *,
                 saturation_horizon_s: float = 0.1, mesh=None):
        self.prefill = prefill_engine
        self.decode = decode_engine
        self.channel = channel
        self.saturation_horizon_s = float(saturation_horizon_s)
        self.mesh = mesh
        self._rids = itertools.count()
        self._by_prefill_sid: dict[int, dict] = {}
        self._by_decode_sid: dict[int, int] = {}     # decode sid -> rid
        self._imports: dict[int, list[int]] = {}     # decode sid -> page ids
        self._results: dict[int, dict | None] = {}   # rid -> result record
        self.handoffs = 0
        self.fallbacks = 0
        prefill_engine.on_request_finish(self._on_prefill_finish)
        decode_engine.on_request_finish(self._on_decode_finish)

    # -- admission -------------------------------------------------------------

    def submit(self, prompt: Sequence[int], *, max_new: int,
               cls: str | None = None) -> int:
        """Route one prompt. Split serving when the wire has headroom;
        single-host (decode host end-to-end) when a handoff would queue
        past the saturation horizon or there is nothing to hand off."""
        rid = next(self._rids)
        self._results[rid] = None
        now = self.prefill.scheduler.now
        if max_new <= 1 or self.channel.link.saturated(
                now, self.saturation_horizon_s):
            self.fallbacks += 1
            sid = self.decode.submit(list(prompt), max_new=max_new,
                                     cls=cls)
            self._by_decode_sid[sid] = rid
            return rid
        self.handoffs += 1
        sid = self.prefill.submit(list(prompt), max_new=1, cls=cls)
        self._by_prefill_sid[sid] = {
            "rid": rid, "prompt": list(prompt), "max_new": int(max_new),
            "cls": cls,
        }
        return rid

    # -- handoff (prefill-host finish hook) ------------------------------------

    def _on_prefill_finish(self, engine, seq) -> None:
        rec = self._by_prefill_sid.pop(seq.sid, None)
        if rec is None:
            return
        view = engine.view
        ps = view.page_size
        prompt_len = seq.prompt_len
        # after the first decode step KV covers [0, prompt_len): prefill
        # wrote [0, prompt_len-1), the step wrote position prompt_len-1
        pages = list(seq.pages[:-(-prompt_len // ps)])
        parcel = self.channel.send(
            view, pages, now=engine.scheduler.now,
            tokens=rec["prompt"], ntokens=prompt_len, mesh=self.mesh)
        new_ids, parcel, import_s = self.channel.recv(
            self.decode.view, mesh=self.mesh)
        ready = max(self.decode.scheduler.now, parcel.arrive_s) + import_s
        sid = self.decode.scheduler.submit(
            rec["prompt"] + [int(seq.tokens[-1])], cls=rec["cls"],
            max_new=rec["max_new"] - 1, arrival_s=ready)
        self._by_decode_sid[sid] = rec["rid"]
        self._imports[sid] = new_ids
        # the originating request's TTFT is the prefill host's: the user
        # saw the first token there, before the handoff even started
        slo = engine.scheduler.slo.records[seq.sid]
        self._results[rec["rid"]] = {
            "tokens": None, "produced": 1, "ttft": slo.ttft,
            "mode": "handoff", "done": False,    # head token counted here
        }

    # -- completion (decode-host finish hook) ----------------------------------

    def _on_decode_finish(self, engine, seq) -> None:
        rid = self._by_decode_sid.pop(seq.sid, None)
        if rid is None:
            return
        imported = self._imports.pop(seq.sid, None)
        if imported:
            # the channel's import holds end with the request; chain pages
            # the request shares die with its own release right after
            engine.view.release(imported)
        res = self._results.get(rid)
        if res is None:                     # local mode: decode-host TTFT
            slo = engine.scheduler.slo.records[seq.sid]
            res = {"ttft": slo.ttft, "mode": "local", "produced": 0}
        res["tokens"] = list(seq.tokens)
        res["produced"] += int(seq.produced)
        res["done"] = True
        self._results[rid] = res

    # -- driving ---------------------------------------------------------------

    def _has_work(self, engine) -> bool:
        # a queued future arrival counts: the scheduler's own idle-jump
        # advances the clock to it on the next schedule() call
        sch = engine.scheduler
        return bool(sch.running or sch.prefilling or sch.swapped
                    or sch.queued)

    def step(self) -> bool:
        """One router tick: step each engine that has open work (the
        scheduler's idle-jump handles future arrivals). Returns whether
        anything progressed."""
        worked = False
        for engine in (self.prefill, self.decode):
            if self._has_work(engine):
                engine.step()
                worked = True
        return worked

    def all_done(self) -> bool:
        return all(r is not None and r.get("done")
                   for r in self._results.values())

    def drain(self, max_steps: int = 100_000) -> None:
        steps = 0
        while not self.all_done():
            if not self.step():
                raise RuntimeError("cluster drain stalled with open "
                                   "requests")
            steps += 1
            assert steps < max_steps, "cluster drain exceeded step budget"

    # -- reporting -------------------------------------------------------------

    def result(self, rid: int) -> list[int]:
        res = self._results[rid]
        assert res is not None and res.get("done"), f"request {rid} open"
        return list(res["tokens"])

    def summary(self) -> dict:
        done = [r for r in self._results.values()
                if r is not None and r.get("done")]
        ttfts = [r["ttft"] for r in done if r["ttft"] is not None]
        tokens = sum(r["produced"] for r in done)
        elapsed = max(self.prefill.scheduler.now,
                      self.decode.scheduler.now)
        ttft_mean = float(np.mean(ttfts)) if ttfts else 0.0
        goodput = tokens / max(elapsed, 1e-9)
        return {
            "completed": len(done),
            "tokens": tokens,
            "elapsed_s": float(elapsed),
            "ttft_mean_s": ttft_mean,
            "goodput_tok_s": goodput,
            "ttft_weighted_goodput": goodput / max(ttft_mean, 1e-9),
            "handoffs": self.handoffs,
            "fallbacks": self.fallbacks,
            "channel": self.channel.stats(),
        }
