"""Layout conversion on import (DESIGN.md §13).

A page-range blob is stamped with the exporter's geometry (page size,
block shapes) and layout (mesh axes, KV-pool partition spec). PR 6 made
a mismatched import raise; a disaggregated cluster cannot afford that —
a prefill host and a decode host legitimately run different page sizes
(prefill wants large pages for sequential writes, decode small ones for
fine-grained sharing) and different meshes. :func:`convert_range`
re-chunks/reshards the blob into the importer's geometry instead,
bit-exact per token:

- **Layout-only mismatch** (mesh axes / ``kv_pool_spec``): the wire
  carries full host-side arrays — sharding is a placement property of
  the *device* pools, not of the bytes — so conversion is a metadata
  restamp, trivially bit-exact.
- **Page-size mismatch**: the per-token trailing dims must agree (same
  ``kind``, dtype, layer count, block tails); then the k/v arrays
  re-chunk token-exactly — flatten pages to a token axis, trim the
  exporter's tail padding (``ntokens``), zero-pad to the importer's page
  boundary, re-fold. Chain keys rebuild from the blob's token path over
  *full* destination pages only (a partial tail page carries real bytes
  but no trie key; the importer's prefill recomputes past it).
- **Anything deeper** (different head counts, dtypes, cache kinds) is a
  recompute, not a re-layout: still a ``ValueError``.
"""

from __future__ import annotations

import hashlib

import numpy as np


def _sha256(b: bytes) -> str:
    return hashlib.sha256(b).hexdigest()


def _require(cond: bool, what: str, a, b) -> None:
    if not cond:
        raise ValueError(
            f"cannot convert page range: {what} differs "
            f"({a!r} -> {b!r}) — that is a recompute, not a re-layout")


def convert_range(blob: dict, *, geometry: dict, layout: dict) -> dict:
    """Return a blob importable under ``geometry``/``layout``.

    Same geometry and layout passes through untouched; a layout-only
    mismatch restamps; a page-size mismatch re-chunks the k/v payloads
    token-exactly (see module docstring). Raises ``ValueError`` when the
    source and target disagree on per-token facts.
    """
    src = dict(blob["geometry"])
    dst = dict(geometry)
    if src == dst and blob.get("layout") == layout:
        return blob
    for key in ("kind", "dtype", "num_layers"):
        _require(src.get(key) == dst.get(key), key,
                 src.get(key), dst.get(key))
    for key in ("k_block", "v_block"):
        # trailing (per-token) dims must agree; the leading dim is the
        # page size, which is exactly what re-chunking changes
        _require(list(src.get(key, ()))[1:] == list(dst.get(key, ()))[1:],
                 f"{key} tail", src.get(key), dst.get(key))
    out = dict(blob)
    out["geometry"] = dst
    out["layout"] = layout
    ps_s, ps_d = int(src["page_size"]), int(dst["page_size"])
    if ps_s == ps_d:
        return out

    n_src = len(blob["pages"])
    ntokens = int(blob.get("ntokens") or n_src * ps_s)
    assert 0 < ntokens <= n_src * ps_s, (ntokens, n_src, ps_s)
    n_dst = -(-ntokens // ps_d)

    def rechunk(arr: np.ndarray) -> np.ndarray:
        arr = np.ascontiguousarray(arr)        # [L, n_src, ps_s, *rest]
        nl = arr.shape[0]
        rest = arr.shape[3:]
        flat = arr.reshape(nl, n_src * ps_s, *rest)[:, :ntokens]
        pad = n_dst * ps_d - ntokens
        if pad:
            flat = np.concatenate(
                [flat, np.zeros((nl, pad) + rest, arr.dtype)], axis=1)
        return np.ascontiguousarray(
            flat.reshape(nl, n_dst, ps_d, *rest))

    out["k"] = rechunk(blob["k"])
    out["v"] = rechunk(blob["v"])
    out["pages"] = list(range(n_dst))
    out["ref"] = {int(p): 1 for p in out["pages"]}
    out["ntokens"] = ntokens
    out["converted"] = True
    chains = []
    tokens = blob.get("tokens")
    if tokens:
        n_full = min(len(tokens), ntokens) // ps_d
        if n_full:
            chains.append({
                "tokens": [int(t) for t in tokens[:n_full * ps_d]],
                "phys": list(range(n_full)),
            })
    out["chains"] = chains
    out["sha256"] = {"k": _sha256(out["k"].tobytes()),
                     "v": _sha256(out["v"].tobytes())}
    return out
