"""Virtual-clock link model: the page wire as Eq.-1 rows (DESIGN.md §13).

BWAP's Eq. 1 prices a batch read as the max over per-domain transfer
times; a cluster interconnect is the same shape one level up — each
physical link between the prefill and decode hosts is an asymmetric,
contended row with its own bandwidth *and* a propagation latency the
intra-host domains don't have. :func:`repro.core.bwmodel.stall_cost`
grew ``link_bytes``/``link_bw_gbps``/``link_latency_s`` rows for exactly
this, so a KV handoff is priced like any other domain read.

Striping follows the paper's Eq.-5 weighted interleave applied to the
wire: a transfer splits across the links proportionally to their
effective bandwidth (``optimal_weights`` over a one-worker profile), so
the slowest link stops being the bottleneck the way uniform spreading
would make it.

The wire runs on its own virtual clock: sends serialize behind
``busy_until``, queueing delay is observable (the router's saturation
fallback reads it), and measured transfers EWMA-calibrate
``bw_effective`` the same way the fabric calibrates its domain rows.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import bwmodel


@dataclasses.dataclass(frozen=True)
class Link:
    """One physical wire between the hosts."""

    name: str
    bw_gbps: float
    latency_s: float = 0.0

    def __post_init__(self):
        assert self.bw_gbps > 0 and self.latency_s >= 0.0


class Interconnect:
    """Eq.-1/Eq.-5 model of one directed host-to-host page wire."""

    def __init__(self, links: Sequence[Link], *,
                 calibration_alpha: float = 0.25):
        links = list(links)
        assert links, "an interconnect needs at least one link"
        self.links = links
        self.bw_nominal = np.asarray([l.bw_gbps for l in links],
                                     dtype=np.float64)
        self.bw_effective = self.bw_nominal.copy()
        self.latency_s = np.asarray([l.latency_s for l in links],
                                    dtype=np.float64)
        self._alpha = float(calibration_alpha)
        self.busy_until = 0.0           # wire virtual clock (seconds)
        self.sends = 0
        self.sent_bytes = 0
        self.busy_seconds = 0.0
        self.calibration_samples = 0

    # -- Eq.-5 weighted striping ----------------------------------------------

    def weights(self) -> np.ndarray:
        """Eq.-5 weights over the wire's links: proportional to effective
        bandwidth (one worker group, so minbw is the link bandwidth)."""
        return bwmodel.optimal_weights(self.bw_effective[:, None])

    def stripe(self, nbytes: int) -> np.ndarray:
        """Byte split of one transfer across the links, DWP-weighted;
        integer remainder lands on the highest-weight link."""
        w = self.weights()
        per = np.floor(w * int(nbytes)).astype(np.int64)
        per[int(np.argmax(w))] += int(nbytes) - int(per.sum())
        return per.astype(np.float64)

    # -- Eq.-1 pricing ---------------------------------------------------------

    def transfer_seconds(self, nbytes: int) -> float:
        """Eq.-1 price of one striped transfer: per-link rows (bandwidth +
        latency) appended to an empty domain vector — link transfers
        overlap, the stall is the slowest link's stripe."""
        if nbytes <= 0:
            return 0.0
        return bwmodel.stall_cost(
            np.zeros(0), np.zeros(0),
            link_bytes=self.stripe(nbytes),
            link_bw_gbps=self.bw_effective,
            link_latency_s=self.latency_s)

    # -- virtual clock ---------------------------------------------------------

    def queue_delay(self, now: float) -> float:
        """Seconds a transfer issued at ``now`` waits before starting."""
        return max(0.0, self.busy_until - float(now))

    def send(self, nbytes: int, now: float) -> tuple[float, float]:
        """Occupy the wire for one transfer: starts when the wire frees
        up, takes Eq.-1 time. Returns ``(start_s, seconds)``."""
        start = max(float(now), self.busy_until)
        seconds = self.transfer_seconds(nbytes)
        self.busy_until = start + seconds
        self.sends += 1
        self.sent_bytes += int(nbytes)
        self.busy_seconds += seconds
        return start, seconds

    def saturated(self, now: float, horizon_s: float) -> bool:
        """The router's fallback predicate: the wire is saturated when its
        backlog at ``now`` exceeds ``horizon_s`` — a handoff queued behind
        it would arrive later than serving the request locally."""
        return self.queue_delay(now) > float(horizon_s)

    # -- calibration (mirrors fabric.calibrate's EWMA) -------------------------

    def calibrate(self, nbytes: int, measured_s: float) -> None:
        """Fold one measured transfer into ``bw_effective``: every link's
        rate moves toward what the measurement implies, at the same EWMA
        step the fabric uses for its domain rows."""
        predicted = self.transfer_seconds(nbytes)
        if predicted <= 0 or measured_s <= 0:
            return
        ratio = predicted / float(measured_s)   # >1: wire faster than model
        a = self._alpha
        self.bw_effective = np.maximum(
            (1 - a) * self.bw_effective + a * self.bw_effective * ratio,
            1e-9)
        self.calibration_samples += 1

    def stats(self) -> dict:
        return {
            "links": [l.name for l in self.links],
            "bw_nominal_gbps": [float(b) for b in self.bw_nominal],
            "bw_effective_gbps": [float(b) for b in self.bw_effective],
            "weights": [float(w) for w in self.weights()],
            "sends": self.sends,
            "sent_bytes": self.sent_bytes,
            "busy_seconds": self.busy_seconds,
            "busy_until": self.busy_until,
            "calibration_samples": self.calibration_samples,
        }
