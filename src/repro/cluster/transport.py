"""Message-passing page channel between two fabrics (DESIGN.md §13).

The PR-6 wire format (``persist.serialize_range``: length-prefixed JSON
header + two ``np.save`` payloads) already carries everything a peer
needs to adopt a page range; this module moves those bytes between two
:class:`~repro.placement.fabric.MemoryFabric` instances that share **no
pool**, over an :class:`~repro.cluster.interconnect.Interconnect`:

- sends are **chunked** onto the wire — each chunk occupies the link's
  virtual clock in turn, so a large handoff is preemptible by the
  model's accounting and its cost is visible as queueing delay to later
  sends;
- each transfer is **billed to the drift ledger** (``link_transfer``
  kind) when a probe supplies a measured time, which also
  EWMA-calibrates the wire's effective bandwidth;
- both ends **emit fabric events** (``link_send`` / ``link_recv``) that
  the observatory turns into labeled byte/chunk counters and Perfetto
  spans;
- a geometry/layout mismatch on the receiving side is **converted**
  (:func:`repro.cluster.convert.convert_range`) instead of raising.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Sequence

from repro.cluster.convert import convert_range
from repro.placement.persist import (deserialize_range, kv_layout_metadata,
                                     serialize_range)


@dataclasses.dataclass
class Parcel:
    """One serialized page range in flight on the wire."""

    data: bytes
    sent_s: float            # sender clock when the send was issued
    arrive_s: float          # wire clock when the last chunk lands
    chunks: int


class PageChannel:
    """Ordered, chunked channel from one fabric's tier to another's."""

    def __init__(self, interconnect, *, chunk_bytes: int = 1 << 16,
                 probe=None):
        assert chunk_bytes >= 1
        self.link = interconnect
        self.chunk_bytes = int(chunk_bytes)
        # probe("link_transfer", nbytes) -> measured seconds (or None to
        # skip): wall clock on a real wire, planted truth in benchmarks
        self.probe = probe
        self._inflight: collections.deque[Parcel] = collections.deque()
        self.sent_parcels = 0
        self.recv_parcels = 0
        self.converted_imports = 0

    def pending(self) -> int:
        return len(self._inflight)

    # -- send ------------------------------------------------------------------

    def send(self, src_view, pages: Sequence[int], *, now: float,
             tokens: Sequence[int] | None = None,
             ntokens: int | None = None, mesh=None) -> Parcel:
        """Export ``pages`` from the sending fabric's tier and put the
        serialized bytes on the wire in ``chunk_bytes`` chunks. Returns
        the in-flight :class:`Parcel`; the matching :meth:`recv` adopts
        it on the other fabric. Non-destructive for the sender."""
        fabric = src_view.fabric
        tier = fabric.persist
        assert tier is not None, "sending fabric has no persistent tier"
        blob = tier.export_range(src_view, pages, mesh,
                                 tokens=tokens, ntokens=ntokens)
        data = serialize_range(blob)
        nbytes = len(data)
        chunks = -(-nbytes // self.chunk_bytes)
        start0 = max(float(now), self.link.busy_until)
        arrive, left = float(now), nbytes
        for _ in range(chunks):
            step = min(self.chunk_bytes, left)
            s, secs = self.link.send(step, now)
            arrive = s + secs
            left -= step
        seconds = arrive - start0
        obs = fabric.obs
        if obs is not None and obs.drift is not None \
                and self.probe is not None:
            measured = self.probe("link_transfer", nbytes)
            if measured is not None:
                obs.drift.observe_scalar("link_transfer", seconds,
                                         float(measured))
                self.link.calibrate(nbytes, float(measured))
        fabric.emit("link_send", view=src_view.name, bytes=nbytes,
                    chunks=chunks, seconds=seconds)
        parcel = Parcel(data=data, sent_s=float(now), arrive_s=arrive,
                        chunks=chunks)
        self._inflight.append(parcel)
        self.sent_parcels += 1
        return parcel

    # -- receive ---------------------------------------------------------------

    def recv(self, dst_view, *, mesh=None) -> tuple[list[int], Parcel,
                                                    float]:
        """Adopt the oldest in-flight parcel into the receiving fabric:
        deserialize, convert when the peer's geometry or layout differs
        from the importer's, and import under the view's own placement
        cycle and ledger. Returns ``(new_ids, parcel, import_seconds)``;
        the caller owns releasing ``new_ids`` when the adopted range is
        no longer needed."""
        assert self._inflight, "no parcel in flight"
        parcel = self._inflight.popleft()
        fabric = dst_view.fabric
        tier = fabric.persist
        assert tier is not None, "receiving fabric has no persistent tier"
        blob = deserialize_range(parcel.data)
        pool = dst_view.pool
        want_geometry = tier._geometry(pool)
        want_layout = kv_layout_metadata(pool.cfg, pool.page_size, mesh)
        if blob["geometry"] != want_geometry \
                or blob.get("layout") != want_layout:
            blob = convert_range(blob, geometry=want_geometry,
                                 layout=want_layout)
            self.converted_imports += 1
        new_ids, seconds = tier.import_range(dst_view, blob)
        fabric.emit("link_recv", view=dst_view.name, pages=len(new_ids),
                    bytes=len(parcel.data), seconds=seconds)
        self.recv_parcels += 1
        return new_ids, parcel, seconds

    def stats(self) -> dict:
        return {
            "sent_parcels": self.sent_parcels,
            "recv_parcels": self.recv_parcels,
            "pending": self.pending(),
            "converted_imports": self.converted_imports,
            "chunk_bytes": self.chunk_bytes,
            "link": self.link.stats(),
        }
