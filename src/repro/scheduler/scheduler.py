"""Request scheduler: priority continuous batching with KV swap preemption.

The scheduler owns the request lifecycle the serving engine used to hand-roll:

- **Admission** — a priority-class queue (higher ``level`` preempts lower;
  FIFO within a class) with a *chunked-prefill token budget*: at most
  ``prefill_token_budget`` new prompt tokens materialize K/V per step, so a
  long prompt prefills across steps instead of stalling the decode batch.
- **Continuous batching** — finished sequences leave the batch immediately;
  waiting/swapped requests fill the slot the same step.
- **Preemption** — when fast capacity runs short or a higher class arrives,
  a victim's KV pages swap out to BWAP-weighted slow domains through the
  placement executor (``swap.KVSwapManager``) and back on resume. Victims
  maximize ``priority-factor x page-footprint x Eq.-1 stall cost``
  (DESIGN.md §5): prefer low classes, large footprints, and sequences whose
  pages already stall the batch.

State machine (per request)::

    QUEUED -> PREFILL -> RUNNING -> FINISHED
                 ^          |
                 |          v
                 +------ SWAPPED       (swap-out <-> swap-in)

Time is a virtual clock: the engine advances it by measured wall time plus
the Eq.-1 analytic components (KV read stall, swap transfers), which is what
SLO accounting (slo.py) and trace replay (workload.py) run on.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Sequence

import numpy as np

from repro.placement.fabric import as_view
from repro.scheduler.slo import SloSpec, SloTracker
from repro.scheduler.swap import KVSwapManager


@dataclasses.dataclass(frozen=True)
class PriorityClass:
    """An admission class: scheduling level + SLO deadlines."""

    name: str
    level: int = 0                       # higher preempts lower
    slo: SloSpec = dataclasses.field(default_factory=SloSpec)


class State(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    RUNNING = "running"
    SWAPPED = "swapped"
    FINISHED = "finished"


@dataclasses.dataclass(eq=False)
class Request:
    """One sequence; the engine's ``Sequence_`` fields are preserved
    (``sid``/``tokens``/``pages``/``prompt_len``/``length``/``done``).

    ``eq=False``: requests are identities, not values. The generated
    field-wise ``__eq__`` made every ``in`` / ``list.remove`` on the hot
    decode path an O(B·tokens) deep compare of token lists (and two
    distinct requests with equal prompts compared equal); identity
    semantics make those O(B) pointer checks and restore hashability."""

    sid: int
    tokens: list
    pages: list
    prompt_len: int = 0
    length: int = 0                      # tokens with K/V in the pool
    done: bool = False
    cls: str = "default"
    max_new: int = 32
    arrival_s: float = 0.0
    state: State = State.QUEUED
    resume_after: float = 0.0            # stall-preemption cooldown gate
    probed: bool = False                 # first trie probe already counted

    @property
    def produced(self) -> int:
        return len(self.tokens) - self.prompt_len

    @property
    def prefill_target(self) -> int:
        """Prompt tokens that prefill materializes: all but the last (the
        first decode step writes that one at its true position)."""
        return self.prompt_len - 1


@dataclasses.dataclass
class StepPlan:
    """What one engine step executes, in order: prefill chunks, then decode
    over ``batch``. Swaps already happened inside ``schedule()``.

    ``launch_groups`` is the compute-follows-data assignment (DESIGN.md
    §11): when the view's policy enables micro-batching, ``batch`` is
    partitioned into ``(domain, requests)`` per-domain micro-batches —
    each decodes in its own launch, so the step's Eq.-1 stall is the max
    over per-launch bottlenecks instead of one global max. ``None`` means
    one global launch (the classic path)."""

    prefill_chunks: list                 # (Request, lo, hi) token ranges
    batch: list                          # Requests to decode this step
    swapped_in: list
    swapped_out: list
    swap_seconds: float = 0.0
    launch_groups: list | None = None    # [(domain, [Request, ...]), ...]


class RequestScheduler:
    """Priority continuous batching over one fabric view (a bare
    ``BwapPagePool`` is adopted into a single-view fabric; placement and
    page lifetime go exclusively through :class:`FabricView`).

    On a named (multi-tenant) view the scheduler registers the tenant as a
    priority class at the view's level and makes it the default class — the
    wiring ``arbiter.attach_engine`` used to reach in and do.

    ``swap=None`` disables preemption (the pre-scheduler engine behavior):
    capacity shortfalls make requests wait, and a batch that can no longer
    grow raises ``RuntimeError`` exactly like the bare allocator did.
    """

    def __init__(self, pool, *, max_batch: int = 8,
                 prefill_token_budget: int = 256,
                 classes: Sequence[PriorityClass] | None = None,
                 default_class: str = "default",
                 default_max_new: int = 32,
                 swap: KVSwapManager | None = None,
                 stall_preempt_fraction: float | None = None,
                 stall_preempt_cooldown_s: float = 0.0,
                 spec_tokens: int = 0,
                 conservative_admission: bool = False,
                 micro_batch: bool | None = None):
        assert prefill_token_budget >= 1
        self.view = as_view(pool)        # the only placement surface
        # compute-follows-data (DESIGN.md §11): partition each decode batch
        # into per-domain micro-batches. Default follows the view's
        # placement policy (the `coda` policy turns it on); an explicit
        # bool overrides.
        self.micro_batch = (bool(micro_batch) if micro_batch is not None
                            else bool(getattr(self.view.placement_policy,
                                              "micro_batch", False)))
        self.max_batch = max_batch
        self.prefill_token_budget = prefill_token_budget
        self.swap = swap
        # speculative-decode lookahead (DESIGN.md §7): every decode step
        # may write positions [length, length + spec_tokens], so growth
        # accounting reserves pages for the whole span, admission sizes
        # footprints with the margin, and the per-step token budget charges
        # each running sequence's draft+verify tokens (1 + spec_tokens)
        # before prefill chunks may claim the rest. 0 = plain decode.
        assert spec_tokens >= 0
        self.spec_tokens = spec_tokens
        # conservative (trie-aware) admission: a request joins the batch
        # only when its whole remaining *physical* footprint — worst case
        # minus pages it already shares through the prefix trie — fits
        # alongside every admitted request's remaining footprint. The
        # admitted set can then always grow to completion without swap
        # capacity, at the cost of lower oversubscription; the default
        # keeps the greedy admission that leans on preemption.
        self.conservative_admission = conservative_admission
        # stall-triggered preemption (Eq. 1): evict a sequence whose own
        # KV read time exceeds this fraction of the batch read time.
        # None disables; the cooldown stops an out/in thrash loop.
        assert stall_preempt_fraction is None \
            or 0.0 < stall_preempt_fraction < 1.0
        self.stall_preempt_fraction = stall_preempt_fraction
        self.stall_preempt_cooldown_s = stall_preempt_cooldown_s
        self.classes: dict[str, PriorityClass] = {}
        for pc in (classes or []):
            self.classes[pc.name] = pc
        if default_class not in self.classes:
            self.classes[default_class] = PriorityClass(default_class)
        self.default_class = default_class
        self.default_max_new = default_max_new
        self.slo = SloTracker(
            {n: pc.slo for n, pc in self.classes.items()},
            counters=self.view.attach_slo())
        if not self.view._adopted:
            # multi-tenant fabric: the tenant is a priority class at its
            # view's level and the default class; operator-configured SLO
            # deadlines (a pre-declared class of the same name) survive
            existing = self.classes.get(self.view.name)
            self.ensure_class(PriorityClass(
                name=self.view.name, level=self.view.level,
                slo=existing.slo if existing is not None else SloSpec()))
            self.default_class = self.view.name
        # arbiter-driven allocation-cycle moves (co-scheduled DWP): re-home
        # live sequences when the view's assignment changes under us
        self.view.on_assignment_change(self._rehome_live)
        # all-holders re-homing (DESIGN.md §11) changes physical ids under
        # live sequences; patch every request's page list with the map
        self.view.on_page_remap(self._apply_page_remap)
        self._ids = itertools.count()
        self.queued: list[Request] = []
        self.prefilling: list[Request] = []
        self.running: list[Request] = []
        self.swapped: list[Request] = []
        self.finished: list[Request] = []
        self.now = 0.0
        self._plan: StepPlan | None = None

    # -- class registry ------------------------------------------------------

    def ensure_class(self, pc: PriorityClass) -> None:
        """Register (or update) a priority class — the arbiter routes each
        tenant through this so tenant priority == scheduling priority."""
        self.classes[pc.name] = pc
        self.slo.specs[pc.name] = pc.slo

    def level(self, r: Request) -> int:
        return self.classes[r.cls].level

    # -- admission -----------------------------------------------------------

    def allocatable_pages(self) -> int:
        """Pages a single sequence could ever hold at once: the view's
        capacity minus the swap reservation (reserved slots are for
        *parked* copies)."""
        reserved = self.swap.reserved_total if self.swap is not None else 0
        return self.view.capacity() - reserved

    def submit(self, prompt: Sequence[int], *, cls: str | None = None,
               max_new: int | None = None,
               arrival_s: float | None = None) -> int:
        cls = cls if cls is not None else self.default_class
        assert cls in self.classes, f"unknown priority class {cls!r}"
        r = Request(sid=next(self._ids), tokens=list(prompt), pages=[],
                    prompt_len=len(prompt), cls=cls,
                    max_new=(max_new if max_new is not None
                             else self.default_max_new),
                    arrival_s=arrival_s if arrival_s is not None
                    else self.now)
        # reject infeasible requests here — admitting one would let it
        # accumulate pages chunk by chunk until it wedges the whole engine
        # (speculative lookahead pages count: a verify step may transiently
        # hold spec_tokens positions past the final committed one)
        ps = self.view.page_size
        footprint = self.view.geometry.pages_for_tokens(
            r.prefill_target + r.max_new + self.spec_tokens)
        if footprint > self.allocatable_pages():
            # shared trie pages cannot rescue a single request's residency
            # bound — they still occupy pages it must hold — but the
            # submit-time probe names them so the error is diagnosable
            sharable = self.view.peek_prefix(r.tokens[:r.prompt_len]) // ps
            raise ValueError(
                f"request needs {footprint} KV pages ({sharable} currently "
                f"sharable via the prefix trie) but at most "
                f"{self.allocatable_pages()} are ever allocatable "
                "(view capacity minus swap reservation)")
        self.queued.append(r)
        self.slo.on_submit(r.sid, r.cls, r.arrival_s)
        obs = self.view.fabric.obs
        if obs is not None:
            obs.on_admit(self.view, r, self.now)
        return r.sid

    @property
    def pending(self) -> list[Request]:
        """Everything submitted but not finished and not in the batch."""
        return self.queued + self.prefilling + self.swapped

    # -- the per-step decision ------------------------------------------------

    def schedule(self) -> StepPlan:
        plan = StepPlan([], [], [], [], 0.0)
        self._plan = plan
        if not (self.running or self.prefilling or self.swapped
                or self._arrived()):
            nxt = min((r.arrival_s for r in self.queued), default=None)
            if nxt is not None and nxt > self.now:
                self.now = nxt           # idle: jump to the next arrival
        self._priority_preempt()
        self._stall_preempt()
        self._swap_ins(plan)
        self._plan_prefills(plan)
        self._ensure_growth()
        plan.batch = list(self.running)
        if self.micro_batch and len(plan.batch) > 1:
            plan.launch_groups = self._launch_groups(plan.batch)
        self._plan = None
        if (not plan.batch and not plan.prefill_chunks
                and not plan.swapped_in and not plan.swapped_out
                and self.pending):
            future = [r.arrival_s for r in self.queued
                      if r.arrival_s > self.now]
            if future:
                # blocked but more requests are due: jump to them (they can
                # only be scheduled, never free capacity, so if nothing is
                # admissible once all have arrived we raise below)
                self.now = min(future)
            else:
                # no step will ever change this state — fail like the bare
                # allocator did instead of spinning
                raise RuntimeError(
                    "KV pool exhausted: pending requests but no admissible "
                    "work (pool too small or swap slots depleted)")
        return plan

    def _arrived(self) -> list[Request]:
        out = [r for r in self.queued if r.arrival_s <= self.now]
        out.sort(key=self._order)
        return out

    def _order(self, r: Request):
        return (-self.level(r), r.arrival_s, r.sid)

    def _slots_used(self) -> int:
        return len(self.running) + len(self.prefilling)

    def _growth_need(self, seqs) -> int:
        """Decode pages the next step will allocate for ``seqs``."""
        return sum(self._seq_growth(r.length, r.pages) for r in seqs)

    def _future_pages(self, r: Request) -> int:
        """Pages ``r`` will still allocate over its whole lifetime: the
        logical worst case (prompt + max_new + speculative lookahead) minus
        pages already held — shared trie pages included, which is what
        makes the bound *physical* — plus a CoW clone when the first
        decode write lands in a currently-shared page."""
        ps = self.view.page_size
        total = self.view.geometry.pages_for_tokens(
            r.prefill_target + r.max_new + self.spec_tokens)
        cow = 1 if (r.pages and r.prefill_target // ps < len(r.pages)
                    and self.view.shared(r.pages[r.prefill_target // ps])) \
            else 0
        return max(0, total + cow - len(r.pages))

    def _admitted_future(self) -> int:
        """Remaining lifetime pages of everything already in the batch."""
        return sum(self._future_pages(r)
                   for r in self.running + self.prefilling)

    def demand_pages(self) -> int:
        """Pages the current workload still wants beyond what the view
        can allocate right now — the capacity market's demand signal
        (``placement.zoo``): pending requests' lifetime footprints plus
        the running batch's next-step growth, minus free capacity.
        0 means satisfied; positive means this tenant is starved and
        values annexed funding at its Eq.-1 stall exposure."""
        need = sum(self._future_pages(r) for r in self.pending) \
            + self._growth_need(self.running)
        return max(0, need - self.view.free_count())

    def _seq_growth(self, length: int, pages) -> int:
        """Pages one sequence's next decode step may allocate: enough fresh
        pages to cover the write span ``[length, length + spec_tokens]``
        (one page per step when speculation is off), plus a CoW clone when
        the first write position falls inside a *shared* page (the
        full-prompt-match fork)."""
        ps = self.view.page_size
        need = max(0, self.view.geometry.pages_for_tokens(
            length + self.spec_tokens + 1) - len(pages))
        if length % ps and pages \
                and self.view.shared(pages[length // ps]):
            need += 1
        return need

    # -- preemption -----------------------------------------------------------

    def _exclusive(self, r: Request) -> int:
        """Pages an eviction of ``r`` actually frees: its refcount-1 pages.
        Shared (prefix) pages are pinned — other sequences read them."""
        return len(self.view.exclusive(r.pages))

    def victim_score(self, r: Request) -> float:
        """priority-factor x footprint x Eq.-1 stall cost (DESIGN.md §5):
        ``2^-level`` halves a victim's attractiveness per priority level;
        footprint is the *bytes* the eviction frees (exclusive pages only —
        shared prefix pages stay put; byte-denominated so scores compare
        across page geometries, DESIGN.md §12); the stall term prefers
        sequences whose pages already gate the batch's read time."""
        stall = self.view.stall_cost(r.pages)
        freed_bytes = self._exclusive(r) * float(self.view.page_bytes)
        return (2.0 ** -self.level(r)) * freed_bytes * (stall + 1e-12)

    def _swap_out(self, r: Request) -> None:
        pages = self._exclusive(r)
        r.pages, secs = self.swap.swap_out(r.pages)
        self.running.remove(r)
        r.state = State.SWAPPED
        self.swapped.append(r)
        self.slo.on_preempt(r.sid, pages)
        obs = self.view.fabric.obs
        if obs is not None:
            obs.on_preempt(self.view, r, self.now, secs, pages)
        if self._plan is not None:
            self._plan.swapped_out.append(r)
            self._plan.swap_seconds += secs

    def _reclaim(self, need: int, max_level: int | None = None) -> bool:
        """Swap out victims until ``need`` pages are allocatable. Never
        touches classes above ``max_level`` (capacity pressure from a low
        class must not evict a high one). Victims must free at least one
        page — evicting an all-shared sequence reclaims nothing."""
        while self.view.free_count() < need:
            if self.swap is None:
                return False
            protect = self._plan.swapped_in if self._plan is not None else []
            victims = [r for r in self.running if self._exclusive(r) > 0
                       and r not in protect   # no same-step in->out churn
                       and (max_level is None or self.level(r) <= max_level)
                       and self.swap.can_swap_out(self._exclusive(r))]
            if not victims:
                return False
            self._swap_out(max(victims, key=self.victim_score))
        return True

    def _priority_preempt(self) -> None:
        """Make a batch slot for the best waiting request by evicting a
        strictly lower class (victim choice by ``victim_score``)."""
        if self.swap is None:
            return
        cands = sorted(self._arrived() + self.swapped, key=self._order)
        if not cands or self._slots_used() < self.max_batch:
            return
        cand = cands[0]
        lower = [r for r in self.running if self.level(r) < self.level(cand)
                 and r.pages and self.swap.can_swap_out(self._exclusive(r))]
        if lower:
            self._swap_out(max(lower, key=self.victim_score))

    def _stall_preempt(self) -> None:
        """Stall-triggered preemption: when one sequence's Eq.-1 KV read
        time exceeds ``stall_preempt_fraction`` of the whole batch's read
        time, its pages are gating every token the batch produces — evict
        it (the worst offender, one per step) so the rest of the batch runs
        at the speed of its own placement. The victim sits out
        ``stall_preempt_cooldown_s`` of virtual time before resuming."""
        frac = self.stall_preempt_fraction
        if frac is None or self.swap is None or len(self.running) < 2:
            return
        batch = self.view.stall_cost(
            [p for r in self.running for p in r.pages])
        if batch <= 0.0:
            return
        offenders = [
            r for r in self.running
            if self._exclusive(r) > 0
            and self.swap.can_swap_out(self._exclusive(r))
            and self.view.stall_cost(r.pages) > frac * batch]
        if offenders:
            victim = max(offenders,
                         key=lambda r: self.view.stall_cost(r.pages))
            victim.resume_after = self.now + self.stall_preempt_cooldown_s
            self._swap_out(victim)

    # -- resume ---------------------------------------------------------------

    def _swap_ins(self, plan: StepPlan) -> None:
        for r in sorted(self.swapped, key=self._order):
            if r in plan.swapped_out:    # no same-step thrash
                continue
            if r.resume_after > self.now:   # stall-preemption cooldown
                continue
            if self._slots_used() >= self.max_batch:
                break
            # promotable footprint re-allocates: pages parked in slots AND
            # pages demoted to the persistent tier; pinned shared pages
            # never left
            need = (self.swap.promotable_count(r.pages)
                    + self._seq_growth(r.length, r.pages)
                    + self._growth_need(self.running))
            if self.conservative_admission:
                need = max(need, self.swap.promotable_count(r.pages)
                           + self._future_pages(r)
                           + self._admitted_future())
            if self.view.free_count() < need:
                continue
            r.pages, secs = self.swap.swap_in(r.pages)
            self.swapped.remove(r)
            r.state = State.RUNNING
            self.running.append(r)
            self.slo.on_resume(r.sid, len(r.pages))
            obs = self.view.fabric.obs
            if obs is not None:
                obs.on_resume(self.view, r, self.now, secs)
            plan.swapped_in.append(r)
            plan.swap_seconds += secs

    # -- chunked prefill ------------------------------------------------------

    def _plan_prefills(self, plan: StepPlan) -> None:
        ps = self.view.page_size
        budget = self.prefill_token_budget
        if self.spec_tokens:
            # draft+verify accounting: every running sequence's decode this
            # step is a (1 + spec_tokens)-token forward through the same
            # batched prefill-mode op prefill chunks use — charge it
            # against the shared per-step token budget first, so a step's
            # total forward tokens stay bounded (running sequences always
            # decode; prefill takes what is left)
            budget -= len(self.running) * (1 + self.spec_tokens)
            if budget <= 0:
                return
        in_flight = sorted(self.prefilling, key=self._order)
        fresh = self._arrived()
        for r in in_flight + fresh:
            if budget <= 0:
                break
            if r.state is State.QUEUED \
                    and self._slots_used() >= self.max_batch:
                continue                 # a lower class may still fit later
            if r.state is State.QUEUED and not r.pages and r.length == 0:
                # probe the prefix trie — matched pages join the view
                # shared (refcount bumps), their K/V already sits in the
                # pool, and prefill starts past them. A capacity-blocked
                # request re-probes next step (a donor may register late);
                # only the first probe counts in telemetry.
                matched = self.view.probe_prefix(
                    r.tokens[:r.prompt_len], r.pages, count=not r.probed)
                r.probed = True
                # a full-prompt match still leaves the last prompt token to
                # the first decode step (it CoW-forks the shared page)
                r.length = min(matched, r.prefill_target)
            target = r.prefill_target
            chunk = min(budget, target - r.length)
            hi = r.length + chunk
            new_pages = -(-hi // ps) - len(r.pages)
            # reserve the first decode step's pages too when this chunk
            # completes the prefill, so the sequence can decode (with
            # speculation the first verify step may span several pages)
            done_now = hi == target
            first_decode = 0
            if done_now:
                first_decode = max(
                    0, -(-(target + self.spec_tokens + 1) // ps)
                    - (-(-hi // ps)))
            need = new_pages + self._growth_need(self.running) + first_decode
            if self.conservative_admission and r.state is State.QUEUED:
                # admit only if the whole batch (this request included)
                # can still run to completion on free pages alone
                need = max(need, self._future_pages(r)
                           + self._admitted_future())
            if self.view.free_count() < need and \
                    not self._reclaim(need, max_level=self.level(r)):
                continue
            self.view.grow(r.pages, new_pages)
            # NB: trie registration happens in the *engine* after the final
            # chunk's K/V physically lands (registering at plan time let a
            # same-step matcher bump refcounts before the donor's write,
            # which then CoW-forked the donor onto private clones and left
            # the matcher reading never-written pages)
            if chunk > 0:
                plan.prefill_chunks.append((r, r.length, hi))
                budget -= chunk
                # advance now so growth accounting sees the post-chunk
                # length; the engine writes K/V from the plan's (lo, hi)
                r.length = hi
            if r.state is State.QUEUED:
                self.queued.remove(r)
                if done_now:
                    r.state = State.RUNNING
                    self.running.append(r)
                else:
                    r.state = State.PREFILL
                    self.prefilling.append(r)
            elif done_now:
                self.prefilling.remove(r)
                r.state = State.RUNNING
                self.running.append(r)

    def _launch_groups(self, batch) -> list | None:
        """Partition the decode batch by Eq.-1 bottleneck domain
        (DESIGN.md §11): each sequence joins the micro-batch of the domain
        that gates *its own* read, so a launch's bottleneck bytes all
        belong to its sequences and launches to different domain groups
        overlap. The step stall becomes the max over per-launch
        bottlenecks — never worse than the global max, and strictly
        better whenever no single domain carries every launch's
        bottleneck. Cross-launch traffic inside one domain is
        second-order here; the drift ledger's per-launch billing absorbs
        the residual model error into calibration. Returns ``None`` when
        every sequence lands in one group (a global launch is identical
        and skips the partition bookkeeping)."""
        bw = self.view.bw * 1e9
        fallback = int(np.argmax(bw))    # pageless sequence: fastest domain
        groups: dict[int, list] = {}
        for r in batch:
            bpd = self.view.footprint(r.pages)
            dom = int(np.argmax(bpd / bw)) if bpd.sum() > 0 else fallback
            groups.setdefault(dom, []).append(r)
        if len(groups) <= 1:
            return None
        return [(d, groups[d]) for d in sorted(groups)]

    def _apply_page_remap(self, moves: dict) -> None:
        """All-holders re-homing moved physical pages under us: swap the
        old ids for the new ones in every live request's page list (a
        queued request can hold trie-matched pages from the admission
        probe; a swapped request's list keeps its *shared* pages live)."""
        for r in (self.queued + self.prefilling + self.running
                  + self.swapped):
            if r.pages:
                r.pages = [moves.get(p, p) for p in r.pages]

    def _rehome_live(self) -> None:
        """The view's allocation cycle moved under us (arbiter-driven
        co-scheduled tuning): re-home live sequences' pages per the new
        weights (one batched gather/scatter; shared pages stay pinned)."""
        for r in self.running:
            r.pages = self.view.migrate(r.pages)

    def _ensure_growth(self) -> None:
        """The decode batch must be able to allocate its next pages; evict
        (any class — an undecodable batch serves nobody) or fail loudly."""
        while self.view.free_count() < self._growth_need(self.running):
            victims = [r for r in self.running if self._exclusive(r) > 0
                       and self.swap is not None
                       and self.swap.can_swap_out(self._exclusive(r))]
            if not victims:
                raise RuntimeError("KV pool exhausted: decode batch cannot "
                                   "grow and no victim is swappable")
            self._swap_out(max(victims, key=self.victim_score))

    # -- completion + clock (driven by the engine) ----------------------------

    def advance(self, seconds: float) -> None:
        self.now += float(seconds)

    def notice_first_token(self, r: Request) -> None:
        self.slo.on_first_token(r.sid, self.now)

    def finish(self, r: Request) -> None:
        r.done = True
        r.state = State.FINISHED
        # drop this request's references; pages nobody else holds are
        # freed, pages shared with live sequences stay (and stay matchable)
        self.view.release(r.pages)
        r.pages = []
        self.running.remove(r)
        self.finished.append(r)
        self.slo.on_finish(r.sid, self.now, r.produced)
        obs = self.view.fabric.obs
        if obs is not None:
            obs.on_finish(self.view, r, self.now)

    # -- reporting ------------------------------------------------------------

    def stats(self) -> dict:
        return {
            "now_s": self.now,
            "queued": len(self.queued),
            "prefilling": len(self.prefilling),
            "running": len(self.running),
            "swapped": len(self.swapped),
            "finished": len(self.finished),
            "swap_slots_free": (self.swap.slots_free()
                                if self.swap else 0),
            "demoted_pages": (self.swap.demoted_count()
                              if self.swap else 0),
            "slo": self.slo.summary(self.now),
        }
