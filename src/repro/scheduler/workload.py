"""Trace-driven workload generation for the request scheduler.

Serving behavior under memory pressure depends on the *shape* of demand, not
just its mean: bursts force preemption, heavy-tailed prompts create the
large-footprint victims swap exists for. Three arrival processes (all
deterministic under a seed):

``poisson``     exponential interarrivals — the steady-state baseline.
``bursty``      on/off: bursts of back-to-back arrivals separated by idle
                gaps (mean rate preserved) — stresses admission + preemption.
``heavy_tail``  Pareto interarrivals and prompt lengths — a few huge
                requests among many small ones, the classic LLM-serving mix.
``domain_skew`` a near-zero-gap flood of long-prompt requests fills the
                fast domains first; a steady tail of short templated
                requests (carrying the shared prefix) arrives while they
                are full, so its pages land in slow domains — the
                contention pattern heat-driven re-homing (DESIGN.md §11)
                exists to fix.
``hot_prefix``  steady arrivals that all share one long hot system
                prompt — the maximally-shared-prefix stress for
                all-holders re-homing and the prefix trie.

``generate`` yields a time-sorted list of :class:`TraceRequest`; the driver
submits each to the scheduler with its arrival timestamp and the scheduler's
virtual clock does the rest.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    arrival_s: float
    prompt: tuple[int, ...]
    max_new: int
    cls: str = "default"


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Knobs shared by all trace kinds.

    ``class_mix`` maps priority-class name -> probability; ``kind``-specific
    parameters are ignored by the other kinds.
    """

    kind: str = "poisson"  # poisson|bursty|heavy_tail|domain_skew|hot_prefix
    num_requests: int = 16
    mean_interarrival_s: float = 0.05
    prompt_mean: int = 12
    prompt_max: int = 64
    max_new: int = 16
    vocab_size: int = 1000
    class_mix: tuple[tuple[str, float], ...] = (("default", 1.0),)
    seed: int = 0
    # bursty
    burst_len: int = 4                  # requests per burst
    burst_factor: float = 8.0           # gap/mean ratio between bursts
    # heavy_tail
    tail_alpha: float = 1.5             # Pareto shape (smaller = heavier)
    # domain_skew: fraction of requests in the leading flood (long prompts,
    # back-to-back, no shared prefix — they claim the fast domains); the
    # rest arrive at the steady rate and carry the prefix machinery
    skew_frac: float = 0.5
    # shared prefixes (any kind): with probability ``prefix_frac`` a request
    # prepends one of ``prefix_groups`` common prefixes of ``prefix_len``
    # tokens — the system prompt / few-shot template pattern that makes
    # prefix-sharing KV caches pay (DESIGN.md §6)
    prefix_len: int = 0
    prefix_groups: int = 1
    prefix_frac: float = 1.0
    # repetition-friendly prompts (any kind): with ``prompt_loop_len > 0``
    # each prompt body is a random motif of that length tiled to the drawn
    # prompt length — the templated / copy-heavy structure that makes
    # n-gram self-drafting (serve/spec.py, DESIGN.md §7) accept at high
    # rates; 0 keeps fully random bodies
    prompt_loop_len: int = 0


def _skew_head(spec: WorkloadSpec) -> int:
    """Requests in the domain_skew leading flood (at least one, and at
    least one steady-tail request remains)."""
    return min(max(1, int(round(spec.num_requests * spec.skew_frac))),
               spec.num_requests - 1)


def _interarrivals(spec: WorkloadSpec, rng: np.random.Generator) -> np.ndarray:
    n, mean = spec.num_requests, spec.mean_interarrival_s
    if spec.kind in ("poisson", "hot_prefix"):
        return rng.exponential(mean, size=n)
    if spec.kind == "domain_skew":
        # leading flood back-to-back, then the steady tail
        gaps = rng.exponential(mean, size=n)
        gaps[:_skew_head(spec)] = mean / 100.0
        return gaps
    if spec.kind == "bursty":
        # within a burst: near-zero gaps; between bursts: one long gap sized
        # so the long-run mean interarrival stays ``mean``
        gaps = np.full(n, mean / spec.burst_factor)
        start = np.arange(n) % spec.burst_len == 0
        per_burst = spec.burst_len * mean \
            - (spec.burst_len - 1) * mean / spec.burst_factor
        gaps[start] = per_burst
        return gaps * rng.uniform(0.8, 1.2, size=n)   # jitter, seeded
    if spec.kind == "heavy_tail":
        # Pareto with E[x] = mean: x = xm * (1 + P(alpha)), xm = mean*(a-1)/a
        a = spec.tail_alpha
        xm = mean * (a - 1.0) / a if a > 1 else mean
        return xm * (1.0 + rng.pareto(a, size=n))
    raise ValueError(f"unknown workload kind {spec.kind!r}")


def _prompt_lengths(spec: WorkloadSpec, rng: np.random.Generator) -> np.ndarray:
    n = spec.num_requests
    if spec.kind == "heavy_tail":
        a = spec.tail_alpha
        xm = max(spec.prompt_mean * (a - 1.0) / a, 1.0) if a > 1 \
            else float(spec.prompt_mean)
        lens = xm * (1.0 + rng.pareto(a, size=n))
    else:
        # lognormal around the mean: multiplicative spread, never < 1
        lens = rng.lognormal(np.log(max(spec.prompt_mean, 1)), 0.4, size=n)
    if spec.kind == "domain_skew":
        # the flood is all long prompts — it must actually fill the fast
        # domains before the steady tail shows up
        lens[:_skew_head(spec)] = spec.prompt_max
    return np.clip(np.round(lens), 1, spec.prompt_max).astype(np.int64)


def generate(spec: WorkloadSpec) -> list[TraceRequest]:
    """Deterministic trace: same spec (including seed) -> same requests."""
    rng = np.random.default_rng(spec.seed)
    arrivals = np.cumsum(_interarrivals(spec, rng))
    lens = _prompt_lengths(spec, rng)
    names = [c for c, _ in spec.class_mix]
    probs = np.asarray([p for _, p in spec.class_mix], dtype=np.float64)
    probs = probs / probs.sum()
    classes = rng.choice(len(names), size=spec.num_requests, p=probs)
    # hot_prefix with no explicit prefix config defaults to one long
    # shared system prompt every request carries
    plen, pgroups, pfrac = (spec.prefix_len, spec.prefix_groups,
                            spec.prefix_frac)
    if spec.kind == "hot_prefix" and plen == 0:
        plen, pgroups, pfrac = 2 * spec.prompt_mean, 1, 1.0
    prefixes = [tuple(int(t) for t in
                      rng.integers(1, spec.vocab_size, plen))
                for _ in range(pgroups)] if plen else []
    skew_head = _skew_head(spec) if spec.kind == "domain_skew" else 0
    out = []
    for i in range(spec.num_requests):
        head: tuple[int, ...] = ()
        # domain_skew: the flood carries no prefix (and consumes no rng
        # draws for it) — only the steady tail shares the template
        if prefixes and i >= skew_head and rng.uniform() < pfrac:
            head = prefixes[int(rng.integers(len(prefixes)))]
        n = int(lens[i])
        if spec.prompt_loop_len > 0:
            motif = rng.integers(1, spec.vocab_size,
                                 min(spec.prompt_loop_len, n))
            body = tuple(int(motif[j % len(motif)]) for j in range(n))
        else:
            body = tuple(int(t) for t in rng.integers(1, spec.vocab_size, n))
        prompt = head + body
        out.append(TraceRequest(arrival_s=float(arrivals[i]), prompt=prompt,
                                max_new=spec.max_new,
                                cls=names[int(classes[i])]))
    return out


def total_kv_pages(trace: list[TraceRequest], page_size: int) -> int:
    """Aggregate page footprint if every request were live at once — the
    oversubscription ratio vs ``hbm_local`` capacity is footprint/capacity."""
    return sum(-(-(len(t.prompt) + t.max_new) // page_size) for t in trace)
