"""KV swap manager: park preempted sequences' pages in slow domains.

The paper's Observation 1 — slow domains are wasted capacity unless placement
uses them — applies twice in serving. Live decode pages spread per BWAP
weights, and *cold* pages (sequences preempted by the scheduler) should not
occupy fast-HBM capacity at all: they park in reserved slots carved out of
the slow domains, freeing fast pages for the running batch. That is what
lets total live KV exceed ``hbm_local`` capacity.

All placement access goes through a :class:`repro.placement.fabric.FabricView`
(DESIGN.md §8): the view hands out reserved slots (``view.reserve``), moves
bytes (``park_pages``/``unpark_pages``), and keeps the fabric's ownership
and refcount ledgers consistent. A swap-out distributes a victim's pages
over the view's slow domains through a policy from the placement registry —
``bwap_canonical`` (weights ∝ slow-domain bandwidth) by default, ``uniform``
/ ``local_first`` as the baselines ``benchmarks/scheduler_bench.py``
compares — and executes the copies as one batched gather/scatter per pool
array. Swap-in allocates destinations through the view's live placement
policy and returns the vacated slots to the reservation.

**Cross-tenant slot loans** (ROADMAP arbiter-level swap): the manager
registers as a slot provider on its view. When a bursty tenant runs out of
reserved slots, ``swap_out`` borrows idle slots from co-tenant reservations
through the fabric's loan broker; when a lender runs short, its shortfall
recalls the loans — borrowers return idle slots instantly and vacate parked
ones by relocating the bytes into their remaining reservation (one batched
copy whose Eq.-1 time is charged to the loan record and to the reclaiming
swap-out).

Transfer cost is the Eq.-1 max-parallel-transfer time of the slower side of
the copy under the fabric's *effective* (calibrated) bandwidths; the engine
folds it into the step latency, which is how swap-placement quality reaches
goodput.
"""

from __future__ import annotations

import numpy as np

from repro.placement import policy as placement_policy
from repro.placement.fabric import as_view


class KVSwapManager:
    """Swap-slot reservation + bandwidth-aware swap placement for one
    fabric view (a bare pool is adopted into a single-view fabric)."""

    def __init__(self, pool, *, placement: str = "bwap_canonical",
                 reserve_fraction: float = 0.5,
                 reserve_pages: dict[str, int] | None = None,
                 lend: bool = True, borrow: bool = True):
        """``reserve_fraction`` of every slow (non-home) domain's currently
        free pages is reserved, unless ``reserve_pages`` gives explicit
        per-domain counts (by domain name; missing names reserve zero).
        ``lend``/``borrow`` opt this tenant in or out of the fabric's
        cross-tenant slot-loan broker."""
        self.view = as_view(pool)
        self.placement = placement_policy.resolve(placement)
        self.slow = list(self.view.slow_domains)
        assert self.slow, "swap needs at least one non-home domain"
        self.lend = lend
        self.borrow = borrow
        self.slots: dict[int, list[int]] = {}
        for d in self.slow:
            if reserve_pages is not None:
                n = int(reserve_pages.get(self.view.domains[d].name, 0))
            else:
                n = int(self.view.free_domain_count(d) * reserve_fraction)
            self.slots[d] = self.view.reserve(d, n)
        self.reserved_total = sum(len(s) for s in self.slots.values())
        self._out: set[int] = set()   # slot ids currently holding parked KV
        self._borrowed: set[int] = set()   # slots on loan from co-tenants
        self._lent: set[int] = set()       # own slots currently loaned out
        self._moved: dict[int, int] = {}   # parked-page forwarding (vacate)
        self._demoted: set[int] = set()    # tier handles (persist demotion)
        self._park_order: dict[int, int] = {}   # slot -> park stamp (cold)
        self._park_stamp = 0
        self.view.offer_slots(self)

    @property
    def persist(self):
        """The fabric's persistent tier, if one is attached — looked up
        live so a tier attached after this manager was built still counts
        as demotion headroom."""
        return self.view.fabric.persist

    # -- capacity ------------------------------------------------------------

    def slots_free(self) -> int:
        return sum(len(s) for s in self.slots.values())

    def can_swap_out(self, num_pages: int) -> bool:
        """Counts slots in hand plus what the loan broker could actually
        deliver — borrowable idle co-tenant slots in *this tenant's* slow
        domains and instantly-recallable slots this tenant has on loan —
        plus slots the persistent tier could vacate by demoting the
        coldest parked pages."""
        avail = self.slots_free()
        if self.borrow:
            avail += self.view.borrowable()
        if self._lent:
            avail += self.view.recallable()
        if self.persist is not None:
            avail += min(len(self._out), self.persist.capacity_left())
        return avail >= num_pages

    def parked_count(self, page_ids) -> int:
        """How many of a view's pages currently sit in reserved slots (the
        ones swap-in must re-allocate; pinned shared pages never parked)."""
        return sum(1 for p in page_ids if self._resolve(p) in self._out)

    def promotable_count(self, page_ids) -> int:
        """Pages swap-in must re-allocate: parked in reserved slots *plus*
        demoted into the persistent tier — admission sizes a swapped
        sequence's resume footprint with this, not ``parked_count``."""
        n = 0
        for p in page_ids:
            q = self._resolve(p)
            n += q in self._out or q in self._demoted
        return n

    def demoted_count(self) -> int:
        return len(self._demoted)

    def _resolve(self, pid: int) -> int:
        """Chase the forwarding chain of a parked page that a loan reclaim
        relocated after its sequence recorded the id."""
        while pid in self._moved:
            pid = self._moved[pid]
        return pid

    def _ensure_slots(self, n: int) -> float:
        """Make ``n`` slots available, borrowing from co-tenants,
        recalling own loans, and finally demoting the coldest parked pages
        into the persistent tier. Returns the Eq.-1 seconds spent vacating
        recalled slots and demoting (charged to this swap-out)."""
        seconds = 0.0
        short = n - self.slots_free()
        if short > 0 and self.borrow:
            short -= self.view.request_loan(short)
        if short > 0 and self._lent:
            _, secs = self.view.recall_loans(short)
            seconds += secs
        short = n - self.slots_free()
        if short > 0 and self.persist is not None:
            _, secs = self.demote_cold(short)
            seconds += secs
        return seconds

    def demote_cold(self, n: int) -> tuple[int, float]:
        """Vacate up to ``n`` reserved slots by demoting the
        longest-parked (coldest) pages into the persistent tier. Eq.-1
        priced through the tier's bandwidth row; the freed slots rejoin
        the reservation and the forwarding map chases slot -> handle, so
        a later ``swap_in`` promotes transparently. Returns
        ``(pages_demoted, seconds)``."""
        tier = self.persist
        if tier is None or not self._out or n <= 0:
            return 0, 0.0
        n = min(n, len(self._out), tier.capacity_left())
        if n <= 0:
            return 0, 0.0
        cold = sorted(self._out,
                      key=lambda p: self._park_order.get(p, 0))[:n]
        handles, seconds = tier.demote(self.view, cold)
        for p, h in zip(cold, handles):
            self._out.discard(p)
            self._park_order.pop(p, None)
            self.slots[self.view.domain_of(p)].append(int(p))
            self._moved[p] = h
            self._demoted.add(h)
        return len(cold), seconds

    # -- loan-broker provider protocol (fabric calls these) --------------------

    def lendable_count(self, domains=None) -> int:
        """Idle own slots the broker may take — optionally restricted to
        ``domains`` (a borrower can only park in its own slow domains, so
        an unfiltered count would over-promise)."""
        if not self.lend:
            return 0
        return sum(1 for d in self.slots
                   if domains is None or d in domains
                   for p in self.slots[d] if p not in self._borrowed)

    def idle_count(self, ids) -> int:
        free = {p for s in self.slots.values() for p in s}
        return sum(1 for p in ids if p in free)

    def lend_slots(self, n: int, domains) -> list[int]:
        """Hand up to ``n`` idle own slots in ``domains`` to the broker."""
        out: list[int] = []
        if not self.lend:
            return out
        for d in self.slots:
            if d not in domains:
                continue
            keep = [p for p in self.slots[d] if p in self._borrowed]
            own = [p for p in self.slots[d] if p not in self._borrowed]
            while own and len(out) < n:
                out.append(own.pop())
            self.slots[d] = keep + own
        self._lent.update(out)
        return out

    def take_slots(self, ids) -> None:
        """Receive slots from the broker: a granted loan, or own slots
        coming back from a reclaim."""
        for p in ids:
            d = self.view.domain_of(p)
            self.slots.setdefault(d, []).append(int(p))
            if p in self._lent:
                self._lent.discard(p)
            else:
                self._borrowed.add(int(p))

    def yield_slots(self, ids) -> tuple[list[int], float]:
        """Give back loaned slots on recall. Idle ones return instantly;
        parked ones vacate by relocating their bytes into this manager's
        remaining slots (one batched copy, Eq.-1 cost). Slots that cannot
        vacate (no room left) stay borrowed."""
        returned: list[int] = []
        ids = set(ids)
        for d in self.slots:
            stay = []
            for p in self.slots[d]:
                if p in ids and len(returned) < len(ids):
                    returned.append(p)
                else:
                    stay.append(p)
            self.slots[d] = stay
        seconds = 0.0
        parked = [p for p in ids if p in self._out]
        if parked:
            src, dst = [], []
            for p in parked:
                home = None
                for d in self.slots:
                    spare = [q for q in self.slots[d]
                             if q not in ids and q not in dst]
                    if spare:
                        home = spare[-1]
                        break
                if home is None:
                    continue            # nowhere to vacate: stays borrowed
                self.slots[self.view.domain_of(home)].remove(home)
                src.append(p)
                dst.append(home)
            if src:
                self.view.repark_pages(src, dst)
                for s, t in zip(src, dst):
                    self._out.discard(s)
                    self._out.add(t)
                    self._moved[s] = t
                    if s in self._park_order:
                        self._park_order[t] = self._park_order.pop(s)
                    returned.append(s)
                seconds = self._transfer_seconds(
                    [self.view.domain_of(s) for s in src],
                    [self.view.domain_of(t) for t in dst])
        for p in returned:
            self._borrowed.discard(p)
        return returned, seconds

    def parked_ids(self):
        return set(self._out)

    # -- teardown --------------------------------------------------------------

    def release_parked(self, page_ids) -> list[int]:
        """A swapped-out sequence died: discard its parked KV in place (no
        copies) — the slots rejoin the reservation, the table references
        drop. Returns the page ids that were *not* parked (live shared
        pages the caller releases normally)."""
        live: list[int] = []
        for p in page_ids:
            q = self._forward(p)         # retire the chain: the slot may
            if q in self._out:           # be re-lent and re-parked later
                self._out.discard(q)
                self._park_order.pop(q, None)
                self.slots[self.view.domain_of(q)].append(int(q))
                self.view.drop_parked_ref(q)
            elif q in self._demoted:     # died cold: drop the tier bytes
                self._demoted.discard(q)
                self.view.drop_parked_ref(q)
                if q not in self.view.table.ref:
                    self.persist.forget(q)
            else:
                live.append(q)
        return live

    def close(self) -> None:
        """Tear down the reservation (tenant leaving): loans settle
        through the fabric (borrowed slots go home, lent slots come back
        or transfer their charge), then every remaining slot returns to
        the allocator. Requires no parked KV — swap sequences in or
        ``release_parked`` them first."""
        assert not self._out, "close() with parked KV still in slots"
        assert not self._demoted, "close() with KV still in the tier"
        self.view.settle_loans()
        for d in list(self.slots):
            for p in self.slots[d]:
                self.view.unreserve(p)
            self.slots[d] = []
        self.reserved_total = 0
        self._borrowed.clear()
        self._lent.clear()
        self.view.withdraw_slots()

    # -- placement over the slow-domain subspace ------------------------------

    def _slot_domains(self) -> list[int]:
        return sorted(self.slots)

    def _slot_counts(self, num_pages: int) -> np.ndarray:
        """How many of ``num_pages`` go to each slow domain (policy-weighted,
        clamped to available slots; order = ``_slot_domains``)."""
        doms = self._slot_domains()
        ctx = placement_policy.PlacementContext(
            bandwidths=np.asarray([self.view.domains[d].read_bw
                                   for d in doms]),
            num_pages=num_pages,
            capacities=np.asarray([len(self.slots[d]) for d in doms]))
        return self.placement.counts(ctx)

    # -- the round-trip -------------------------------------------------------

    def swap_out(self, page_ids: list[int],
                 table=None) -> tuple[list[int], float]:
        """Move a sequence's pages into reserved slow-domain slots; frees
        the sources back to the fabric. Returns ``(new_page_ids, seconds)``
        with page order preserved (the view stays positional). ``table`` is
        accepted for backward compatibility and must be the view's own
        page table — pinning and remapping always ride the fabric now.

        Pages with refcount > 1 are *pinned*: other live sequences read
        them, so they keep their fast-domain homes and only this sequence's
        exclusive pages park. Moved pages leave the prefix trie (a parked
        page must not be matched — its id changes again on swap-in) and the
        fabric carries refcounts and holds onto the slots."""
        assert table is None or table is self.view.table, \
            "swap rides the fabric view's own page table"
        movable = [p for p in page_ids if not self.view.shared(p)]
        n = len(movable)
        if n == 0:
            return list(page_ids), 0.0
        loan_seconds = self._ensure_slots(n)
        assert self.slots_free() >= n, "not enough reserved swap slots"
        counts = self._slot_counts(n)
        dst: list[int] = []
        for d, c in zip(self._slot_domains(), counts):
            dst.extend(self.slots[d].pop() for _ in range(int(c)))
        src_doms = [self.view.domain_of(p) for p in movable]
        dst_doms = [self.view.domain_of(p) for p in dst]
        self.view.park_pages(movable, dst)
        moved = dict(zip(movable, dst))
        self._out.update(dst)
        for p in dst:                      # park order drives cold demotion
            self._park_stamp += 1
            self._park_order[p] = self._park_stamp
        seconds = self._transfer_seconds(src_doms, dst_doms) + loan_seconds
        self.view.telemetry.record_swap("out", n, seconds)
        return [moved.get(p, p) for p in page_ids], seconds

    def swap_in(self, page_ids: list[int],
                table=None) -> tuple[list[int], float]:
        """Bring parked pages back through the view's live placement
        policy; vacated slots rejoin the reservation. Pages that demoted
        to the persistent tier promote back through the same forwarding
        map, bit-exactly. Pages of the view that never parked (pinned
        shared pages) pass through untouched. Caller guarantees the view
        has enough allocatable pages (the scheduler checks against the
        promotable count)."""
        assert table is None or table is self.view.table, \
            "swap rides the fabric view's own page table"
        page_ids = [self._forward(p) for p in page_ids]
        parked = [p for p in page_ids if p in self._out]
        demoted = [p for p in page_ids if p in self._demoted]
        if not parked and not demoted:
            return list(page_ids), 0.0
        moved: dict[int, int] = {}
        seconds = 0.0
        if parked:
            src_doms = [self.view.domain_of(p) for p in parked]
            dst = self.view.unpark_pages(parked)
            dst_doms = [self.view.domain_of(p) for p in dst]
            moved.update(zip(parked, dst))
            for pid in parked:
                self._out.discard(pid)
                self._park_order.pop(pid, None)
                self.slots[self.view.domain_of(pid)].append(int(pid))
            secs = self._transfer_seconds(src_doms, dst_doms)
            self.view.telemetry.record_swap("in", len(parked), secs)
            seconds += secs
        if demoted:
            dst, secs = self.persist.promote(self.view, demoted)
            moved.update(zip(demoted, dst))
            self._demoted.difference_update(demoted)
            seconds += secs
        return [moved.get(p, p) for p in page_ids], seconds

    def _forward(self, pid: int) -> int:
        """Resolve (and retire) the forwarding chain for one page id."""
        out = pid
        while out in self._moved:
            out = self._moved.pop(out)
        return out

    def _transfer_seconds(self, src_doms, dst_doms) -> float:
        """Eq.-1 cost of the copy under the fabric's effective bandwidths:
        reads and writes overlap across domains, so the transfer takes the
        slower of the two sides. Sized per geometry — ``view.page_bytes``
        comes from the group's :class:`PageGeometry` (DESIGN.md §12), so
        swapping an MLA latent page bills its true (much smaller) byte
        cost, not the dense-transformer constant."""
        nd = len(self.view.domains)
        pb = self.view.page_bytes
        read = np.bincount(src_doms, minlength=nd) * pb
        write = np.bincount(dst_doms, minlength=nd) * pb
        secs = max(self.view.stall_seconds(read),
                   self.view.stall_seconds(write))
        obs = self.view.fabric.obs
        if obs is not None:
            # Eq.-1 prediction vs measurement (observatory drift ledger):
            # the transfer touches both page sets, read side + write side
            obs.observe_transfer(read + write, secs)
        return secs
