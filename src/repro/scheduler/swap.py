"""KV swap manager: park preempted sequences' pages in slow domains.

The paper's Observation 1 — slow domains are wasted capacity unless placement
uses them — applies twice in serving. Live decode pages spread per BWAP
weights (kvcache), and *cold* pages (sequences preempted by the scheduler)
should not occupy fast-HBM capacity at all: they park in reserved slots
carved out of the slow domains, freeing fast pages for the running batch.
That is what lets total live KV exceed ``hbm_local`` capacity.

Mechanics: at construction the manager reserves a fraction of every
non-worker domain's pages (``BwapPagePool.reserve_pages`` — the slots leave
the free lists, so the allocator never hands them to live sequences). A
swap-out distributes a victim's pages over the slow domains through a policy
from the placement registry — ``bwap_canonical`` (weights ∝ slow-domain
bandwidth) by default, ``uniform`` / ``local_first`` as the baselines
``benchmarks/scheduler_bench.py`` compares — and executes the copies as one
batched gather/scatter per pool array (placement.executor). Swap-in
allocates destinations through ``pool.alloc_page`` (live-placement policy)
and returns the vacated slots to the reservation.

Transfer cost is the Eq.-1 max-parallel-transfer time
(``core.bwmodel.stall_cost``) of the slower side of the copy; the engine
folds it into the step latency, which is how swap-placement quality reaches
goodput.
"""

from __future__ import annotations

import numpy as np

from repro.core import bwmodel
from repro.placement import policy as placement_policy


class KVSwapManager:
    """Swap-slot reservation + bandwidth-aware swap placement for one pool."""

    def __init__(self, pool, *, placement: str = "bwap_canonical",
                 reserve_fraction: float = 0.5,
                 reserve_pages: dict[str, int] | None = None):
        """``reserve_fraction`` of every slow (non-worker) domain's currently
        free pages is reserved, unless ``reserve_pages`` gives explicit
        per-domain counts (by domain name; missing names reserve zero)."""
        self.pool = pool
        self.placement = placement_policy.resolve(placement)
        self.slow = list(pool.slow_domains)
        assert self.slow, "swap needs at least one non-worker domain"
        self.slots: dict[int, list[int]] = {}
        for d in self.slow:
            if reserve_pages is not None:
                n = int(reserve_pages.get(pool.domains[d].name, 0))
            else:
                n = int(len(pool.free[d]) * reserve_fraction)
            self.slots[d] = pool.reserve_pages(d, n)
        self.reserved_total = sum(len(s) for s in self.slots.values())
        self._out: set[int] = set()   # slot ids currently holding parked KV

    # -- capacity ------------------------------------------------------------

    def slots_free(self) -> int:
        return sum(len(s) for s in self.slots.values())

    def can_swap_out(self, num_pages: int) -> bool:
        return self.slots_free() >= num_pages

    def parked_count(self, page_ids) -> int:
        """How many of a view's pages currently sit in reserved slots (the
        ones swap-in must re-allocate; pinned shared pages never parked)."""
        return sum(1 for p in page_ids if p in self._out)

    # -- placement over the slow-domain subspace ------------------------------

    def _slot_counts(self, num_pages: int) -> np.ndarray:
        """How many of ``num_pages`` go to each slow domain (policy-weighted,
        clamped to available slots)."""
        ctx = placement_policy.PlacementContext(
            bandwidths=np.asarray([self.pool.domains[d].read_bw
                                   for d in self.slow]),
            num_pages=num_pages,
            capacities=np.asarray([len(self.slots[d]) for d in self.slow]))
        return self.placement.counts(ctx)

    # -- the round-trip -------------------------------------------------------

    def swap_out(self, page_ids: list[int],
                 table=None) -> tuple[list[int], float]:
        """Move a sequence's pages into reserved slow-domain slots; frees the
        sources back to the pool. Returns ``(new_page_ids, seconds)`` with
        page order preserved (the view stays positional).

        With ``table`` (a :class:`~repro.serve.pagetable.PageTable`), pages
        with refcount > 1 are *pinned*: other live sequences read them, so
        they keep their fast-domain homes and only this sequence's exclusive
        pages park. Moved pages leave the prefix trie (a parked page must
        not be matched — its id changes again on swap-in) and are remapped
        under the table so the refcount follows the bytes."""
        movable = [p for p in page_ids
                   if table is None or not table.shared(p)]
        n = len(movable)
        if n == 0:
            return list(page_ids), 0.0
        assert self.can_swap_out(n), "not enough reserved swap slots"
        counts = self._slot_counts(n)
        dst: list[int] = []
        for d, c in zip(self.slow, counts):
            dst.extend(self.slots[d].pop() for _ in range(int(c)))
        src_doms = [self.pool.domain_of(p) for p in movable]
        dst_doms = [self.pool.domain_of(p) for p in dst]
        (self.pool.k_pool, self.pool.v_pool), _ = self.pool.executor.execute(
            (self.pool.k_pool, self.pool.v_pool), movable, dst,
            src_domains=src_doms, dst_domains=dst_doms)
        moved = dict(zip(movable, dst))
        if table is not None:
            for s, d in moved.items():
                table.unregister(s)
                table.remap_physical(s, d)
        self._out.update(dst)
        self.pool.free_pages(movable)
        seconds = self._transfer_seconds(src_doms, dst_doms)
        self.pool.telemetry.record_swap("out", n, seconds)
        return [moved.get(p, p) for p in page_ids], seconds

    def swap_in(self, page_ids: list[int],
                table=None) -> tuple[list[int], float]:
        """Bring parked pages back through the pool's live placement policy;
        vacated slots rejoin the reservation. Pages of the view that never
        parked (pinned shared pages) pass through untouched. Caller
        guarantees the pool has enough allocatable pages (the scheduler
        checks against the parked count)."""
        parked = [p for p in page_ids if p in self._out]
        n = len(parked)
        if n == 0:
            return list(page_ids), 0.0
        dst = [self.pool.alloc_page() for _ in range(n)]
        src_doms = [self.pool.domain_of(p) for p in parked]
        dst_doms = [self.pool.domain_of(p) for p in dst]
        (self.pool.k_pool, self.pool.v_pool), _ = self.pool.executor.execute(
            (self.pool.k_pool, self.pool.v_pool), parked, dst,
            src_domains=src_doms, dst_domains=dst_doms)
        moved = dict(zip(parked, dst))
        if table is not None:
            for s, d in moved.items():
                table.remap_physical(s, d)
        spilled = False
        for pid in parked:
            self._out.discard(pid)
            d = self.pool.domain_of(pid)
            if d in self.slots:
                self.slots[d].append(int(pid))
            else:   # a rebalance spilled this parked slot into a worker
                self.pool.free[d].append(int(pid))   # domain: hand it back
                self.reserved_total -= 1
                spilled = True
        if spilled:
            self._sync_pool_reserved()
        seconds = self._transfer_seconds(src_doms, dst_doms)
        self.pool.telemetry.record_swap("in", n, seconds)
        return [moved.get(p, p) for p in page_ids], seconds

    def _transfer_seconds(self, src_doms, dst_doms) -> float:
        """Eq.-1 cost of the copy: reads and writes overlap across domains,
        so the transfer takes the slower of the two sides."""
        nd = len(self.pool.domains)
        read = np.bincount(src_doms, minlength=nd) * self.pool.page_bytes
        write = np.bincount(dst_doms, minlength=nd) * self.pool.page_bytes
        return max(bwmodel.stall_cost(read, self.pool.bw),
                   bwmodel.stall_cost(write, self.pool.bw))

    # -- arbiter rebalance ----------------------------------------------------

    def remap(self, id_map: np.ndarray) -> None:
        """Rewrite reserved slot ids after the pool was rebuilt (slots are
        live pages from the pool's perspective, so the id map covers them)."""
        self._out = {int(id_map[p]) for p in self._out}
        assert all(p >= 0 for p in self._out), "parked page lost in rebalance"
        for d in list(self.slots):
            self.slots[d] = [int(id_map[p]) for p in self.slots[d]]
            assert all(p >= 0 for p in self.slots[d]), \
                "reserved swap slot lost in rebalance"
        # domain indices are stable across rebalance (sizes change, order
        # does not), but a shrinking rebalance may spill a slot into
        # another domain — re-key, and hand slots that landed in *worker*
        # domains back to the allocator (fast pages must not sit idle in a
        # parking reservation, and _slot_counts only spans slow domains).
        rekey: dict[int, list[int]] = {d: [] for d in self.slow}
        for pages in self.slots.values():
            for p in pages:
                d = self.pool.domain_of(p)
                if d in rekey:
                    rekey[d].append(p)
                else:
                    self.pool.free[d].append(p)
                    self.reserved_total -= 1
        self.slots = rekey
        self._sync_pool_reserved()

    def _sync_pool_reserved(self) -> None:
        """Mirror the reservation (free slots + parked pages) into the
        pool's per-domain reserved counts — what swap-aware DWP reads."""
        counts = np.zeros(len(self.pool.domains), dtype=np.int64)
        for d, pages in self.slots.items():
            counts[d] += len(pages)
        for p in self._out:
            counts[self.pool.domain_of(p)] += 1
        self.pool.set_reserved_counts(counts)
