"""SLO accounting: TTFT, TPOT, and goodput under per-class deadlines.

Serving quality is not mean latency: a request is *good* only if its time to
first token (TTFT) and time per output token (TPOT) both meet the deadlines
of its priority class. Goodput — the metric the scheduler optimizes and
``benchmarks/scheduler_bench.py`` compares swap placements on — counts only
tokens from requests that met both deadlines, per unit time.

All times are scheduler-clock seconds (virtual time on CPU hosts: measured
wall plus the Eq.-1 analytic components — decode KV reads and swap
transfers — that supply the memory-domain asymmetry the host lacks).
Counters live in ``placement.telemetry.ClassSloCounters`` so the pool's
telemetry snapshot carries SLO state alongside placement state.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.placement.telemetry import ClassSloCounters


@dataclasses.dataclass(frozen=True)
class SloSpec:
    """Per-class deadlines, seconds. ``inf`` = unconstrained."""

    ttft_s: float = math.inf
    tpot_s: float = math.inf


@dataclasses.dataclass
class RequestRecord:
    rid: int
    cls: str
    arrival_s: float
    first_token_s: float | None = None
    finish_s: float | None = None
    produced: int = 0
    preemptions: int = 0

    @property
    def ttft(self) -> float | None:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def tpot(self) -> float | None:
        """Mean inter-token time after the first token."""
        if self.finish_s is None or self.first_token_s is None:
            return None
        if self.produced <= 1:
            return 0.0
        return (self.finish_s - self.first_token_s) / (self.produced - 1)


class SloTracker:
    """Request lifecycle observer for one scheduler.

    ``counters`` is the pool telemetry's per-class block (attach_slo), so
    engine snapshots surface the same numbers ``summary()`` reports.
    """

    def __init__(self, specs: dict[str, SloSpec] | None = None,
                 counters: ClassSloCounters | None = None):
        self.specs = dict(specs or {})
        self.counters = counters or ClassSloCounters()
        self.records: dict[int, RequestRecord] = {}

    def spec(self, cls: str) -> SloSpec:
        return self.specs.get(cls, SloSpec())

    # -- lifecycle hooks (driven by the scheduler) ---------------------------

    def on_submit(self, rid: int, cls: str, arrival_s: float) -> None:
        self.records[rid] = RequestRecord(rid, cls, arrival_s)
        self.counters.add(cls, "submitted")

    def on_first_token(self, rid: int, now: float) -> None:
        r = self.records[rid]
        if r.first_token_s is None:
            r.first_token_s = now
            spec = self.spec(r.cls)
            met = (now - r.arrival_s) <= spec.ttft_s
            self.counters.add(r.cls, "ttft_met" if met else "ttft_missed")

    def on_finish(self, rid: int, now: float, produced: int) -> None:
        r = self.records[rid]
        r.finish_s = now
        r.produced = produced
        self.counters.add(r.cls, "completed")
        spec = self.spec(r.cls)
        tpot = r.tpot
        met = tpot is not None and tpot <= spec.tpot_s
        self.counters.add(r.cls, "tpot_met" if met else "tpot_missed")
        if self.is_good(r):
            self.counters.add(r.cls, "goodput_tokens", produced)

    def on_preempt(self, rid: int, pages: int) -> None:
        r = self.records[rid]
        r.preemptions += 1
        self.counters.add(r.cls, "preemptions")
        self.counters.add(r.cls, "swap_out_pages", pages)

    def on_resume(self, rid: int, pages: int) -> None:
        self.counters.add(self.records[rid].cls, "swap_in_pages", pages)

    # -- reporting ------------------------------------------------------------

    def is_good(self, r: RequestRecord) -> bool:
        """Completed and met both deadlines."""
        spec = self.spec(r.cls)
        return (r.finish_s is not None and r.ttft is not None
                and r.ttft <= spec.ttft_s
                and r.tpot is not None and r.tpot <= spec.tpot_s)

    def summary(self, now: float) -> dict:
        """Per-class metrics plus aggregate goodput over [0, now]."""
        per_cls: dict[str, list[RequestRecord]] = {}
        for r in self.records.values():
            per_cls.setdefault(r.cls, []).append(r)
        out: dict = {"classes": {}, "elapsed_s": now}
        total_good_tokens = 0
        total_completed = 0
        for cls, recs in sorted(per_cls.items()):
            done = [r for r in recs if r.finish_s is not None]
            good = [r for r in done if self.is_good(r)]
            ttfts = [r.ttft for r in done if r.ttft is not None]
            tpots = [r.tpot for r in done if r.tpot is not None]
            good_tokens = sum(r.produced for r in good)
            total_good_tokens += good_tokens
            total_completed += len(done)
            out["classes"][cls] = {
                "submitted": len(recs),
                "completed": len(done),
                "good": len(good),
                "slo_attainment": len(good) / max(len(done), 1),
                "ttft_mean_s": float(np.mean(ttfts)) if ttfts else 0.0,
                "ttft_p95_s": float(np.percentile(ttfts, 95))
                if ttfts else 0.0,
                "tpot_mean_s": float(np.mean(tpots)) if tpots else 0.0,
                "preemptions": sum(r.preemptions for r in recs),
                "goodput_tokens": good_tokens,
            }
        out["completed"] = total_completed
        out["good_tokens"] = total_good_tokens
        out["goodput_tok_s"] = total_good_tokens / max(now, 1e-9)
        # TTFT-weighted goodput: good tokens per second, discounted by the
        # aggregate mean TTFT — the figure of merit for prefill/decode
        # disaggregation (scheduler_bench.disagg_compare), where the win is
        # first tokens arriving sooner at equal token throughput
        all_ttfts = [r.ttft for r in self.records.values()
                     if r.ttft is not None]
        ttft_mean = float(np.mean(all_ttfts)) if all_ttfts else 0.0
        out["ttft_mean_s"] = ttft_mean
        out["ttft_weighted_goodput"] = (
            out["goodput_tok_s"] / max(ttft_mean, 1e-9))
        return out
