"""Request scheduler subsystem: priority continuous batching, chunked
prefill, and bandwidth-aware KV swap to slow memory domains (DESIGN.md §5).

- ``scheduler``: admission queue, batch composition, preemption.
- ``swap``: swap-slot reservation + BWAP-weighted swap placement.
- ``workload``: trace-driven request generators (deterministic seeds).
- ``slo``: TTFT / TPOT / goodput accounting under per-class deadlines.
"""

from repro.scheduler.scheduler import (PriorityClass, Request,  # noqa: F401
                                       RequestScheduler, State, StepPlan)
from repro.scheduler.slo import SloSpec, SloTracker  # noqa: F401
from repro.scheduler.swap import KVSwapManager  # noqa: F401
from repro.scheduler.workload import (TraceRequest,  # noqa: F401
                                      WorkloadSpec, generate,
                                      total_kv_pages)
