"""Migration executor: page moves as batched JAX gather/scatter.

The seed implementation of ``BwapPagePool.migrate_sequence`` moved pages one
``at[].set`` at a time — each call materializes a full copy of the pool, so a
k-page migration cost k whole-pool copies *per array*. The executor instead
gathers all source pages and scatters them in one ``at[ids].set`` per array,
independent of how many pages move (benchmarks/placement_bench.py measures
the gap; acceptance floor is 5x on a 4096-page migration).

Moves are expressed as parallel ``src_ids``/``dst_ids`` index vectors over
the page axis. Callers must ensure ``dst_ids`` are free (not also sources):
the pool pops destinations from the free lists *before* executing, so a page
freed by this migration is never simultaneously read and overwritten.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MigrationResult:
    """What a batch of moves did, for telemetry and benchmarks."""

    num_moves: int
    bytes_moved: int                      # across all arrays
    pair_pages: dict                      # (src_domain, dst_domain) -> pages

    @staticmethod
    def empty() -> "MigrationResult":
        return MigrationResult(0, 0, {})


def _page_bytes(array, page_axis: int) -> int:
    """Bytes of one page slice of ``array``."""
    shape = array.shape
    per = array.dtype.itemsize
    for i, s in enumerate(shape):
        if i != page_axis:
            per *= int(s)
    return per


def pair_histogram(src_domains: np.ndarray,
                   dst_domains: np.ndarray) -> dict:
    """Group move counts by (src_domain, dst_domain)."""
    pairs = {}
    for s, d in zip(np.asarray(src_domains), np.asarray(dst_domains)):
        key = (int(s), int(d))
        pairs[key] = pairs.get(key, 0) + 1
    return pairs


class MigrationExecutor:
    """Executes MigrationPlans / move lists against JAX page pools.

    Stateless aside from an optional telemetry sink; arrays are immutable so
    every method returns the new arrays.
    """

    def __init__(self, telemetry=None):
        self.telemetry = telemetry

    # -- same-pool moves -----------------------------------------------------

    def execute(self, arrays: Sequence, src_ids, dst_ids, *,
                page_axis: int = 1, src_domains=None, dst_domains=None):
        """Copy pages ``src_ids -> dst_ids`` inside each array.

        One gather + one scatter per array regardless of the number of moves.
        Returns ``(new_arrays, MigrationResult)``.
        """
        src = np.asarray(src_ids, dtype=np.int64)
        dst = np.asarray(dst_ids, dtype=np.int64)
        assert src.shape == dst.shape
        if src.size == 0:
            return list(arrays), MigrationResult.empty()
        out = []
        nbytes = 0
        sidx = jnp.asarray(src)
        didx = jnp.asarray(dst)
        for a in arrays:
            ix = (slice(None),) * page_axis + (didx,)
            out.append(a.at[ix].set(jnp.take(a, sidx, axis=page_axis)))
            nbytes += _page_bytes(a, page_axis) * src.size
        result = MigrationResult(
            num_moves=int(src.size), bytes_moved=int(nbytes),
            pair_pages=(pair_histogram(src_domains, dst_domains)
                        if src_domains is not None else {}))
        self._record(result)
        return out, result

    # -- cross-pool moves (pool rebalance / resize) --------------------------

    def copy(self, src_arrays: Sequence, dst_arrays: Sequence, src_ids,
             dst_ids, *, page_axis: int = 1):
        """Scatter pages of ``src_arrays`` into ``dst_arrays`` (which may
        have a different page-axis length — used when a pool is rebuilt on
        arbiter rebalance). Returns ``(new_dst_arrays, MigrationResult)``."""
        src = np.asarray(src_ids, dtype=np.int64)
        dst = np.asarray(dst_ids, dtype=np.int64)
        assert src.shape == dst.shape
        if src.size == 0:
            return list(dst_arrays), MigrationResult.empty()
        out = []
        nbytes = 0
        sidx = jnp.asarray(src)
        didx = jnp.asarray(dst)
        for a_src, a_dst in zip(src_arrays, dst_arrays):
            ix = (slice(None),) * page_axis + (didx,)
            out.append(a_dst.at[ix].set(
                jnp.take(a_src, sidx, axis=page_axis)))
            nbytes += _page_bytes(a_src, page_axis) * src.size
        result = MigrationResult(int(src.size), int(nbytes), {})
        self._record(result)
        return out, result

    # -- reference path ------------------------------------------------------

    def execute_looped(self, arrays: Sequence, src_ids, dst_ids, *,
                       page_axis: int = 1):
        """The seed's per-page Python loop, kept as the benchmark baseline
        and as an oracle for tests. Do not use on hot paths."""
        src = np.asarray(src_ids, dtype=np.int64)
        dst = np.asarray(dst_ids, dtype=np.int64)
        out = list(arrays)
        for s, d in zip(src, dst):
            for i in range(len(out)):
                a = out[i]
                ix = (slice(None),) * page_axis + (int(d),)
                src_ix = (slice(None),) * page_axis + (int(s),)
                out[i] = a.at[ix].set(a[src_ix])
        nbytes = sum(_page_bytes(a, page_axis) for a in arrays) * src.size
        return out, MigrationResult(int(src.size), int(nbytes), {})

    def _record(self, result: MigrationResult) -> None:
        if self.telemetry is None or result.num_moves == 0:
            return
        if result.pair_pages:
            per_page = result.bytes_moved // max(result.num_moves, 1)
            for (s, d), pages in result.pair_pages.items():
                self.telemetry.record_migration(s, d, pages,
                                                pages * per_page)
        else:
            self.telemetry.record_executed(result.num_moves)
