"""The page-fabric zoo: heterogeneous model groups over one byte arena,
with a capacity market between them (DESIGN.md §12).

One :class:`MemoryFabric` serves one model group — every page in it has
that group's :class:`~repro.placement.geometry.PageGeometry`, so the
paged-attention kernels stay oblivious and intra-group ledgers stay in
page units.  Serving the *zoo* (chat transformer + MLA tenant + SSM
tenant + ASR encoder tier on one machine) therefore needs a layer above
the fabric whose currency is the only unit all geometries share:
**bytes per physical memory domain**.

:class:`PageFabricZoo` owns that byte ledger.  Each registered group
gets its own fabric whose pool *address space* spans the full domain
capacity in the group's own page units (so a group could, if funded,
hold a whole domain), while the group's single view is *funded* with
``floor(share * domain_bytes / page_bytes)`` pages — the view quota is
the funding, and the fabric's ``_headroom`` gate makes residency follow
funding.  Quota moves between groups are pure ledger arithmetic: no
array rebuild, no page-id remapping, no data motion.

The market prices a funded page by the paper's Eq. 1: the marginal
value of one more funded byte to group *g* is the stall it would shave
off *g*'s next step — zero while *g* has free funding or no demand,
and ``D_g / (bw_home(g) * 1e9)`` seconds (its unfunded demand streamed
at its home domain's bandwidth) while it is starved.  A trade happens
exactly when one group's marginal value strictly exceeds another's —
in practice: a chat burst annexes idle ASR/SSM funding and repays it
when the lender's own demand returns or the burst drains.

Because lender and borrower page sizes differ, every trade quantizes
down to whole pages on both sides and escrows the remainder bytes in
the lease itself; repayment restores the lender's exact original page
count, so repeated annex/repay cycles leak nothing.  The zoo-level
invariant — per domain, funded + escrowed + free bytes == capacity —
is checked together with every member fabric's own page/byte
invariants by :meth:`PageFabricZoo.check_invariants`.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.dwp import DWPConfig
from repro.placement.fabric import MemoryFabric
from repro.placement.geometry import PageGeometry, geometry_for
from repro.placement.pool import MemoryDomain


@dataclasses.dataclass(frozen=True)
class ByteDomain:
    """One physical memory domain as the zoo sees it: a byte capacity
    and a read bandwidth — page counts are per-group derived quantities."""

    name: str
    capacity_bytes: int
    read_bw: float                       # GB/s toward the workers
    is_worker: bool = False


@dataclasses.dataclass
class Lease:
    """One outstanding capacity-market trade, byte-exact.

    The lender released ``lender_pages[d] * lender_bpp`` bytes in domain
    ``d``; the borrower was funded ``borrower_pages[d] * borrower_bpp``
    of them; the difference sits in ``escrow_bytes[d]`` until repayment
    (page sizes rarely divide each other, and the remainder must not be
    double-spent by a concurrent trade)."""

    lender: str
    borrower: str
    lender_pages: np.ndarray             # int64 per domain
    borrower_pages: np.ndarray           # int64 per domain
    escrow_bytes: np.ndarray             # int64 per domain
    granted_bytes: int = 0               # cumulative borrower funding
    repaid_bytes: int = 0                # cumulative funding returned

    def outstanding_bytes(self) -> int:
        return self.granted_bytes - self.repaid_bytes

    def as_dict(self) -> dict:
        return {
            "lender": self.lender, "borrower": self.borrower,
            "granted_bytes": int(self.granted_bytes),
            "repaid_bytes": int(self.repaid_bytes),
            "outstanding_bytes": int(self.outstanding_bytes()),
            "escrow_bytes": int(self.escrow_bytes.sum()),
        }


@dataclasses.dataclass
class ZooGroup:
    """One model group: its config, geometry, fabric, and funded view."""

    name: str
    cfg: object
    geometry: PageGeometry
    fabric: MemoryFabric
    view: object                          # FabricView
    demand_bytes: int = 0                 # unfunded demand (market input)

    @property
    def page_bytes(self) -> int:
        return self.geometry.page_bytes

    def funded_bytes(self) -> np.ndarray:
        return self.view.quota.astype(np.int64) * self.page_bytes

    def idle_pages(self) -> np.ndarray:
        """Funded-but-unused pages per domain — what the group could
        lend without touching anything resident."""
        return (self.view.quota - self.view.used
                - self.view.reserved).astype(np.int64)


class PageFabricZoo:
    """Byte arena + capacity market over per-group member fabrics."""

    def __init__(self, domains: Sequence[ByteDomain], *, seed: int = 0):
        self.domains = list(domains)
        self.capacity_bytes = np.asarray(
            [d.capacity_bytes for d in self.domains], dtype=np.int64)
        self.seed = seed
        self.groups: dict[str, ZooGroup] = {}
        self.leases: list[Lease] = []
        self.trades = 0                   # cumulative grant events

    # -- registration ----------------------------------------------------------

    def register(self, name: str, cfg, *, share: float,
                 page_size: int = 4, geometry: PageGeometry | None = None,
                 policy: str = "bwap_dwp", level: int = 0,
                 dwp_config: DWPConfig | None = None,
                 share_prefix: bool = True) -> ZooGroup:
        """Stand up one model group: a fabric whose address space spans
        the full arena in the group's own page units, and a view funded
        with ``share`` of every domain's bytes."""
        assert name not in self.groups, f"group {name!r} already registered"
        assert 0.0 < share <= 1.0
        geom = geometry if geometry is not None \
            else geometry_for(cfg, page_size)
        bpp = geom.page_bytes
        space = [MemoryDomain(d.name, int(d.capacity_bytes // bpp),
                              d.read_bw, d.is_worker)
                 for d in self.domains]
        assert all(s.num_pages > 0 for s in space), \
            f"group {name!r}: page_bytes {bpp} exceeds a domain's capacity"
        fabric = MemoryFabric(cfg, space, page_size=geom.page_size,
                              seed=self.seed, policy=policy,
                              geometry=geom, group=name)
        funded = self._affordable(share, bpp)
        assert int(funded.sum()) > 0, f"group {name!r}: share funds 0 pages"
        home = tuple(i for i, d in enumerate(self.domains) if d.is_worker) \
            or (int(np.argmax([d.read_bw for d in self.domains])),)
        view = fabric.view(name, quota=funded, home=home, level=level,
                           share_prefix=share_prefix and geom.shareable,
                           dwp_config=dwp_config)
        group = ZooGroup(name=name, cfg=cfg, geometry=geom,
                         fabric=fabric, view=view)
        self.groups[name] = group
        assert (self._funded_total() <= self.capacity_bytes).all(), \
            "group shares oversubscribe the arena"
        return group

    def _affordable(self, share: float, bpp: int) -> np.ndarray:
        return np.asarray(
            [int(share * c) // bpp for c in self.capacity_bytes],
            dtype=np.int64)

    def unregister(self, name: str) -> np.ndarray:
        """Drop a group; its funding returns to the arena. All leases it
        is party to must be repaid first — the market cannot price pages
        of a tenant that no longer exists."""
        assert not any(ln.outstanding_bytes() for ln in self.leases
                       if name in (ln.lender, ln.borrower)), \
            f"group {name!r} still party to an outstanding lease"
        group = self.groups[name]
        freed = group.funded_bytes()
        group.fabric.unregister(name)
        del self.groups[name]
        return freed

    # -- the market ------------------------------------------------------------

    def observe_demand(self, name: str, demand_bytes: int) -> None:
        """Report a group's *unfunded* demand: bytes it wants resident
        beyond its current free funding (0 = satisfied/idle)."""
        self.groups[name].demand_bytes = max(0, int(demand_bytes))

    def page_value(self, name: str) -> float:
        """Marginal value of one more funded page to this group, in
        Eq.-1 stall-seconds saved per byte times its unfunded demand:
        ``D_g / (bw_home * 1e9)`` while starved, 0 while satisfied.
        (A group with free funding left is never starved — its next
        page is already paid for.)"""
        g = self.groups[name]
        if g.demand_bytes <= 0 or g.view.free_count() * g.page_bytes \
                >= g.demand_bytes:
            return 0.0
        bw = max(self.domains[h].read_bw for h in g.view.home)
        return g.demand_bytes / (bw * 1e9)

    def market_tick(self) -> dict:
        """One pricing round: repay leases whose borrowers are idle (or
        whose lenders are starved), then fund starved groups from the
        cheapest idle funding on the market. Returns a summary of byte
        flows this round."""
        repaid = self._repay_round()
        granted = self._annex_round()
        return {"granted_bytes": granted, "repaid_bytes": repaid}

    def _annex_round(self) -> int:
        total = 0
        values = {n: self.page_value(n) for n in self.groups}
        for bname, bval in sorted(values.items(), key=lambda kv: -kv[1]):
            if bval <= 0.0:
                continue
            borrower = self.groups[bname]
            want = borrower.demand_bytes \
                - borrower.view.free_count() * borrower.page_bytes
            # cheapest funding first: idle groups before busy ones
            for lname in sorted(values, key=lambda n: values[n]):
                if want <= 0:
                    break
                if lname == bname or values[lname] >= bval:
                    continue
                total += self._grant(self.groups[lname], borrower, want)
                want = borrower.demand_bytes \
                    - borrower.view.free_count() * borrower.page_bytes
        return total

    def _grant(self, lender: ZooGroup, borrower: ZooGroup,
               want_bytes: int) -> int:
        """Move idle funding lender -> borrower, domain by domain,
        quantized to whole pages on both sides; remainder bytes escrow
        in the lease. Returns borrower bytes funded."""
        lb, bb = lender.page_bytes, borrower.page_bytes
        lease = self._lease(lender.name, borrower.name)
        granted = 0
        idle = lender.idle_pages()
        for d in range(len(self.domains)):
            if want_bytes <= 0:
                break
            n_l = min(int(idle[d]), -(-int(want_bytes) // lb))
            if n_l <= 0:
                continue
            released = n_l * lb
            n_b = released // bb
            if n_b <= 0:
                continue                  # lender page too small to fund one
            funded = n_b * bb
            lender.view.quota[d] -= n_l
            borrower.view.quota[d] += n_b
            lease.lender_pages[d] += n_l
            lease.borrower_pages[d] += n_b
            lease.escrow_bytes[d] += released - funded
            lease.granted_bytes += funded
            granted += funded
            want_bytes -= funded
            self.trades += 1
            borrower.fabric.emit(
                "share", kind="loan", lender=lender.name,
                borrower=borrower.name, slots=int(n_b))
        return granted

    def _repay_round(self) -> int:
        """Unwind leases whose borrower is idle in a domain (or whose
        lender is starved while the borrower has free funding): restore
        the lender's exact original page count, release the escrow."""
        total = 0
        for lease in self.leases:
            if lease.outstanding_bytes() <= 0:
                continue
            borrower = self.groups[lease.borrower]
            lender = self.groups[lease.lender]
            borrower_busy = borrower.demand_bytes > 0 \
                and self.page_value(borrower.name) \
                >= self.page_value(lender.name)
            if borrower_busy:
                continue
            b_idle = borrower.idle_pages()
            for d in range(len(self.domains)):
                n_b = int(lease.borrower_pages[d])
                if n_b == 0 or b_idle[d] < n_b:
                    continue              # annexed pages still resident
                n_l = int(lease.lender_pages[d])
                borrower.view.quota[d] -= n_b
                lender.view.quota[d] += n_l
                repaid = n_b * borrower.page_bytes
                lease.repaid_bytes += repaid
                lease.borrower_pages[d] = 0
                lease.lender_pages[d] = 0
                lease.escrow_bytes[d] = 0
                total += repaid
                lender.fabric.emit(
                    "share", kind="reclaim", lender=lease.lender,
                    borrower=lease.borrower, slots=int(n_b),
                    seconds=0.0)
        return total

    def _lease(self, lender: str, borrower: str) -> Lease:
        for ln in self.leases:
            if (ln.lender, ln.borrower) == (lender, borrower):
                return ln
        nd = len(self.domains)
        ln = Lease(lender=lender, borrower=borrower,
                   lender_pages=np.zeros(nd, dtype=np.int64),
                   borrower_pages=np.zeros(nd, dtype=np.int64),
                   escrow_bytes=np.zeros(nd, dtype=np.int64))
        self.leases.append(ln)
        return ln

    def outstanding_bytes(self) -> int:
        return sum(ln.outstanding_bytes() for ln in self.leases)

    # -- accounting ------------------------------------------------------------

    def _funded_total(self) -> np.ndarray:
        out = np.zeros(len(self.domains), dtype=np.int64)
        for g in self.groups.values():
            out += g.funded_bytes()
        return out

    def _escrow_total(self) -> np.ndarray:
        out = np.zeros(len(self.domains), dtype=np.int64)
        for ln in self.leases:
            out += ln.escrow_bytes
        return out

    def free_bytes(self) -> np.ndarray:
        """Per-domain bytes funded to nobody (unsold arena capacity)."""
        return self.capacity_bytes - self._funded_total() \
            - self._escrow_total()

    def check_invariants(self) -> None:
        """Zoo-wide byte balance: per domain, every capacity byte is
        funded to exactly one group, escrowed in exactly one lease, or
        free — plus every member fabric's own page/byte invariants."""
        funded = self._funded_total()
        escrow = self._escrow_total()
        free = self.free_bytes()
        assert (free >= 0).all(), \
            f"arena oversubscribed: funded {funded} escrow {escrow} " \
            f"capacity {self.capacity_bytes}"
        np.testing.assert_array_equal(
            funded + escrow + free, self.capacity_bytes,
            err_msg="zoo byte ledger does not balance")
        for g in self.groups.values():
            assert (g.view.quota >= g.view.used + g.view.reserved).all(), \
                f"group {g.name!r} residency exceeds funding"
            np.testing.assert_array_equal(
                g.funded_bytes(),
                g.view.quota.astype(np.int64) * g.page_bytes,
                err_msg=f"group {g.name!r} byte funding drifted")
            g.fabric.check_invariants()

    def stats(self) -> dict:
        return {
            "capacity_bytes": self.capacity_bytes.tolist(),
            "free_bytes": self.free_bytes().tolist(),
            "trades": self.trades,
            "leases": [ln.as_dict() for ln in self.leases],
            "groups": {
                n: {
                    "kind": g.geometry.kind,
                    "page_bytes": g.page_bytes,
                    "funded_bytes": g.funded_bytes().tolist(),
                    "used_bytes": g.view.used_bytes(),
                    "demand_bytes": g.demand_bytes,
                } for n, g in self.groups.items()
            },
        }
