"""Logical→physical page table: refcounts, prefix-sharing trie, CoW forks.

BWAP's unit of placement is the *physical* page; the serving stack's unit of
meaning is the *logical* page — "the b-th page-size block of this sequence's
K/V". The seed bound the two directly (``Request.pages`` was the physical
truth), so identical prompt prefixes — which heavy-tail traces produce
constantly — materialized N times, and nothing in the stack could say "these
two sequences read the same bytes". This table decouples them, the same
indirection that lets tiered-memory systems migrate pages under a live
workload (arXiv 2112.12685) and co-locate shared hot pages in fast domains
(CODA, arXiv 1710.09517):

- **Refcounts** — ``ref[pid]`` counts how many sequence views hold physical
  page ``pid``. Pages are allocated from / returned to the
  :class:`~repro.placement.pool.BwapPagePool` only through this table
  (``append_page`` / ``release``); a page is freed when its last holder
  releases it.
- **Prefix trie** — completed *prompt* pages are registered under a chain
  key ``(parent_node_id, token_block)``: a node matches only when its whole
  ancestor chain matches, so equal token blocks at different depths (or
  after different prefixes) never alias. ``match_prefix`` walks the trie
  and hands back the longest chain of already-materialized pages with
  refcount bumps — the new sequence starts life with those logical pages
  mapped to shared physical pages, shrinking its physical footprint and its
  prefill work at once.
- **Copy-on-write** — a write to a page with ``ref > 1`` must not be seen by
  the other holders. ``fork_for_write`` allocates a fresh page, copies the
  contents through the migration executor (one gather/scatter pair), moves
  one reference over, and returns the private clone. The only organic
  trigger in the serving stack is a *full-prompt* match: the first decode
  step rewrites the last prompt position, which lives in a shared page.

Placement stays downstream and untouched: the pool still decides *where*
physical pages live, migration/swap still move them — they just notify the
table (``remap_physical``) so refcounts and trie nodes follow the bytes.
Pages with ``ref > 1`` are **pinned** for movement purposes: migration and
swap skip them, because the mover only speaks for one of the holders
(``exclusive`` filters a view down to movable pages).
"""

from __future__ import annotations

import itertools
from typing import Sequence

ROOT = -1                      # parent id of depth-0 trie nodes


class _TrieNode:
    """One registered full page: chain-keyed by (parent node, token block)."""

    __slots__ = ("nid", "parent", "block", "phys", "children")

    def __init__(self, nid: int, parent: int, block: tuple, phys: int):
        self.nid = nid
        self.parent = parent               # parent node id (ROOT at depth 0)
        self.block = block                 # the page's token tuple
        self.phys = phys
        self.children: set[int] = set()    # child node ids


class PageTable:
    """Refcounted logical→physical mapping for one page pool.

    A sequence's *view* is its positional page list (``Request.pages``):
    index = logical page number, value = physical page id. The table does
    not own the lists — it owns the lifetime (refcounts), the sharing index
    (trie), and the fork semantics; callers thread their lists through.
    """

    def __init__(self, pool, prefix_reuse: bool = True):
        self.pool = pool
        self.prefix_reuse = prefix_reuse
        # the group geometry's shareability class gates the trie outright:
        # non-shareable pages (SSM state, mutated in place every step) must
        # never be matched into another sequence, whatever prefix_reuse
        # callers later toggle on this table
        self._shareable = bool(
            getattr(getattr(pool, "geometry", None), "shareable", True))
        self.ref: dict[int, int] = {}
        self._nodes: dict[int, _TrieNode] = {}
        self._index: dict[tuple[int, tuple], int] = {}   # key -> node id
        self._node_of: dict[int, int] = {}               # phys -> node id
        self._ids = itertools.count()
        # cumulative counters (surfaced via FabricView.snapshot)
        self.cow_faults = 0
        self.prefix_hit_pages = 0
        self.prefix_probes = 0
        self.prefix_misses = 0

    # -- allocation / release (the only paths to the pool's free lists) ------

    def append_page(self, view: list, alloc=None) -> int:
        """Grow a view by one fresh (exclusive) physical page. ``alloc``
        overrides the physical allocator — a fabric view passes its
        quota-ledgered, per-tenant allocation cycle; bare callers get the
        pool's own."""
        pid = (alloc or self.pool.alloc_page)()
        self.ref[pid] = 1
        view.append(pid)
        return pid

    def grow(self, view: list, n: int, alloc=None) -> None:
        for _ in range(n):
            self.append_page(view, alloc=alloc)

    def pop_page(self, view: list) -> int:
        """Undo the most recent ``append_page`` on this view (speculative
        rollback): drops the reference and returns the id so the caller can
        hand it back to the allocator (``pool.undo_alloc`` — *not*
        ``free_pages``, which would log churn and reorder the free list).
        Only valid for exclusive, trie-unregistered pages — which freshly
        appended decode pages always are."""
        pid = view.pop()
        n = self.ref.pop(pid)
        assert n == 1, "cannot pop a shared page"
        assert pid not in self._node_of, "cannot pop a registered page"
        return pid

    def release(self, view: Sequence[int]) -> list[int]:
        """Drop one reference per page; free pages nobody holds anymore.
        Returns the freed (dead) page ids so ledgered callers (fabric
        views) can settle per-tenant ownership accounting."""
        dead: list[int] = []
        for pid in view:
            n = self.ref[pid] - 1
            if n:
                self.ref[pid] = n
            else:
                del self.ref[pid]
                self._unregister(pid)
                dead.append(pid)
        if dead:
            self.pool.free_pages(dead)
        return dead

    # -- sharing ---------------------------------------------------------------

    def shared(self, pid: int) -> bool:
        return self.ref.get(pid, 1) > 1

    def exclusive(self, view: Sequence[int]) -> list[int]:
        """The view's movable pages: held by this view alone. Shared pages
        are pinned — migration/swap would yank them out from under the
        other holders."""
        return [p for p in view if self.ref.get(p, 1) == 1]

    def match_prefix(self, tokens: Sequence[int], view: list, *,
                     count: bool = True, allow=None) -> int:
        """Walk the trie over full ``page_size`` blocks of ``tokens``,
        bumping refcounts and appending matched physical pages to ``view``
        (must be empty). Returns the number of *tokens* covered.
        ``count=False`` leaves the probe/miss telemetry untouched (a
        capacity-blocked request re-probes every step hoping for a late
        registration; only its first probe should count). ``allow`` is an
        optional per-page predicate: the walk stops at the first physical
        page it rejects — fabric views use it to gate the cross-tenant
        prefix tier (a view may only match pages whose owner opted into
        sharing)."""
        assert not view, "prefix match must seed an empty view"
        if count:
            self.prefix_probes += 1
        if not (self.prefix_reuse and self._shareable):
            return 0
        ps = self.pool.page_size
        parent = ROOT
        for b in range(len(tokens) // ps):
            block = tuple(tokens[b * ps:(b + 1) * ps])
            nid = self._index.get((parent, block))
            if nid is None:
                break
            pid = self._nodes[nid].phys
            if allow is not None and not allow(pid):
                break
            self.ref[pid] += 1
            view.append(pid)
            parent = nid
        if count and not view:
            self.prefix_misses += 1
        self.prefix_hit_pages += len(view)
        return len(view) * ps

    def peek_prefix(self, tokens: Sequence[int], *, allow=None) -> int:
        """``match_prefix`` without the side effects: how many *tokens* a
        probe would cover right now, bumping no refcounts and touching no
        telemetry. Trie-aware admission calls this at submit time to size a
        request's physical (post-sharing) footprint."""
        if not (self.prefix_reuse and self._shareable):
            return 0
        ps = self.pool.page_size
        parent = ROOT
        matched = 0
        for b in range(len(tokens) // ps):
            block = tuple(tokens[b * ps:(b + 1) * ps])
            nid = self._index.get((parent, block))
            if nid is None or (allow is not None
                               and not allow(self._nodes[nid].phys)):
                break
            matched += 1
            parent = nid
        return matched * ps

    def register_prefix(self, tokens: Sequence[int], view: Sequence[int],
                        upto_tokens: int) -> int:
        """Make the view's full prompt pages discoverable: register every
        page whose ``page_size`` token block lies entirely within
        ``tokens[:upto_tokens]`` (i.e. whose K/V is final). Idempotent along
        already-registered chains; first writer wins on races (a page that
        lost the race simply stays private). Returns pages registered."""
        if not (self.prefix_reuse and self._shareable):
            return 0
        ps = self.pool.page_size
        parent = ROOT
        added = 0
        for b in range(upto_tokens // ps):
            block = tuple(tokens[b * ps:(b + 1) * ps])
            key = (parent, block)
            nid = self._index.get(key)
            if nid is None:
                pid = view[b]
                if pid in self._node_of:       # already registered elsewhere
                    break                       # (can't chain through it twice)
                nid = next(self._ids)
                node = _TrieNode(nid, parent, block, pid)
                self._nodes[nid] = node
                self._index[key] = nid
                self._node_of[pid] = nid
                if parent != ROOT and parent in self._nodes:
                    self._nodes[parent].children.add(nid)
                added += 1
            parent = nid
        return added

    # -- chain export / import (persistence tier, DESIGN.md §9) ----------------

    def export_chains(self, select=None) -> list[dict]:
        """Serialize the trie as maximal root-anchored chains.

        A chain is only meaningful with its whole ancestor line (the chain
        key is ``(parent, block)``), so the walk starts at depth-0 nodes and
        descends while every page passes ``select`` (default: all). Each
        record carries the concatenated token blocks and the physical ids in
        chain order — enough for a peer (or a restarted fabric) to rebuild
        the exact chain keys via ``register_prefix``. Branching chains emit
        one record per leaf; shared ancestor pages repeat across records and
        deduplicate on import through a prefix probe.
        """
        ok = (lambda pid: True) if select is None else select
        out: list[dict] = []
        roots = [n for n in self._nodes.values()
                 if n.parent == ROOT and ok(n.phys)]
        stack = [(n, [], []) for n in sorted(roots, key=lambda n: -n.nid)]
        while stack:
            node, toks, phys = stack.pop()
            toks = toks + list(node.block)
            phys = phys + [node.phys]
            kids = [self._nodes[c] for c in node.children
                    if c in self._nodes and ok(self._nodes[c].phys)]
            if not kids:
                out.append({"tokens": toks, "phys": phys})
                continue
            stack.extend((k, toks, phys)
                         for k in sorted(kids, key=lambda n: -n.nid))
        return out

    def import_chains(self, chains: Sequence[dict], pages_of) -> int:
        """Re-register exported chains against *this* table. ``pages_of``
        maps a chain record to its already-materialized physical pages (the
        importer allocates and fills them first). Idempotent along chains
        that already exist. Returns pages newly registered."""
        added = 0
        for ch in chains:
            added += self.register_prefix(ch["tokens"], pages_of(ch),
                                          len(ch["tokens"]))
        return added

    # -- copy-on-write ---------------------------------------------------------

    def fork_for_write(self, view: list, idx: int, alloc=None) -> int:
        """Make logical page ``idx`` privately writable. No-op for exclusive
        pages; for shared pages: allocate a clone, copy the bytes (one
        batched gather/scatter through the pool's executor), move this
        view's reference onto the clone. Returns the writable physical id.
        ``alloc`` overrides the physical allocator (fabric views charge the
        clone to their own quota)."""
        pid = view[idx]
        if self.ref.get(pid, 1) <= 1:
            return pid
        clone = (alloc or self.pool.alloc_page)()
        (self.pool.k_pool, self.pool.v_pool), _ = self.pool.executor.execute(
            (self.pool.k_pool, self.pool.v_pool), [pid], [clone],
            src_domains=[self.pool.domain_of(pid)],
            dst_domains=[self.pool.domain_of(clone)])
        self.ref[pid] -= 1
        self.ref[clone] = 1
        view[idx] = clone
        self.cow_faults += 1
        return clone

    def ensure_writable(self, view: list, lo_tok: int, hi_tok: int,
                        alloc=None) -> None:
        """CoW-fork every logical page overlapping token positions
        [lo_tok, hi_tok) ahead of a write."""
        ps = self.pool.page_size
        for idx in range(lo_tok // ps, -(-hi_tok // ps)):
            self.fork_for_write(view, idx, alloc=alloc)

    # -- movement notifications (migration / swap / rebalance) -----------------

    def remap_physical(self, old: int, new: int) -> None:
        """A mover relocated an exclusive page's bytes: carry the reference
        and any trie node over to the new id."""
        self.ref[new] = self.ref.pop(old)
        nid = self._node_of.pop(old, None)
        if nid is not None:
            self._nodes[nid].phys = new
            self._node_of[new] = nid

    def unregister(self, pid: int) -> None:
        """Drop the page (and its now-unreachable descendants) from the
        trie without touching refcounts — used when a page's bytes leave
        the live pool (swap-out parks them in a reserved slot)."""
        self._unregister(pid)

    def _unregister(self, pid: int) -> None:
        nid = self._node_of.pop(pid, None)
        if nid is None:
            return
        stack = [nid]
        while stack:
            n = self._nodes.pop(stack.pop())
            self._index.pop((n.parent, n.block), None)
            self._node_of.pop(n.phys, None)
            if n.parent in self._nodes:
                self._nodes[n.parent].children.discard(n.nid)
            stack.extend(c for c in n.children if c in self._nodes)

    def remap(self, id_map) -> None:
        """Pool was rebuilt (arbiter rebalance): rewrite every physical id."""
        self.ref = {int(id_map[p]): n for p, n in self.ref.items()}
        self._node_of = {}
        for nid, node in self._nodes.items():
            node.phys = int(id_map[node.phys])
            assert node.phys >= 0, "trie page lost in rebalance"
            self._node_of[node.phys] = nid
        assert all(p >= 0 for p in self.ref), "refcounted page lost"

    # -- reporting -------------------------------------------------------------

    def stats(self) -> dict:
        """Instantaneous sharing state + cumulative fork/probe counters."""
        phys = len(self.ref)
        logical = sum(self.ref.values())
        return {
            "physical_pages": phys,
            "logical_pages": logical,
            "shared_pages": sum(1 for n in self.ref.values() if n > 1),
            "unique_pages": sum(1 for n in self.ref.values() if n == 1),
            "saved_pages": logical - phys,
            "trie_nodes": len(self._nodes),
            "cow_faults": self.cow_faults,
            "prefix_hit_pages": self.prefix_hit_pages,
            "prefix_probes": self.prefix_probes,
            "prefix_misses": self.prefix_misses,
        }
