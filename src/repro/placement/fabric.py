"""Memory fabric: one placement surface for pool, page table, swap, arbiter.

BWAP's thesis is that placement must be tuned per co-located application
partition (paper §III-B3); before this layer the runtime's placement state
was smeared across four subsystems glued by ad-hoc attach calls
(``pool.table``, ``arbiter.attach_engine``, ``telemetry.attach_pagetable``,
``swap → pool.set_reserved_counts``). The fabric replaces those pairwise
back-channels with a single owner (DESIGN.md §8):

- :class:`MemoryFabric` owns the memory domains, the physical page pool
  (one array set per model group — which is what makes *cross-tenant*
  physical page sharing possible at all), the logical page table, the
  per-tenant quota/reservation ledgers, the swap-slot loan broker, the
  Eq.-1 calibration state, the persistent third tier
  (:class:`~repro.placement.persist.PersistentTier`, DESIGN.md §9), and an
  event bus (``on_alloc/on_free/on_migrate/on_share/on_latency`` plus the
  tier's ``on_demote/on_promote/on_restore``).
- :class:`FabricView` is a tenant-scoped handle — the **only** API the
  serve/scheduler layers touch. Page lifetime (``alloc``/``free``/CoW/
  prefix sharing), swap reservations and loans, migration, Eq.-1 cost
  queries, and the K/V data plane all go through the view, which charges
  every physical page to its tenant's ledger.

Tenants of one fabric share one physical pool and one prefix trie, so a
view's ``probe_prefix`` can map another tenant's registered prompt pages
into its own sequences (the arbiter-brokered read-only prefix tier;
``share_prefix`` gates it per view), and idle swap reservations can be
loaned across tenants (``request_loan``/``recall_loans``) with Eq.-1
stall-cost accounting on the reclaim path.

``as_view(pool)`` adopts a bare :class:`BwapPagePool` into a single-view
fabric whose placement decisions delegate to the pool's own tuner/cycle —
bit-identical to the pre-fabric behavior — so single-tenant callers keep
constructing pools directly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core import bwmodel, interleave
from repro.core.dwp import DWPConfig, DWPTuner
from repro.placement import policy as placement_policy
from repro.placement.pool import BwapPagePool, MemoryDomain
from repro.placement.telemetry import DomainTelemetry

EVENTS = ("alloc", "free", "migrate", "share", "latency",
          "demote", "promote", "restore",
          "evict", "export_skip", "link_send", "link_recv")

# The event payload contract: every ``emit(event, ...)`` call site carries
# AT LEAST these keyword fields (tests/test_obs.py asserts it statically
# over the source and dynamically on a live fabric), so tracer/metrics
# subscribers can rely on them. ``share`` fans out by ``kind``.
EVENT_FIELDS: dict[str, tuple[str, ...]] = {
    "alloc": ("view", "page", "domain"),
    "free": ("view", "page", "domain"),      # view None: owner already gone
    "migrate": ("view", "src", "dst"),       # one physical page id pair
    "share": ("kind",),                      # + SHARE_KIND_FIELDS[kind]
    "latency": ("view", "seconds"),
    "demote": ("view", "pages", "handles", "seconds"),
    "promote": ("view", "pages", "seconds"),
    "restore": ("view", "pages", "seconds"),
    "evict": ("view", "pages", "chains"),        # LRU prefix-store eviction
    "export_skip": ("view", "pages", "chains"),  # over-cap chains dropped
    "link_send": ("view", "bytes", "chunks", "seconds"),
    "link_recv": ("view", "pages", "bytes", "seconds"),
}
SHARE_KIND_FIELDS: dict[str, tuple[str, ...]] = {
    "prefix": ("page", "owner", "view"),     # view = the borrowing reader
    "loan": ("lender", "borrower", "slots"),
    "reclaim": ("lender", "borrower", "slots", "seconds"),
}


@dataclasses.dataclass
class SlotLoan:
    """One cross-tenant swap-slot loan (arbiter-brokered)."""

    lender: str
    borrower: str
    slots: list[int]                     # outstanding loaned slot ids
    granted: int = 0                     # cumulative slots ever granted
    reclaimed: int = 0                   # cumulative slots reclaimed
    reclaim_seconds: float = 0.0         # Eq.-1 time spent vacating

    def as_dict(self) -> dict:
        return {
            "lender": self.lender, "borrower": self.borrower,
            "outstanding": len(self.slots), "granted": self.granted,
            "reclaimed": self.reclaimed,
            "reclaim_seconds": self.reclaim_seconds,
        }


class MemoryFabric:
    """Owner of one model group's placement state; hands out views."""

    def __init__(self, cfg, domains: Sequence[MemoryDomain], *,
                 page_size: int = 16, seed: int = 0,
                 policy: str = "bwap_dwp",
                 telemetry: DomainTelemetry | None = None,
                 calibration_alpha: float = 0.25,
                 geometry=None, group: str = ""):
        self.cfg = cfg
        self.seed = seed
        self.policy_name = policy
        # model-group label (zoo member fabrics set it; "" = single-group,
        # which keeps every metric label bit-identical to pre-zoo runs)
        self.group = group
        self.pool = BwapPagePool(cfg, domains, page_size=page_size,
                                 seed=seed, policy=policy,
                                 telemetry=telemetry, geometry=geometry)
        self.table = self.pool.table
        self.telemetry = self.pool.telemetry
        self.views: dict[str, FabricView] = {}
        self.owner: dict[int, str] = {}        # live physical page -> view
        self._subs: dict[str, list[Callable]] = {e: [] for e in EVENTS}
        self._providers: dict[str, object] = {}   # view -> slot provider
        self.loans: list[SlotLoan] = []
        self.persist = None                    # PersistentTier (third tier)
        self.obs = None                        # Observatory (DESIGN.md §10)
        self._adopted = False
        # Eq.-1 calibration (EWMA over measured per-domain transfer times);
        # starts at the analytic bandwidths and is shared by every view's
        # stall_cost / expected_read_time / swap-transfer estimate
        self._alpha = calibration_alpha
        self._bw_cal = np.asarray(self.pool.bw, dtype=np.float64).copy()
        self.calibration_samples = 0

    # -- adoption (single-view compat over a bare pool) ----------------------

    @classmethod
    def adopt(cls, pool: BwapPagePool) -> "MemoryFabric":
        """Wrap an existing pool in a single-view fabric. Placement
        decisions (allocation cycle, weights, migration targets, tuner)
        delegate to the pool itself, so adopted behavior is bit-identical
        to driving the pool directly."""
        fab = cls.__new__(cls)
        fab.cfg = pool.cfg
        fab.seed = 0
        fab.policy_name = "adopted"
        fab.group = ""
        fab.pool = pool
        fab.table = pool.table
        fab.telemetry = pool.telemetry
        fab.views = {}
        fab.owner = {}
        fab._subs = {e: [] for e in EVENTS}
        fab._providers = {}
        fab.loans = []
        fab.persist = None
        fab.obs = None
        fab._adopted = True
        fab._alpha = 0.25
        fab._bw_cal = np.asarray(pool.bw, dtype=np.float64).copy()
        fab.calibration_samples = 0
        quota = np.asarray([d.num_pages for d in pool.domains],
                           dtype=np.int64)
        view = FabricView(fab, "default", quota=quota, home=pool.workers,
                          adopted=True)
        fab.views["default"] = view
        return fab

    # -- persistent tier (third tier below the swap slots) ---------------------

    def attach_persist(self, tier) -> None:
        """Own a :class:`~repro.placement.persist.PersistentTier`. Its
        demote/promote/restore events route into the telemetry tier
        counters, and each event refreshes the per-tier occupancy gauges
        (fast domains / swap slots / persistent tier)."""
        assert self.persist is None, "fabric already owns a persistent tier"
        self.persist = tier
        tier.bind(self)
        for ev in ("demote", "promote", "restore", "evict"):
            self.subscribe(ev, self._tier_recorder(ev))
        self.refresh_tier_gauges()

    def _tier_recorder(self, event: str) -> Callable:
        def record(pages: int = 0, seconds: float = 0.0, **_) -> None:
            self.telemetry.record_tier(event, int(pages), float(seconds))
            self.refresh_tier_gauges()
        return record

    def refresh_tier_gauges(self) -> None:
        """Occupancy gauges for the three placement tiers (DESIGN.md §9)."""
        tel, pool = self.telemetry, self.pool
        reserved = int(pool.reserved.sum())
        tel.record_tier_occupancy("fast_domains",
                                  int(pool.used_pages().sum()),
                                  pool.total_pages - reserved)
        parked = sum(len(p.parked_ids())
                     for p in self._providers.values())
        tel.record_tier_occupancy("swap_slots", parked, reserved)
        if self.persist is not None:
            tel.record_tier_occupancy(self.persist.name,
                                      self.persist.used_pages(),
                                      self.persist.capacity_pages)

    # -- event bus ------------------------------------------------------------

    def subscribe(self, event: str, fn: Callable) -> None:
        """Register ``fn`` on one of the fabric events (``alloc``, ``free``,
        ``migrate``, ``share``, ``latency``, the tier's ``demote``/
        ``promote``/``restore``/``evict``/``export_skip``, or the cluster
        wire's ``link_send``/``link_recv``). Callbacks receive keyword
        arguments only; unknown keys must be tolerated (``**_``)."""
        assert event in EVENTS, f"unknown fabric event {event!r}"
        self._subs[event].append(fn)

    def emit(self, event: str, **kw) -> None:
        """Fan one event out to its subscribers. A raising subscriber is
        isolated — emit sits on the alloc/free hot path, and a broken
        observer must never abort placement — and counted in
        ``telemetry.subscriber_errors`` (labeled per event in the metrics
        registry)."""
        for fn in self._subs[event]:
            try:
                fn(**kw)
            except Exception:
                self.telemetry.record_subscriber_error(event)

    def attach_obs(self, obs) -> None:
        """Register the fabric observatory (``repro.obs.Observatory``);
        scheduler/engine/swap hot paths find it via ``view.fabric.obs``."""
        assert self.obs is None, "fabric already has an observatory"
        self.obs = obs

    # -- views ----------------------------------------------------------------

    def view(self, name: str, *, quota: Sequence[int],
             home: Sequence[int], level: int = 0,
             share_prefix: bool = True, tuner=None,
             dwp_config: DWPConfig | None = None) -> "FabricView":
        """Create a tenant view: ``quota`` pages per domain (the view's
        ledger ceiling), ``home`` worker domains (its placement target),
        ``level`` its scheduling priority, ``share_prefix`` its membership
        in the cross-tenant read-only prefix tier. ``tuner`` overrides the
        view's DWP tuner (the arbiter passes a CoScheduledTuner for
        best-effort tenants)."""
        assert name not in self.views, f"view {name!r} already registered"
        assert not self._adopted, "adopted fabrics are single-view"
        quota = np.asarray(quota, dtype=np.int64)
        assert quota.shape == (len(self.pool.domains),)
        v = FabricView(self, name, quota=quota, home=tuple(home),
                       level=level, share_prefix=share_prefix,
                       tuner=tuner, dwp_config=dwp_config)
        self.views[name] = v
        return v

    def unregister(self, name: str) -> np.ndarray:
        """Remove a view. Remaining holds are force-released (a drained
        tenant has none); pages that survive because other views hold them
        are re-owned by a surviving holder, so nothing leaks and nothing a
        live tenant reads is freed. Returns the view's per-domain quota for
        the caller (arbiter) to redistribute — pure ledger arithmetic, no
        array rebuild, no id remapping. The view's swap manager (if any)
        is closed first: loans settle and its reservation returns to the
        allocator."""
        v = self.views[name]
        prov = self._providers.get(name)
        if prov is not None and hasattr(prov, "close"):
            prov.close()
        for pid in [p for p, c in list(v._held.items()) for _ in range(c)]:
            if pid < 0:                 # persisted handle: no free-list id
                v.drop_parked_ref(pid)
                if pid not in self.table.ref and self.persist is not None:
                    self.persist.forget(pid)
                continue
            v._drop(pid)
            dead = self.table.release([pid])
            for d in dead:
                self._on_free(d)
        for pid, owner in list(self.owner.items()):
            if owner == name:            # shared pages another view holds
                self._reassign_owner(pid, exclude=name)
        del self.views[name]
        self._providers.pop(name, None)
        assert not any(ln.slots for ln in self.loans
                       if name in (ln.lender, ln.borrower)), \
            "unregistered view still party to an outstanding loan"
        assert not any(o == name for o in self.owner.values()), \
            "unregistered view still owns pages"
        return v.quota.copy()

    # -- ledger hooks (views call these; nothing else should) -----------------

    def _own(self, view: "FabricView", pid: int) -> None:
        self.owner[pid] = view.name
        view.used[self.pool.domain_of(pid)] += 1
        self.emit("alloc", view=view.name, page=pid,
                  domain=self.pool.domain_of(pid))

    def _on_alloc(self, view: "FabricView", pid: int) -> None:
        self._own(view, pid)
        view._hold(pid)

    def _on_free(self, pid: int) -> None:
        name = self.owner.pop(pid, None)
        if name is not None and name in self.views:
            self.views[name].used[self.pool.domain_of(pid)] -= 1
        self.emit("free", view=name, page=pid,
                  domain=self.pool.domain_of(pid))

    def _on_undo(self, view: "FabricView", pid: int) -> None:
        """Speculative-allocation rollback: ownership reverts with no free
        event (rejected speculation is not page churn)."""
        if self.owner.pop(pid, None) is not None:
            view.used[self.pool.domain_of(pid)] -= 1

    def _reassign_owner(self, pid: int, exclude: str) -> None:
        for v in self.views.values():
            if v.name != exclude and v._held.get(pid, 0) > 0:
                old = self.owner.get(pid)
                if old is not None and old in self.views:
                    self.views[old].used[self.pool.domain_of(pid)] -= 1
                self.owner[pid] = v.name
                v.used[self.pool.domain_of(pid)] += 1
                return
        # nobody else holds it: the caller is about to free it

    # -- swap-slot loan broker -------------------------------------------------

    def offer_slots(self, view: "FabricView", provider) -> None:
        """A view's swap manager registers as a slot provider. Protocol:
        ``lendable_count(domains=None)``, ``lend_slots(n, domains) ->
        ids``, ``take_slots(ids)``, ``yield_slots(ids) -> (ids,
        seconds)``, ``idle_count(ids)``, ``parked_ids()``."""
        self._providers[view.name] = provider

    def withdraw_slots(self, view: "FabricView") -> None:
        """Remove a view's slot provider (its swap manager closed)."""
        self._providers.pop(view.name, None)

    def borrowable(self, borrower: "FabricView") -> int:
        """Idle slots other views could lend right now — counting only
        domains the borrower can actually park in (its slow set), so the
        promise matches what ``request_loan`` can deliver."""
        want = set(borrower.slow_domains)
        return sum(p.lendable_count(want)
                   for name, p in self._providers.items()
                   if name != borrower.name)

    def recallable(self, lender: "FabricView") -> int:
        """Loaned-out slots of ``lender`` that are instantly reclaimable
        (idle at the borrower); parked loaned slots may still vacate on
        demand but are not promised here."""
        n = 0
        for loan in self.loans:
            if loan.lender != lender.name or not loan.slots:
                continue
            p = self._providers.get(loan.borrower)
            if p is not None:
                n += p.idle_count(loan.slots)
        return n

    def request_loan(self, borrower: "FabricView", n: int) -> int:
        """Broker up to ``n`` idle reserved slots from other views into the
        borrower's swap manager. Slots stay charged to the lender's
        reservation ledger (the loan is temporary occupancy, not a quota
        transfer). Returns the number of slots granted."""
        taker = self._providers.get(borrower.name)
        if taker is None or n <= 0:
            return 0
        want_domains = set(borrower.slow_domains)
        granted = 0
        for name, p in self._providers.items():
            if granted >= n or name == borrower.name:
                continue
            ids = p.lend_slots(min(n - granted,
                                   p.lendable_count(want_domains)),
                               want_domains)
            if not ids:
                continue
            taker.take_slots(ids)
            loan = self._loan(name, borrower.name)
            loan.slots.extend(ids)
            loan.granted += len(ids)
            granted += len(ids)
            self.emit("share", kind="loan", lender=name,
                      borrower=borrower.name, slots=list(ids))
        return granted

    def recall_loans(self, lender: "FabricView",
                     need: int) -> tuple[int, float]:
        """Reclaim up to ``need`` loaned-out slots for ``lender``. Borrowers
        vacate on demand: idle slots return instantly; parked slots
        relocate into the borrower's remaining reservation (one batched
        copy, Eq.-1 stall-cost accounted on the loan record). Returns
        ``(slots_returned, seconds)``."""
        back = self._providers.get(lender.name)
        returned, seconds = 0, 0.0
        if back is None:
            return returned, seconds
        for loan in self.loans:
            if returned >= need or loan.lender != lender.name \
                    or not loan.slots:
                continue
            holder = self._providers.get(loan.borrower)
            if holder is None:
                continue
            # ask idle slots first: a parked slot the borrower cannot
            # vacate must not shadow reclaimable idle ones further down
            idle = [p for p in loan.slots if holder.idle_count([p])]
            parked = [p for p in loan.slots if p not in idle]
            ask = (idle + parked)[:need - returned]
            got, secs = holder.yield_slots(list(ask))
            for pid in got:
                loan.slots.remove(pid)
            back.take_slots(got)
            loan.reclaimed += len(got)
            loan.reclaim_seconds += secs
            returned += len(got)
            seconds += secs
            self.emit("share", kind="reclaim", lender=lender.name,
                      borrower=loan.borrower, slots=list(got),
                      seconds=secs)
        return returned, seconds

    def _loan(self, lender: str, borrower: str) -> SlotLoan:
        for loan in self.loans:
            if loan.lender == lender and loan.borrower == borrower:
                return loan
        loan = SlotLoan(lender, borrower, [])
        self.loans.append(loan)
        return loan

    def settle_loans(self, view: "FabricView") -> None:
        """Close out every loan touching ``view`` (its swap manager is
        shutting down). Borrowed slots go back to their lenders (the
        closing manager holds no parked KV, so they are idle). Lent-out
        slots are recalled; any the borrower cannot vacate transfer their
        reservation charge to the borrower — occupancy must stay
        consistent even if the lender leaves."""
        name = view.name
        for loan in self.loans:
            if loan.borrower == name and loan.slots:
                holder = self._providers.get(name)
                lender = self._providers.get(loan.lender)
                got, _ = holder.yield_slots(list(loan.slots))
                assert len(got) == len(loan.slots), \
                    "closing borrower still parks KV in loaned slots"
                loan.slots.clear()
                loan.reclaimed += len(got)
                if lender is not None:
                    lender.take_slots(got)
                else:                     # lender view already gone
                    for q in got:
                        self.pool.unreserve_page(q)
            if loan.lender == name and loan.slots:
                self.recall_loans(view, len(loan.slots))
                for q in list(loan.slots):
                    d = self.pool.domain_of(q)
                    assert view.reserved[d] > 0
                    view.reserved[d] -= 1
                    borrower = self.views.get(loan.borrower)
                    if borrower is not None:
                        borrower.reserved[d] += 1
                    loan.slots.remove(q)

    # -- Eq.-1 calibration -----------------------------------------------------

    @property
    def bw_effective(self) -> np.ndarray:
        """Per-domain bandwidths every Eq.-1 consumer reads: the analytic
        profile until ``calibrate`` feeds measurements, then the EWMA of
        measured transfer rates (ROADMAP real-machine calibration)."""
        return self._bw_cal

    def calibrate(self, measured_s: Sequence[float | None],
                  *, page_bytes: int | None = None) -> np.ndarray:
        """Fold one measured sample per domain into the effective
        bandwidths: ``measured_s[d]`` is the observed seconds to transfer
        one page (``page_bytes`` overrides the pool's page size) from
        domain ``d``; ``None`` skips a domain. EWMA with the fabric's
        ``calibration_alpha``; returns the updated effective GB/s."""
        nbytes = page_bytes if page_bytes is not None \
            else self.pool.page_bytes
        for d, s in enumerate(measured_s):
            if s is None:
                continue
            assert s > 0, "measured transfer time must be positive"
            sample = nbytes / float(s) / 1e9
            self._bw_cal[d] = ((1 - self._alpha) * self._bw_cal[d]
                               + self._alpha * sample)
        self.calibration_samples += 1
        return self._bw_cal.copy()

    # -- invariants / reporting ------------------------------------------------

    def cross_shared_pages(self) -> int:
        """Physical pages currently held by two or more distinct views —
        the cross-tenant prefix tier's footprint saving."""
        n = 0
        views = list(self.views.values())
        for pid in self.table.ref:
            holders = sum(1 for v in views if v._held.get(pid, 0) > 0)
            n += holders >= 2
        return n

    def check_invariants(self) -> None:
        """Fabric-wide consistency (the hypothesis property test drives
        this after every operation): refcounts == view holds, ownership
        ledgers == live allocations, parked pages accounted, page ids
        conserved."""
        held: dict[int, int] = {}
        for v in self.views.values():
            for pid, c in v._held.items():
                assert c > 0, f"non-positive hold {pid} in {v.name}"
                held[pid] = held.get(pid, 0) + c
        assert held == dict(self.table.ref), \
            f"view holds {held} != table refcounts {dict(self.table.ref)}"
        per_view = {n: np.zeros(len(self.pool.domains), dtype=np.int64)
                    for n in self.views}
        for pid, name in self.owner.items():
            assert name in self.views, f"page {pid} owned by ghost {name!r}"
            per_view[name][self.pool.domain_of(pid)] += 1
        for name, v in self.views.items():
            np.testing.assert_array_equal(
                v.used, per_view[name],
                err_msg=f"view {name!r} ledger != ownership map")
        parked = set()
        for p in self._providers.values():
            parked |= set(p.parked_ids())
        persisted = set(self.persist.persisted_ids()) \
            if self.persist is not None else set()
        for pid in self.table.ref:
            assert pid in self.owner or pid in parked \
                or pid in persisted, \
                f"live page {pid} neither owned, parked, nor persisted"
        if self.persist is not None:
            per = self.persist.per_view_counts()
            for name, v in self.views.items():
                assert int(v.persisted) == per.get(name, 0), \
                    f"view {name!r} persisted ledger != tier contents"
            for h in persisted:
                assert h <= -2, f"persisted handle {h} collides with ids"
                assert h not in self.owner, \
                    f"persisted handle {h} owned as a live page"
        free = sum(len(f) for f in self.pool.free)
        assert free + len(self.owner) + int(self.pool.reserved.sum()) \
            == self.pool.total_pages, "page ids not conserved"
        # byte-denominated ledger balance (DESIGN.md §12): every page of
        # this fabric carries the group geometry's page_bytes, so view
        # byte ledgers must sum to exactly the owned physical bytes
        pb = int(self.pool.page_bytes)
        assert sum(v.used_bytes() for v in self.views.values()) \
            == len(self.owner) * pb, "view byte ledgers != owned bytes"
        assert (free + int(self.pool.reserved.sum())) * pb \
            + sum(v.used_bytes() for v in self.views.values()) \
            == self.pool.total_pages * pb, "fabric bytes not conserved"

    def stats(self) -> dict:
        out = {
            "views": {},
            "cross_shared_pages": self.cross_shared_pages(),
            "calibration_samples": self.calibration_samples,
            "bw_effective_gbps": self._bw_cal.tolist(),
            "loans": [ln.as_dict() for ln in self.loans],
        }
        if self.persist is not None:
            out["persist"] = self.persist.stats()
        for name, v in self.views.items():
            out["views"][name] = {
                "quota": v.quota.tolist(),
                "used": v.used.tolist(),
                "reserved": v.reserved.tolist(),
                "quota_bytes": v.quota_bytes(),
                "used_bytes": v.used_bytes(),
                "held_logical": int(sum(v._held.values())),
                "persisted": int(v.persisted),
                "level": v.level,
                "share_prefix": v.share_prefix,
                "dwp": v.dwp,
            }
        return out


class FabricView:
    """Tenant-scoped placement handle — the only surface serve/scheduler
    layers may touch. Wraps page lifetime, sharing, reservations, loans,
    migration, Eq.-1 costs, and the K/V data plane, charging everything to
    this tenant's ledger."""

    def __init__(self, fabric: MemoryFabric, name: str, *,
                 quota: np.ndarray, home: Sequence[int], level: int = 0,
                 share_prefix: bool = True, tuner=None,
                 dwp_config: DWPConfig | None = None,
                 adopted: bool = False):
        self.fabric = fabric
        self.name = name
        # private copy: the arbiter mutates view quotas on rebalance and
        # keeps its own ledger — aliasing would double-apply grants
        self.quota = np.array(quota, dtype=np.int64)
        self.home = tuple(home)
        self.level = level
        self.share_prefix = share_prefix
        self._adopted = adopted
        self.used = np.zeros(len(fabric.pool.domains), dtype=np.int64)
        self.reserved = np.zeros(len(fabric.pool.domains), dtype=np.int64)
        self.persisted = 0             # this view's pages in the third tier
        self._held: dict[int, int] = {}
        self._assignment_cbs: list[Callable] = []
        self._page_remap_cbs: list[Callable] = []
        pool = fabric.pool
        if adopted:
            self._cotuned = False
            self.tuner = None            # property delegates to the pool
            self._policy = None
        else:
            self._policy = placement_policy.resolve(fabric.policy_name)
            canonical = placement_policy.weights(
                "bwap_canonical", self._ctx(0.0))
            self._cotuned = tuner is not None
            self.tuner = tuner if tuner is not None else DWPTuner(
                canonical, list(self.home), num_pages=4096,
                config=dwp_config or DWPConfig(n=8, c=2),
                on_migrate=lambda plan: fabric.telemetry.record_plan(
                    plan.num_moves))
            self._cycle_pos = 0
            self._perm = np.random.default_rng(
                fabric.seed + len(fabric.views)).permutation(
                len(self.tuner.assignment))

    # -- config / topology ----------------------------------------------------

    @property
    def pool(self) -> BwapPagePool:
        return self.fabric.pool

    @property
    def table(self):
        return self.fabric.table

    @property
    def telemetry(self) -> DomainTelemetry:
        return self.fabric.telemetry

    @property
    def page_size(self) -> int:
        return self.pool.page_size

    @property
    def page_bytes(self) -> int:
        return self.pool.page_bytes

    @property
    def geometry(self):
        """This group's :class:`~repro.placement.geometry.PageGeometry`
        (growth law, shareability class, bytes per page)."""
        return self.pool.geometry

    def quota_bytes(self) -> int:
        """Byte-denominated funding of this view (DESIGN.md §12) — the
        ledger unit the capacity market trades in."""
        return int(self.quota.sum()) * int(self.page_bytes)

    def used_bytes(self) -> int:
        """Bytes of physical pages currently charged to this view."""
        return int(self.used.sum()) * int(self.page_bytes)

    @property
    def domains(self):
        return self.pool.domains

    @property
    def bw(self) -> np.ndarray:
        """Effective (calibrated) per-domain bandwidths."""
        return self.fabric.bw_effective

    @property
    def slow_domains(self) -> tuple[int, ...]:
        """Domains outside this view's home set — where its KV parks."""
        if self._adopted:
            return self.pool.slow_domains
        return tuple(d for d in range(len(self.pool.domains))
                     if d not in self.home)

    def domain_of(self, pid: int) -> int:
        return self.pool.domain_of(pid)

    @property
    def placement_policy(self) -> placement_policy.PlacementPolicy:
        """The resolved policy instance steering this view — carries the
        execution-mode flags (``micro_batch``/``rehome``) the scheduler
        and engine read. Adopted views delegate to the pool's policy."""
        return self.pool.policy if self._adopted else self._policy

    def capacity(self) -> int:
        """Pages this view may ever hold at once (its quota)."""
        return int(self.quota.sum())

    # -- allocation ------------------------------------------------------------

    def _headroom(self, d: int) -> int:
        return int(self.quota[d] - self.used[d] - self.reserved[d])

    def _alloc_physical(self) -> int:
        """Next physical page id under this view's placement cycle and
        quota ledger (adopted views delegate to the pool's own cycle)."""
        pool = self.pool
        if self._adopted:
            return pool.alloc_page()
        cycle = self.tuner.assignment
        for _ in range(len(cycle)):
            want = int(cycle[self._perm[self._cycle_pos % len(self._perm)]])
            self._cycle_pos += 1
            if pool.free[want] and self._headroom(want) > 0:
                self.telemetry.record_alloc(want)
                return pool.free[want].pop()
        for d in pool._bw_order:
            if pool.free[d] and self._headroom(d) > 0:
                self.telemetry.record_alloc(d)
                return pool.free[d].pop()
        raise RuntimeError(
            f"fabric quota exhausted for view {self.name!r}")

    def alloc(self) -> int:
        """One fresh page charged to this view (no table reference — use
        ``append_page`` for sequence views)."""
        pid = self._alloc_physical()
        self.fabric._own(self, pid)
        return pid

    def free(self, pages: Sequence[int]) -> None:
        """Return raw (table-less) pages from ``alloc``."""
        self.pool.free_pages(pages)
        for pid in pages:
            self.fabric._on_free(pid)

    def alloc_marker(self) -> int:
        """Allocation-cycle position for speculative rollback."""
        return self.pool.alloc_marker() if self._adopted else self._cycle_pos

    def undo_alloc(self, pid: int, marker_before: int,
                   marker_after: int) -> None:
        """Rollback of a speculative allocation: free-list LIFO return,
        cycle rewind, alloc-count revert, ledger revert — as if the
        allocation never happened."""
        if self._adopted:
            self.pool.undo_alloc(pid, marker_before, marker_after)
        else:
            self.pool.return_speculative(pid)
            if self._cycle_pos == marker_after:
                self._cycle_pos = marker_before
        self.fabric._on_undo(self, pid)

    def free_count(self) -> int:
        """Pages this view can still allocate right now."""
        if self._adopted:
            return self.pool.free_count()
        return int(sum(min(len(self.pool.free[d]),
                           max(0, self._headroom(d)))
                       for d in range(len(self.pool.domains))))

    # -- page-table lifetime (refcounts ride the view ledger) ------------------

    def _hold(self, pid: int) -> None:
        self._held[pid] = self._held.get(pid, 0) + 1

    def _drop(self, pid: int) -> None:
        n = self._held.get(pid, 0) - 1
        if n > 0:
            self._held[pid] = n
            return
        self._held.pop(pid, None)
        if self.fabric.owner.get(pid) == self.name \
                and self.table.ref.get(pid, 0) > 1:
            # our last hold leaves, others still read it: ownership (and
            # the quota charge) moves to a surviving holder
            self.fabric._reassign_owner(pid, exclude=self.name)

    def _on_remap(self, old: int, new: int) -> None:
        """A mover (swap/migrate) relocated bytes this view holds."""
        n = self._held.pop(old, 0)
        if n:
            self._held[new] = self._held.get(new, 0) + n

    def append_page(self, pages: list) -> int:
        pid = self.table.append_page(pages, alloc=self._alloc_physical)
        self.fabric._on_alloc(self, pid)
        return pid

    def grow(self, pages: list, n: int) -> None:
        for _ in range(n):
            self.append_page(pages)

    def pop_page(self, pages: list) -> int:
        pid = self.table.pop_page(pages)
        self._drop(pid)
        return pid

    def release(self, pages: Sequence[int]) -> None:
        for pid in pages:
            self._drop(pid)
        for pid in self.table.release(pages):
            self.fabric._on_free(pid)

    def drop_parked_ref(self, pid: int) -> None:
        """Discard a dead sequence's reference to a *parked* page: the
        reserved slot keeps its identity (it is not on the free lists, so
        a normal release would corrupt the allocator) — only the table
        reference and this view's hold go away."""
        self._drop(pid)
        n = self.table.ref[pid] - 1
        if n:
            self.table.ref[pid] = n
        else:
            del self.table.ref[pid]
            self.table._unregister(pid)

    def shared(self, pid: int) -> bool:
        return self.table.shared(pid)

    def exclusive(self, pages: Sequence[int]) -> list[int]:
        return self.table.exclusive(pages)

    def fork_for_write(self, pages: list, idx: int) -> int:
        old = pages[idx]
        new = self.table.fork_for_write(pages, idx,
                                        alloc=self._alloc_physical)
        if new != old:
            self.fabric._on_alloc(self, new)
            self._drop(old)
        return new

    def ensure_writable(self, pages: list, lo_tok: int,
                        hi_tok: int) -> None:
        ps = self.page_size
        for idx in range(lo_tok // ps, -(-hi_tok // ps)):
            self.fork_for_write(pages, idx)

    def fork_sequence(self, pages: Sequence[int]) -> list[int]:
        """Geometry-aware whole-sequence fork (DESIGN.md §12).

        Shareable geometries fork lazily: every page's refcount bumps and
        later writes go through the normal ``fork_for_write`` CoW path.
        Non-shareable constant state (SSM) forks eagerly — recurrent
        state is mutated in place every step, so a CoW chain would alias
        live state; the clone gets fresh pages with the state bytes
        copied now through the migration executor."""
        if self.geometry.shareable:
            out = list(pages)
            for pid in out:
                self.table.ref[pid] += 1
                self._hold(pid)
            return out
        out: list[int] = []
        for pid in pages:
            self.append_page(out)
        if out:
            self.execute_copy(list(pages), out)
        return out

    # -- prefix sharing ---------------------------------------------------------

    def _may_match(self, pid: int) -> bool:
        owner = self.fabric.owner.get(pid)
        if owner is None or owner == self.name:
            return True
        other = self.fabric.views.get(owner)
        return (self.share_prefix and other is not None
                and other.share_prefix)

    def probe_prefix(self, tokens: Sequence[int], pages: list, *,
                     count: bool = True) -> int:
        """Trie probe scoped to this view: matches pages of its own tenant
        plus — when both sides opted in — the cross-tenant prefix tier.
        Matched pages join the view's holds; cross-tenant hits emit
        ``share`` events."""
        before = len(pages)
        n = self.table.match_prefix(tokens, pages, count=count,
                                    allow=self._may_match)
        for pid in pages[before:]:
            self._hold(pid)
            owner = self.fabric.owner.get(pid)
            if owner is not None and owner != self.name:
                self.fabric.emit("share", kind="prefix", page=pid,
                                 owner=owner, view=self.name)
        return n

    def peek_prefix(self, tokens: Sequence[int]) -> int:
        """Side-effect-free probe: tokens the trie would cover for this
        view right now (trie-aware admission reads this at submit time)."""
        return self.table.peek_prefix(tokens, allow=self._may_match)

    def register_prefix(self, tokens: Sequence[int], pages: Sequence[int],
                        upto_tokens: int) -> int:
        return self.table.register_prefix(tokens, pages, upto_tokens)

    # -- swap reservations / loans ----------------------------------------------

    def free_domain_count(self, d: int) -> int:
        """Pages this view could still take from domain ``d``."""
        n = len(self.pool.free[d])
        return n if self._adopted else min(n, max(0, self._headroom(d)))

    def reserve(self, domain: int, n: int) -> list[int]:
        """Take ``n`` parking slots out of ``domain`` for this view's swap
        manager; the fabric ledgers them against the view's quota and the
        pool's allocator (and capacity-aware policies) never see them as
        allocatable."""
        assert self._adopted or self._headroom(domain) >= n, \
            f"view {self.name!r} quota cannot cover {n} reserved slots"
        ids = self.pool.reserve_pages(domain, n)
        self.reserved[domain] += n
        self._refresh_tuner_capacity()
        return ids

    def unreserve(self, pid: int) -> None:
        """Return one reserved slot to the shared allocator."""
        dom = self.pool.domain_of(pid)
        self.pool.unreserve_page(pid)
        assert self.reserved[dom] > 0
        self.reserved[dom] -= 1
        self._refresh_tuner_capacity()

    def _refresh_tuner_capacity(self) -> None:
        if self._adopted or self._cotuned \
                or not hasattr(self.tuner, "set_capacity_fractions"):
            return
        caps = (self.quota - self.reserved).astype(np.float64)
        allocatable = float(caps.sum())
        if allocatable <= 0:
            return
        frac = np.where(self.reserved > 0, caps / allocatable, np.inf)
        self.tuner.set_capacity_fractions(frac)

    def offer_slots(self, provider) -> None:
        self.fabric.offer_slots(self, provider)

    def withdraw_slots(self) -> None:
        self.fabric.withdraw_slots(self)

    def settle_loans(self) -> None:
        self.fabric.settle_loans(self)

    def borrowable(self) -> int:
        return self.fabric.borrowable(self)

    def request_loan(self, n: int) -> int:
        return self.fabric.request_loan(self, n)

    def recallable(self) -> int:
        return self.fabric.recallable(self)

    def recall_loans(self, need: int) -> tuple[int, float]:
        return self.fabric.recall_loans(self, need)

    # -- movement ----------------------------------------------------------------

    @property
    def weights(self) -> np.ndarray:
        if self._adopted:
            return self.pool.weights
        return self._policy.weights(self._ctx(float(self.dwp)))

    def _ctx(self, dwp: float) -> placement_policy.PlacementContext:
        pool = self.pool
        return placement_policy.PlacementContext(
            bandwidths=np.asarray([d.read_bw for d in pool.domains]),
            num_pages=int(self.quota.sum()),
            workers=self.home,
            dwp=dwp,
            capacities=(self.quota - self.reserved).astype(np.float64))

    def migrate(self, pages: list[int]) -> list[int]:
        """Re-place a sequence's pages per this view's current weights
        (§III-B2 incremental migration): shared pages are pinned, copies
        batch through the executor, table references and view holds follow
        the bytes, and (non-adopted) destination choice respects the
        view's quota headroom."""
        pool = self.pool
        if self._adopted:
            new_ids = pool.migrate_sequence(pages, table=self.table)
        else:
            target = interleave.weighted_interleave(len(pages), self.weights)
            new_ids, src, dst = [], [], []
            for pid, dom in zip(pages, target):
                dom = int(dom)
                cur = pool.domain_of(pid)
                if self.table.shared(pid) or cur == dom \
                        or not pool.free[dom] or self._headroom(dom) <= 0:
                    new_ids.append(int(pid))
                    continue
                nid = pool.free[dom].pop()
                src.append(int(pid))
                dst.append(nid)
                new_ids.append(nid)
            if src:
                (pool.k_pool, pool.v_pool), _ = pool.executor.execute(
                    (pool.k_pool, pool.v_pool), src, dst,
                    src_domains=[pool.domain_of(p) for p in src],
                    dst_domains=[pool.domain_of(p) for p in dst])
                for s, d in zip(src, dst):
                    if s in self.table.ref:
                        self.table.remap_physical(s, d)
                    pool.free[pool.domain_of(s)].append(s)
        for old, new in zip(pages, new_ids):
            if old != new:
                self._ledger_remap(old, new)
                self.fabric.emit("migrate", view=self.name, src=old,
                                 dst=new)
        return new_ids

    def _ledger_remap(self, old: int, new: int) -> None:
        """Ownership + holds follow a moved page (same view, new id)."""
        fab = self.fabric
        name = fab.owner.pop(old, None)
        if name is not None and name in fab.views:
            v = fab.views[name]
            v.used[self.pool.domain_of(old)] -= 1
            v.used[self.pool.domain_of(new)] += 1
            fab.owner[new] = name
        for v in fab.views.values():
            v._on_remap(old, new)

    # -- heat-driven re-homing (DESIGN.md §11) ---------------------------------

    def rehome_candidates(self, heat, *, min_heat: float = 1e-6
                          ) -> list[tuple[int, float, float]]:
        """Hot shared pages worth pulling into this view's fast domains.

        ``migrate`` pins shared pages (moving one holder's copy would
        strand the others), so a hot prefix allocated while the fast
        domains were full stays in a slow domain forever — the exact
        pages whose Eq.-1 read cost every sharer pays every step.
        Re-homing lifts them with an *all-holders* remap instead.

        Candidates are live pages owned by this view with refcount>1,
        resident outside the home (fast) set, with resolved ``heat``
        above ``min_heat``. Returns ``(pid, heat, heat * save_s)`` sorted
        by expected near-future saving (descending): heat is a decayed
        read count, so it is the natural estimate of how many more times
        the page will be read; ``save_s`` is the per-read Eq.-1 saving of
        serving it from the fastest home domain instead."""
        pool = self.pool
        fast = set(self.home)
        bw = self.fabric.bw_effective
        pb = float(self.page_bytes)
        best = max(fast, key=lambda d: bw[d])
        out = []
        for pid in self.table.ref:
            if self.fabric.owner.get(pid) != self.name:
                continue                     # parked, persisted, or foreign
            if not self.table.shared(pid):
                continue
            src = pool.domain_of(pid)
            if src in fast:
                continue
            save = (pb / (bw[src] * 1e9)) - (pb / (bw[best] * 1e9))
            if save <= 0:
                continue
            h = float(heat.value(pid))
            if h <= min_heat:
                continue
            out.append((pid, h, h * save))
        out.sort(key=lambda t: (-t[2], t[0]))
        return out

    def rehome_hot(self, heat, *, budget_s: float,
                   max_pages: int | None = None
                   ) -> tuple[dict[int, int], float]:
        """Migrate the most profitable ``rehome_candidates`` into home
        domains under an Eq.-1 move budget.

        Selection walks candidates best-first, pricing the growing batch
        with :func:`bwmodel.move_cost` (reads overlap across source
        domains; every byte funnels into the destination). A candidate is
        taken only if (a) the batch still fits ``budget_s`` and (b) its
        *marginal* cost is covered by its expected saving ``heat *
        save_s`` — so migration never exceeds the stall it saves.

        The move itself is one batched executor copy followed by the
        all-holders bookkeeping: ``table.remap_physical`` carries the
        refcount and trie node, ``_ledger_remap`` carries ownership and
        every view's holds, the vacated slow pages return to the shared
        allocator, and every view's ``on_page_remap`` subscribers receive
        the ``{old: new}`` map so schedulers can patch sequence page
        lists. Emits one ``migrate`` event per page. Returns ``(moves,
        seconds)``."""
        pool = self.pool
        bw = self.fabric.bw_effective
        pb = float(self.page_bytes)
        nd = len(pool.domains)
        fast_order = sorted(self.home, key=lambda d: -bw[d])
        moves: dict[int, int] = {}
        bytes_by_src = np.zeros(nd)
        cost = 0.0
        for pid, h, _rank in self.rehome_candidates(heat):
            if max_pages is not None and len(moves) >= max_pages:
                break
            dst_dom = next(
                (d for d in fast_order
                 if pool.free[d]
                 and (self._adopted or self._headroom(d) > 0)), None)
            if dst_dom is None:
                break                        # fast domains full: try later
            trial = bytes_by_src.copy()
            trial[pool.domain_of(pid)] += pb
            new_cost = bwmodel.move_cost(trial, bw, dst_dom)
            if new_cost > budget_s:
                break
            marginal = new_cost - cost
            save = (pb / (bw[pool.domain_of(pid)] * 1e9)
                    - pb / (bw[dst_dom] * 1e9))
            if h * save < marginal:
                continue                     # not worth the transfer
            moves[pid] = pool.free[dst_dom].pop()
            bytes_by_src = trial
            cost = new_cost
        if not moves:
            return {}, 0.0
        src = list(moves)
        dst = [moves[s] for s in src]
        self.execute_copy(src, dst)
        for s, d in zip(src, dst):
            self.table.remap_physical(s, d)
            self._ledger_remap(s, d)
            pool.free[pool.domain_of(s)].append(s)
            self.fabric.emit("migrate", view=self.name, src=s, dst=d)
        for v in self.fabric.views.values():
            for cb in v._page_remap_cbs:
                cb(dict(moves))
        return moves, cost

    def on_page_remap(self, cb: Callable) -> None:
        """Subscribe to all-holders re-homing: ``cb(moves)`` receives the
        ``{old_pid: new_pid}`` map after physical ids change under live
        sequences, so holders can patch their page lists."""
        self._page_remap_cbs.append(cb)

    def execute_copy(self, src: list[int], dst: list[int]) -> None:
        """Batched physical copy through the migration executor (swap
        transfers); ledger updates are the caller's via the park/unpark
        primitives."""
        pool = self.pool
        (pool.k_pool, pool.v_pool), _ = pool.executor.execute(
            (pool.k_pool, pool.v_pool), src, dst,
            src_domains=[pool.domain_of(p) for p in src],
            dst_domains=[pool.domain_of(p) for p in dst])

    def park_pages(self, movable: list[int], dst: list[int]) -> None:
        """Swap-out data move: copy live pages into reserved slots (one
        batched gather/scatter), drop their trie entries (a parked page
        must not be matched — its id changes again on swap-in), carry table
        refs and view holds onto the slots, end the live allocations, and
        return the vacated source pages to the shared allocator."""
        self.execute_copy(movable, dst)
        for s, d in zip(movable, dst):
            if s in self.table.ref:
                self.table.unregister(s)
                self.table.remap_physical(s, d)
            self.fabric._on_free(s)
            for v in self.fabric.views.values():
                v._on_remap(s, d)
        self.pool.free_pages(movable)

    def unpark_pages(self, parked: list[int]) -> list[int]:
        """Swap-in data move: allocate live destinations under this view's
        placement policy, copy the parked bytes over, and carry refs/holds/
        ownership onto the live pages. Returns the new ids (slot ids are
        the caller's to return to its reservation)."""
        dst = [self._alloc_physical() for _ in parked]
        self.execute_copy(parked, dst)
        for s, d in zip(parked, dst):
            if s in self.table.ref:
                self.table.remap_physical(s, d)
            self.fabric._own(self, d)
            for v in self.fabric.views.values():
                v._on_remap(s, d)
        return dst

    def repark_pages(self, src: list[int], dst: list[int]) -> None:
        """Loan-reclaim data move: parked bytes relocate between reserved
        slots (no live allocation on either side)."""
        self.execute_copy(src, dst)
        for s, d in zip(src, dst):
            if s in self.table.ref:
                self.table.remap_physical(s, d)
            for v in self.fabric.views.values():
                v._on_remap(s, d)

    # -- cost model ---------------------------------------------------------------

    def footprint(self, pages: Sequence[int]) -> np.ndarray:
        """Per-domain resident bytes of a page set (Eq.-1 input). Pages
        demoted to the persistent tier (negative handle ids) are not in any
        domain — ``tier_bytes`` accounts them."""
        out = np.zeros(len(self.pool.domains))
        pb = self.page_bytes
        for pid in pages:
            if pid >= 0:
                out[self.pool.domain_of(pid)] += pb
        return out

    def tier_bytes(self, pages: Sequence[int]) -> float:
        """Bytes of this page set resident in the persistent tier."""
        return float(self.page_bytes) * sum(1 for p in pages if p < 0)

    def stall_cost(self, pages: Sequence[int]) -> float:
        """Eq.-1 max-parallel-transfer read time of a page set under the
        *effective* (calibrated) bandwidths; demoted pages contribute the
        tier's bandwidth row."""
        tb = self.tier_bytes(pages)
        tier = self.fabric.persist
        return bwmodel.stall_cost(
            self.footprint(pages), self.fabric.bw_effective,
            tier_bytes=tb if tier is not None else 0.0,
            tier_bw_gbps=tier.bw_gbps if tier is not None else None)

    def stall_seconds(self, bytes_per_domain: np.ndarray) -> float:
        return bwmodel.stall_cost(bytes_per_domain,
                                  self.fabric.bw_effective)

    def expected_read_time(self, pages: Sequence[int]) -> float:
        """``stall_cost`` + per-domain stall telemetry (the engine's
        per-step latency signal)."""
        per_domain = self.footprint(pages)
        times = per_domain / (self.fabric.bw_effective * 1e9)
        for d, t in enumerate(times):
            self.telemetry.record_stall(d, float(t))
        return bwmodel.stall_cost(per_domain, self.fabric.bw_effective)

    # -- tuning --------------------------------------------------------------------

    @property
    def dwp(self) -> float:
        t = self.pool.tuner if self._adopted else self.tuner
        return float(t.dwp)

    def record_latency(self, seconds: float) -> bool:
        """Per-step latency sample: logs it, drives the view's own DWP
        tuner (co-tuned views are driven by the arbiter through
        ``drive_cotuner`` instead), returns True when the allocation cycle
        moved (callers then re-home live sequences)."""
        self.fabric.emit("latency", view=self.name, seconds=seconds)
        if self._adopted:
            return self.pool.record_latency(seconds)
        self.telemetry.record_latency(seconds)
        if self._cotuned:
            return False
        before = self.tuner.assignment.copy()
        self.tuner.record(seconds)
        return not np.array_equal(before, self.tuner.assignment)

    def drive_cotuner(self, stall_a: float, stall_b: float) -> bool:
        """Arbiter entry point for best-effort tenants: feed the two-stage
        co-scheduled search; on an allocation-cycle move, fire the view's
        assignment-change subscribers (the scheduler re-homes live
        sequences) and return True."""
        assert self._cotuned, "view has no co-scheduled tuner"
        before = self.tuner.assignment.copy()
        self.tuner.record(stall_a, stall_b)
        changed = not np.array_equal(before, self.tuner.assignment)
        if changed:
            for cb in self._assignment_cbs:
                cb()
        return changed

    def on_assignment_change(self, cb: Callable) -> None:
        self._assignment_cbs.append(cb)

    # -- data plane ------------------------------------------------------------------

    @property
    def k_pool(self):
        return self.pool.k_pool

    @k_pool.setter
    def k_pool(self, value):
        self.pool.k_pool = value

    @property
    def v_pool(self):
        return self.pool.v_pool

    @v_pool.setter
    def v_pool(self, value):
        self.pool.v_pool = value

    def write_token(self, layer_slot_kv: tuple, page_id: int, slot: int):
        self.pool.write_token(layer_slot_kv, page_id, slot)

    def write_decode_batch(self, layer: int, page_ids, slots, k, v):
        self.pool.write_decode_batch(layer, page_ids, slots, k, v)

    # -- reporting --------------------------------------------------------------------

    def occupancy(self) -> dict[str, float]:
        if self._adopted:
            return self.pool.occupancy()
        out = {}
        for i, d in enumerate(self.pool.domains):
            cap = int(self.quota[i] - self.reserved[i])
            out[d.name] = int(self.used[i]) / max(cap, 1)
        return out

    def used_pages(self) -> np.ndarray:
        return self.pool.used_pages() if self._adopted \
            else self.used.copy()

    def attach_slo(self):
        return self.telemetry.attach_slo()

    def snapshot(self) -> dict:
        """Engine-facing telemetry: domain counters + page-table sharing
        state + loan ledger, one dict (replaces the old
        ``telemetry.attach_pagetable`` back-channel). Cross-tenant
        sharing counts live in ``fabric.stats()`` — computing them is an
        O(live pages) scan that does not belong on the per-step path."""
        tel = self.telemetry.snapshot()
        tel["pagetable"] = self.table.stats()
        tel["fabric"] = {
            "view": self.name,
            "loans": [ln.as_dict() for ln in self.fabric.loans],
        }
        return tel


def as_view(pool_or_view) -> FabricView:
    """Normalize the serve/scheduler entry points: a FabricView passes
    through; a bare BwapPagePool is adopted into a cached single-view
    fabric (placement bit-identical to driving the pool directly)."""
    if isinstance(pool_or_view, FabricView):
        return pool_or_view
    view = getattr(pool_or_view, "_fabric_view", None)
    if view is None:
        view = MemoryFabric.adopt(pool_or_view).views["default"]
        pool_or_view._fabric_view = view
    return view
