"""Multi-tenant domain arbiter (paper §III-B3 as a runtime service).

Several co-located applications share one machine's memory domains. The
arbiter owns the capacity ledger: it partitions every domain's pages among
registered tenants, assigns each tenant a disjoint *home* (worker) domain by
priority (high-priority tenants claim the fastest unclaimed domain), builds
each tenant's :class:`BwapPagePool`, and rebalances capacity when tenants
join or leave (live pools are rebuilt through the batched migration
executor; engines get an id map to rewrite their page tables).

Best-effort tenants are tuned by the paper's two-stage
:class:`CoScheduledTuner`: stage 1 raises the tenant's DWP while the
high-priority tenants' latency stream keeps improving (pulling the tenant's
pages out of the high-priority home domains), freezing a lower bound when it
stabilises; stage 2 hill-climbs the tenant's own latency, never dropping
below the bound. ``observe()`` is the single entry point — feed it each
tenant's per-step latency and the arbiter routes the streams.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

import numpy as np

from repro.core import interleave
from repro.core.dwp import CoScheduledTuner, DWPConfig
from repro.placement import policy as placement_policy
from repro.placement.telemetry import DomainTelemetry, Ring
from repro.serve.kvcache import BwapPagePool, MemoryDomain


class Priority(enum.Enum):
    HIGH = "high"
    BEST_EFFORT = "best_effort"


@dataclasses.dataclass(frozen=True)
class DomainSpec:
    """One physical memory domain managed by the arbiter."""

    name: str
    total_pages: int
    read_bw: float       # GB/s toward the worker chips


@dataclasses.dataclass
class Tenant:
    name: str
    priority: Priority
    share: float
    quotas: np.ndarray                 # pages per domain owned by this tenant
    home: tuple[int, ...]              # worker-domain indices
    pool: BwapPagePool
    cotuner: CoScheduledTuner | None = None
    engine: object | None = None       # anything with .remap_pages/.active
    latency: Ring = dataclasses.field(default_factory=lambda: Ring(64))

    @property
    def dwp(self) -> float:
        return float(self.pool.tuner.dwp)


class DomainArbiter:
    """Capacity ledger + tuner router for N tenants over shared domains."""

    def __init__(self, specs: Sequence[DomainSpec], page_size: int = 8,
                 seed: int = 0):
        self.specs = list(specs)
        self.page_size = page_size
        self.seed = seed
        self.free = np.asarray([s.total_pages for s in self.specs],
                               dtype=np.int64)
        self.bw = np.asarray([s.read_bw for s in self.specs])
        self.tenants: dict[str, Tenant] = {}
        self._claimed_homes: set[int] = set()

    # -- registration --------------------------------------------------------

    def _pick_home(self, priority: Priority) -> int:
        """Fastest domain not yet claimed as another tenant's home; HIGH
        tenants pick before best-effort ones simply by registering first."""
        for d in np.argsort(-self.bw, kind="stable"):
            if int(d) not in self._claimed_homes:
                return int(d)
        raise RuntimeError("more tenants than domains: no free home domain")

    def register(self, name: str, cfg, *, priority: Priority,
                 share: float, dwp_config: DWPConfig | None = None) -> Tenant:
        """Carve ``share`` of every domain's remaining pages for a new
        tenant and build its pool (and co-scheduled tuner if best-effort)."""
        assert name not in self.tenants, f"tenant {name!r} already registered"
        assert 0.0 < share <= 1.0
        totals = np.asarray([s.total_pages for s in self.specs])
        quotas = np.minimum(np.floor(totals * share).astype(np.int64),
                            self.free)
        if quotas.sum() == 0:
            raise RuntimeError("no capacity left for tenant " + name)
        home = self._pick_home(priority)
        self._claimed_homes.add(home)
        domains = [MemoryDomain(s.name, int(q), s.read_bw, i == home)
                   for i, (s, q) in enumerate(zip(self.specs, quotas))]
        telemetry = DomainTelemetry([d.name for d in domains])
        cotuner = None
        if priority is Priority.BEST_EFFORT:
            canonical = interleave.normalize(self.bw)
            cotuner = CoScheduledTuner(
                canonical, [home], num_pages=4096,
                config=dwp_config or DWPConfig(n=4, c=1,
                                               rel_tolerance=0.02),
                on_migrate=lambda plan: telemetry.record_plan(plan.num_moves))
        pool = BwapPagePool(cfg, domains, page_size=self.page_size,
                            dwp_config=dwp_config, seed=self.seed,
                            tuner=cotuner, telemetry=telemetry)
        tenant = Tenant(name=name, priority=priority, share=share,
                        quotas=quotas, home=(home,), pool=pool,
                        cotuner=cotuner)
        self.free -= quotas
        self.tenants[name] = tenant
        return tenant

    #: tenant priority -> scheduler class level (HIGH preempts best-effort)
    PRIORITY_LEVELS = {Priority.HIGH: 10, Priority.BEST_EFFORT: 0}

    def attach_engine(self, name: str, engine) -> None:
        """Wire a tenant's serving engine in. When the engine runs a request
        scheduler, the tenant is registered as a priority class at the level
        of its arbiter priority and becomes the engine's default class — so
        multi-tenant co-scheduling (capacity + DWP) and per-tenant
        preemption (batch slots + KV swap) compose end-to-end."""
        t = self.tenants[name]
        t.engine = engine
        sched = getattr(engine, "scheduler", None)
        if sched is not None:
            from repro.scheduler.scheduler import PriorityClass
            from repro.scheduler.slo import SloSpec
            existing = sched.classes.get(name)
            sched.ensure_class(PriorityClass(
                name=name, level=self.PRIORITY_LEVELS[t.priority],
                # arbiter owns the level; SLO deadlines stay whatever the
                # operator configured on the scheduler (if anything)
                slo=existing.slo if existing is not None else SloSpec()))
            sched.default_class = name

    def unregister(self, name: str) -> dict[str, np.ndarray]:
        """Release a tenant's capacity and grow the remaining tenants' pools
        proportionally to their shares (live pages carried over via one
        batched copy per pool; attached engines get their tables remapped).
        Returns the per-tenant page grants."""
        gone = self.tenants.pop(name)
        self._claimed_homes.discard(gone.home[0])
        self.free += gone.quotas
        grants: dict[str, np.ndarray] = {}
        rest = list(self.tenants.values())
        if not rest:
            return grants
        total_share = sum(t.share for t in rest)
        remaining = gone.quotas.copy()
        for i, t in enumerate(rest):
            if i == len(rest) - 1:                    # remainder to the last
                grant = remaining.copy()
            else:
                grant = np.minimum(
                    np.floor(gone.quotas * (t.share / total_share)).astype(
                        np.int64),
                    remaining)
            remaining -= grant
            id_map = t.pool.rebalance(t.quotas + grant)
            if t.engine is not None:
                t.engine.remap_pages(id_map)
            t.quotas = t.quotas + grant
            self.free -= grant
            grants[t.name] = grant
        return grants

    # -- tuning --------------------------------------------------------------

    def observe(self, name: str, latency: float) -> bool:
        """Feed one tenant's per-step latency sample. For best-effort
        tenants this drives the two-stage co-scheduled search: stall_a is
        the freshest high-priority latency, stall_b the tenant's own. When
        the tuner moves the allocation cycle, live sequences of an attached
        engine are migrated (batched) and True is returned."""
        t = self.tenants[name]
        t.latency.push(latency)
        # (not pushed into pool telemetry: the engine already records its
        # wall+sim latency there; mixing in this analytic stream would
        # average incommensurate quantities)
        if t.priority is not Priority.BEST_EFFORT or t.cotuner is None:
            return False
        high = [o.latency.last() for o in self.tenants.values()
                if o.priority is Priority.HIGH and len(o.latency)]
        stall_a = float(np.mean(high)) if high else 0.0
        before = t.cotuner.assignment.copy()
        t.cotuner.record(stall_a, latency)
        changed = not np.array_equal(before, t.cotuner.assignment)
        if changed and t.engine is not None:
            for s in getattr(t.engine, "active", []):
                s.pages = t.pool.migrate_sequence(s.pages)
        return changed

    # -- interference model --------------------------------------------------

    def interference(self, name: str, scale: float = 1.0) -> float:
        """Analytic cross-tenant contention on ``name``'s home domains
        (Eq.-1 shape): other tenants' resident bytes there, divided by the
        domain bandwidth. The CPU host has no real memory domains, so this
        term supplies the co-location signal the paper reads from stall
        counters — same role as the engine's expected_read_time."""
        t = self.tenants[name]
        total = 0.0
        for d in t.home:
            for o in self.tenants.values():
                if o.name == name:
                    continue
                pages = int(o.pool.used_pages()[d])
                total += pages * o.pool.page_bytes / (self.bw[d] * 1e9)
        return scale * total

    # -- reporting ------------------------------------------------------------

    def stats(self) -> dict:
        out = {}
        for t in self.tenants.values():
            entry = {
                "priority": t.priority.value,
                "home": [self.specs[d].name for d in t.home],
                "quota_pages": int(t.quotas.sum()),
                "dwp": t.dwp,
                "latency_mean_s": t.latency.mean(),
                "occupancy": t.pool.occupancy(),
            }
            if t.cotuner is not None:
                entry["stage"] = t.cotuner.stage
                entry["dwp_lower_bound"] = t.cotuner.dwp_lower_bound
            out[t.name] = entry
        return out
