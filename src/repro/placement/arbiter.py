"""Multi-tenant domain arbiter (paper §III-B3 as a runtime service).

Several co-located applications share one machine's memory domains. The
arbiter is the *policy brain* over one :class:`~repro.placement.fabric.
MemoryFabric`: it partitions every domain's pages among registered tenants
as fabric-view quotas, assigns each tenant a disjoint *home* (worker) domain
by priority (high-priority tenants claim the fastest unclaimed domain), and
redistributes quota when tenants join or leave — pure ledger arithmetic on
the shared pool, no array rebuilds, no page-id remapping (the rebalance
copies and ``attach_engine`` back-channels of the pre-fabric design are
gone; engines find their priority class and co-tuning through their view).

Because every tenant's view shares the fabric's physical pool and prefix
trie, the arbiter also brokers the two cross-tenant resources the fabric
exists for: the **read-only prefix tier** (same-model tenants opt in via
``share_prefix`` and their prompt pages physically dedupe across views) and
**swap-slot loans** (idle reservations of one tenant absorb another's burst
through the fabric's loan ledger).

Best-effort tenants are tuned by the paper's two-stage
:class:`CoScheduledTuner`: stage 1 raises the tenant's DWP while the
high-priority tenants' latency stream keeps improving (pulling the tenant's
pages out of the high-priority home domains), freezing a lower bound when it
stabilises; stage 2 hill-climbs the tenant's own latency, never dropping
below the bound. ``observe()`` is the single entry point — feed it each
tenant's per-step latency and the arbiter routes the streams; cycle moves
re-home live sequences through the view's assignment-change subscription
(the scheduler registers itself — the dependency points serve → placement,
never the reverse).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

import numpy as np

from repro.core import interleave
from repro.core.dwp import CoScheduledTuner, DWPConfig
from repro.placement.fabric import FabricView, MemoryFabric
from repro.placement.pool import MemoryDomain
from repro.placement.telemetry import Ring


class Priority(enum.Enum):
    HIGH = "high"
    BEST_EFFORT = "best_effort"


@dataclasses.dataclass(frozen=True)
class DomainSpec:
    """One physical memory domain managed by the arbiter."""

    name: str
    total_pages: int
    read_bw: float       # GB/s toward the worker chips


@dataclasses.dataclass
class Tenant:
    name: str
    priority: Priority
    share: float
    quotas: np.ndarray                 # pages per domain owned by this tenant
    home: tuple[int, ...]              # worker-domain indices
    view: FabricView
    cotuner: CoScheduledTuner | None = None
    latency: Ring = dataclasses.field(default_factory=lambda: Ring(64))

    @property
    def dwp(self) -> float:
        return self.view.dwp


class DomainArbiter:
    """Quota ledger + tuner router for N tenants over one shared fabric."""

    def __init__(self, specs: Sequence[DomainSpec], page_size: int = 8,
                 seed: int = 0):
        self.specs = list(specs)
        self.page_size = page_size
        self.seed = seed
        self.free = np.asarray([s.total_pages for s in self.specs],
                               dtype=np.int64)
        self.bw = np.asarray([s.read_bw for s in self.specs])
        self.tenants: dict[str, Tenant] = {}
        self._claimed_homes: set[int] = set()
        self.fabric: MemoryFabric | None = None
        self._cfg = None

    # -- registration --------------------------------------------------------

    def _pick_home(self, priority: Priority) -> int:
        """Fastest domain not yet claimed as another tenant's home; HIGH
        tenants pick before best-effort ones simply by registering first."""
        for d in np.argsort(-self.bw, kind="stable"):
            if int(d) not in self._claimed_homes:
                return int(d)
        raise RuntimeError("more tenants than domains: no free home domain")

    def _ensure_fabric(self, cfg) -> MemoryFabric:
        if self.fabric is None:
            fastest = int(np.argmax(self.bw))
            domains = [MemoryDomain(s.name, s.total_pages, s.read_bw,
                                    i == fastest)
                       for i, s in enumerate(self.specs)]
            self.fabric = MemoryFabric(cfg, domains,
                                       page_size=self.page_size,
                                       seed=self.seed)
            self._cfg = cfg
        else:
            assert cfg is self._cfg or cfg == self._cfg, (
                "one fabric serves one model group: tenants of a different "
                "model need their own fabric — physical page sharing "
                "requires identical page geometry. Co-locate heterogeneous "
                "groups through placement.zoo.PageFabricZoo, whose capacity "
                "market trades funding between per-group fabrics in bytes "
                "(DESIGN.md §12)")
        return self.fabric

    #: tenant priority -> scheduler class level (HIGH preempts best-effort)
    PRIORITY_LEVELS = {Priority.HIGH: 10, Priority.BEST_EFFORT: 0}

    def register(self, name: str, cfg, *, priority: Priority,
                 share: float, dwp_config: DWPConfig | None = None,
                 share_prefix: bool = True) -> Tenant:
        """Carve ``share`` of every domain's remaining pages as a new
        tenant's view quota (and build its co-scheduled tuner if
        best-effort). ``share_prefix=False`` keeps the tenant out of the
        cross-tenant read-only prefix tier."""
        assert name not in self.tenants, f"tenant {name!r} already registered"
        assert 0.0 < share <= 1.0
        fabric = self._ensure_fabric(cfg)
        totals = np.asarray([s.total_pages for s in self.specs])
        quotas = np.minimum(np.floor(totals * share).astype(np.int64),
                            self.free)
        if quotas.sum() == 0:
            raise RuntimeError("no capacity left for tenant " + name)
        home = self._pick_home(priority)
        self._claimed_homes.add(home)
        cotuner = None
        if priority is Priority.BEST_EFFORT:
            canonical = interleave.normalize(self.bw)
            cotuner = CoScheduledTuner(
                canonical, [home], num_pages=4096,
                config=dwp_config or DWPConfig(n=4, c=1,
                                               rel_tolerance=0.02),
                on_migrate=lambda plan: fabric.telemetry.record_plan(
                    plan.num_moves))
        view = fabric.view(name, quota=quotas, home=(home,),
                           level=self.PRIORITY_LEVELS[priority],
                           share_prefix=share_prefix, tuner=cotuner,
                           dwp_config=dwp_config)
        tenant = Tenant(name=name, priority=priority, share=share,
                        quotas=quotas, home=(home,), view=view,
                        cotuner=cotuner)
        self.free -= quotas
        self.tenants[name] = tenant
        return tenant

    def unregister(self, name: str) -> dict[str, np.ndarray]:
        """Release a tenant's quota and grow the remaining tenants'
        views proportionally to their shares. Pure ledger arithmetic on
        the shared pool: no live page moves, no id remapping — pages the
        leaving tenant shared into the prefix tier survive under their
        surviving holders. Returns the per-tenant page grants."""
        gone = self.tenants.pop(name)
        self._claimed_homes.discard(gone.home[0])
        released = self.fabric.unregister(name)
        self.free += released
        grants: dict[str, np.ndarray] = {}
        rest = list(self.tenants.values())
        if not rest:
            return grants
        total_share = sum(t.share for t in rest)
        remaining = released.copy()
        for i, t in enumerate(rest):
            if i == len(rest) - 1:                    # remainder to the last
                grant = remaining.copy()
            else:
                grant = np.minimum(
                    np.floor(released * (t.share / total_share)).astype(
                        np.int64),
                    remaining)
            remaining -= grant
            t.view.quota += grant
            t.quotas = t.quotas + grant
            self.free -= grant
            grants[t.name] = grant
        return grants

    # -- tuning --------------------------------------------------------------

    def observe(self, name: str, latency: float) -> bool:
        """Feed one tenant's per-step latency sample. For best-effort
        tenants this drives the two-stage co-scheduled search: stall_a is
        the freshest high-priority latency, stall_b the tenant's own. When
        the tuner moves the allocation cycle, the view's assignment-change
        subscribers (the tenant's scheduler) re-home live sequences and
        True is returned."""
        t = self.tenants[name]
        t.latency.push(latency)
        # (not pushed into fabric telemetry: the engine already records its
        # wall+sim latency there; mixing in this analytic stream would
        # average incommensurate quantities)
        if t.priority is not Priority.BEST_EFFORT or t.cotuner is None:
            return False
        high = [o.latency.last() for o in self.tenants.values()
                if o.priority is Priority.HIGH and len(o.latency)]
        stall_a = float(np.mean(high)) if high else 0.0
        return t.view.drive_cotuner(stall_a, latency)

    # -- interference model --------------------------------------------------

    def interference(self, name: str, scale: float = 1.0) -> float:
        """Analytic cross-tenant contention on ``name``'s home domains
        (Eq.-1 shape): other tenants' resident bytes there, divided by the
        domain bandwidth. The CPU host has no real memory domains, so this
        term supplies the co-location signal the paper reads from stall
        counters — same role as the engine's expected_read_time."""
        t = self.tenants[name]
        total = 0.0
        for d in t.home:
            for o in self.tenants.values():
                if o.name == name:
                    continue
                pages = int(o.view.used_pages()[d])
                total += pages * o.view.page_bytes / (self.bw[d] * 1e9)
        return scale * total

    # -- persistence-tier pin selection (DESIGN.md §13) ------------------------

    def pin_hot_preambles(self, *, top_k: int = 2, min_ref: int = 2) -> list:
        """Pin the globally hottest shared preambles into the persistence
        tier. Candidates are maximal trie chains whose pages are shared
        across tenants (refcount ≥ ``min_ref``) or already pinned; each is
        scored by Σ refcount × (1 + observatory heat) over its pages — the
        cross-tenant demand signal the arbiter alone can see. The ``top_k``
        winners are pinned (re-pinning refreshes the LRU stamp, so a
        preamble that stays hot never ages into eviction); losers keep any
        existing pin and age naturally. Returns the pin keys touched."""
        fabric = self.fabric
        assert fabric is not None and fabric.persist is not None, \
            "pin selection needs a fabric with an attached persistence tier"
        tier = fabric.persist
        table = fabric.table
        heat = fabric.obs.heat if fabric.obs is not None else None
        already = tier.pinned_pages()
        chains = table.export_chains(
            select=lambda pid: table.ref.get(pid, 0) >= min_ref
            or pid in already)
        scored = []
        for ch in chains:
            owner = fabric.owner.get(ch["phys"][0])
            if owner is None:
                continue
            score = sum(
                table.ref.get(p, 0)
                * (1.0 + (heat.value(p) if heat is not None else 0.0))
                for p in ch["phys"])
            scored.append((-score, owner, tuple(ch["tokens"]), ch))
        scored.sort(key=lambda t: t[:3])
        keys = []
        for _neg, owner, _toks, ch in scored[:top_k]:
            key = tier.pin(fabric.views[owner], ch["tokens"])
            if key is not None:
                keys.append(key)
        return keys

    # -- cross-tenant loans (delegated to the fabric broker) ------------------

    def loan_stats(self) -> list[dict]:
        if self.fabric is None:
            return []
        return self.fabric.stats()["loans"]

    # -- reporting ------------------------------------------------------------

    def stats(self) -> dict:
        out = {}
        for t in self.tenants.values():
            entry = {
                "priority": t.priority.value,
                "home": [self.specs[d].name for d in t.home],
                "quota_pages": int(t.quotas.sum()),
                "dwp": t.dwp,
                "latency_mean_s": t.latency.mean(),
                "occupancy": t.view.occupancy(),
            }
            if t.cotuner is not None:
                entry["stage"] = t.cotuner.stage
                entry["dwp_lower_bound"] = t.cotuner.dwp_lower_bound
            out[t.name] = entry
        if self.fabric is not None:
            out["_fabric"] = {
                "cross_shared_pages": self.fabric.cross_shared_pages(),
                "loans": self.fabric.stats()["loans"],
            }
        return out
