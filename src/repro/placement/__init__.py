"""Unified placement runtime (DESIGN.md §3).

- ``policy``: registry of placement policies (uniform, bwap_canonical,
  bwap_dwp, local_first) behind one protocol.
- ``executor``: batched gather/scatter migration of page pools.
- ``arbiter``: multi-tenant partitioning + co-scheduled DWP tuning.
- ``telemetry``: per-domain counters and ring-buffer samples.
"""

from repro.placement import policy
from repro.placement.executor import MigrationExecutor, MigrationResult
from repro.placement.telemetry import DomainTelemetry, Ring

__all__ = [
    "policy",
    "MigrationExecutor",
    "MigrationResult",
    "DomainTelemetry",
    "Ring",
]
