"""Unified placement runtime (DESIGN.md §3, §8).

- ``fabric``: the memory-fabric API — one surface (``MemoryFabric`` +
  tenant-scoped ``FabricView``) owning domains, the physical pool, the
  logical page table, reservation/loan ledgers, and the placement event
  bus. The only placement API the serve/scheduler layers touch.
- ``pool``: the physical page pool (arrays, free lists, executor hooks).
- ``pagetable``: refcounted logical→physical views, prefix trie, CoW.
- ``policy``: registry of placement policies (uniform, bwap_canonical,
  bwap_dwp, local_first) behind one protocol.
- ``executor``: batched gather/scatter migration of page pools.
- ``arbiter``: multi-tenant quota partitioning + co-scheduled DWP tuning +
  cross-tenant loan/prefix brokering over one fabric.
- ``telemetry``: per-domain counters and ring-buffer samples.
"""

from repro.placement import policy
from repro.placement.executor import MigrationExecutor, MigrationResult
from repro.placement.fabric import (FabricView, MemoryFabric, SlotLoan,
                                    as_view)
from repro.placement.telemetry import DomainTelemetry, Ring

__all__ = [
    "policy",
    "MigrationExecutor",
    "MigrationResult",
    "DomainTelemetry",
    "Ring",
    "MemoryFabric",
    "FabricView",
    "SlotLoan",
    "as_view",
]
