"""Placement policy registry: one protocol, many weight distributions.

Every layer of the stack that spreads pages over memory domains — KV pages
(serve/kvcache), ZeRO optimizer shards (sharding/zero), checkpoint staging
buffers (checkpoint/ckpt) — used to hand-roll its own weighted-interleave
variant. They now all ask this registry for a :class:`PlacementPolicy` and
feed the resulting weights to Alg. 1 (core/interleave).

A policy maps a :class:`PlacementContext` (domain bandwidths, capacities,
worker set, DWP) to a normalized weight vector; ``counts``/``assign`` turn
that into capacity-respecting integer page counts and a page table.

Built-in policies (DESIGN.md §3.1):

==================  =========================================================
``uniform``         equal mass on every domain (mbind MPOL_INTERLEAVE)
``bwap_canonical``  w_d ∝ bw_d — the paper's Eq. 2 single-worker closed form
``bwap_dwp``        canonical scaled by data-to-worker proximity (§III-B1)
``local_first``     fill domains fastest-first up to capacity (first-touch /
                    HBM-spill analogue; the baseline BWAP beats)
``coda``            ``bwap_dwp`` placement + compute-follows-data execution:
                    per-domain micro-batch decode and heat-driven re-homing
                    of hot shared pages (DESIGN.md §11)
==================  =========================================================
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core import interleave


@dataclasses.dataclass(frozen=True)
class PlacementContext:
    """Everything a policy may look at when distributing pages.

    Attributes:
      bandwidths: (D,) per-domain read bandwidth toward the workers (GB/s).
      num_pages: number of pages being placed.
      workers: indices of worker-local domains (DWP shifts mass here).
      dwp: data-to-worker proximity in [0, 1]; ignored by DWP-free policies.
      capacities: optional (D,) per-domain page capacities. ``None`` means
        uncapped; policies that *require* capacities (local_first) treat
        ``None`` as infinite everywhere but the fastest domain still wins.
    """

    bandwidths: np.ndarray
    num_pages: int
    workers: tuple[int, ...] = (0,)
    dwp: float = 0.0
    capacities: np.ndarray | None = None

    def __post_init__(self):
        object.__setattr__(self, "bandwidths",
                           np.asarray(self.bandwidths, dtype=np.float64))
        if self.capacities is not None:
            object.__setattr__(self, "capacities",
                               np.asarray(self.capacities, dtype=np.int64))
        object.__setattr__(self, "workers", tuple(self.workers))

    @property
    def num_domains(self) -> int:
        return int(len(self.bandwidths))


class PlacementPolicy:
    """Base class: subclasses define ``weights``; ``counts`` derives
    capacity-clamped integer page counts from them."""

    name: str = "?"
    # execution-mode flags (DESIGN.md §11): a policy can ask the serving
    # stack to *place work*, not just pages. ``micro_batch`` makes the
    # scheduler partition each decode batch into per-domain launches;
    # ``rehome`` makes the engine migrate hot shared pages into fast
    # domains under an Eq.-1 budget. Placement-only policies leave both
    # off; scheduler/engine read them via ``FabricView.placement_policy``.
    micro_batch: bool = False
    rehome: bool = False

    def weights(self, ctx: PlacementContext) -> np.ndarray:
        raise NotImplementedError

    def counts(self, ctx: PlacementContext) -> np.ndarray:
        w = interleave.normalize(self.weights(ctx))
        target = np.floor(w * ctx.num_pages).astype(np.int64)
        # hand out rounding remainders by largest fractional part
        rem = ctx.num_pages - int(target.sum())
        if rem > 0:
            frac = w * ctx.num_pages - target
            for i in np.argsort(-frac)[:rem]:
                target[int(i)] += 1
        if ctx.capacities is None:
            return target
        return clamp_to_capacity(target, ctx.capacities, w)


_REGISTRY: dict[str, PlacementPolicy] = {}


def register(cls: type[PlacementPolicy]) -> type[PlacementPolicy]:
    """Class decorator: instantiate and index by ``cls.name``."""
    assert cls.name not in _REGISTRY, f"duplicate policy {cls.name!r}"
    _REGISTRY[cls.name] = cls()
    return cls


def get(name: str) -> PlacementPolicy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown placement policy {name!r}; "
                       f"available: {sorted(_REGISTRY)}") from None


def available() -> list[str]:
    return sorted(_REGISTRY)


def resolve(policy: str | PlacementPolicy) -> PlacementPolicy:
    return get(policy) if isinstance(policy, str) else policy


# ---------------------------------------------------------------------------
# capacity handling (shared by every policy — was private to sharding/zero)
# ---------------------------------------------------------------------------

def clamp_to_capacity(target: np.ndarray, capacities: np.ndarray,
                      spill_weights: np.ndarray) -> np.ndarray:
    """Clip per-domain page counts to capacity; overflow spills to domains
    with room, proportional to ``spill_weights`` (keeps Eq.-1 transfer times
    balanced under capacity pressure). Integer waterfill: terminates because
    every round places at least one page."""
    caps = np.asarray(capacities, dtype=np.int64)
    want = np.asarray(target, dtype=np.int64)
    total = int(want.sum())
    if total > int(caps.sum()):
        raise ValueError(f"placing {total} pages exceeds aggregate capacity "
                         f"{int(caps.sum())}")
    counts = np.minimum(want, caps)
    deficit = total - int(counts.sum())
    sw = np.asarray(spill_weights, dtype=np.float64)
    while deficit > 0:
        room = caps - counts
        w = np.where(room > 0, np.maximum(sw, 0.0), 0.0)
        if w.sum() <= 0:
            w = np.where(room > 0, 1.0, 0.0)
        give = np.minimum(room, np.floor(deficit * w / w.sum()).astype(
            np.int64))
        if give.sum() == 0:  # fractional shares all rounded to zero
            give = np.zeros_like(counts)
            give[int(np.argmax(np.where(room > 0, w, -1.0)))] = 1
        counts += give
        deficit -= int(give.sum())
    return counts


# ---------------------------------------------------------------------------
# built-in policies
# ---------------------------------------------------------------------------

@register
class Uniform(PlacementPolicy):
    """Equal weight on every domain — the MPOL_INTERLEAVE baseline."""

    name = "uniform"

    def weights(self, ctx: PlacementContext) -> np.ndarray:
        return np.full(ctx.num_domains, 1.0 / ctx.num_domains)


@register
class BwapCanonical(PlacementPolicy):
    """w_d ∝ bw_d (Eq. 2): equalizes per-domain transfer times when every
    worker reads through the same domain list (degenerate-NUMA TPU case)."""

    name = "bwap_canonical"

    def weights(self, ctx: PlacementContext) -> np.ndarray:
        return interleave.normalize(ctx.bandwidths)


@register
class BwapDwp(PlacementPolicy):
    """Canonical weights scaled by DWP (§III-B1): worker-domain mass grows
    from its canonical share (dwp=0) to 1.0 (dwp=1), preserving relative
    weights inside the worker / non-worker clusters (Observation 3)."""

    name = "bwap_dwp"

    def weights(self, ctx: PlacementContext) -> np.ndarray:
        canon = interleave.normalize(ctx.bandwidths)
        return interleave.dwp_weights(canon, list(ctx.workers), ctx.dwp)


@register
class LocalFirst(PlacementPolicy):
    """Fill the fastest domain to capacity, then spill to the next — the
    first-touch / HBM-until-full baseline the paper's placement beats."""

    name = "local_first"

    def weights(self, ctx: PlacementContext) -> np.ndarray:
        c = self.counts(ctx)
        return interleave.normalize(np.maximum(c, 1e-9))

    def counts(self, ctx: PlacementContext) -> np.ndarray:
        caps = (ctx.capacities if ctx.capacities is not None
                else np.full(ctx.num_domains, ctx.num_pages, dtype=np.int64))
        counts = np.zeros(ctx.num_domains, dtype=np.int64)
        left = ctx.num_pages
        for i in np.argsort(-ctx.bandwidths, kind="stable"):
            take = min(left, int(caps[int(i)]))
            counts[int(i)] = take
            left -= take
            if left <= 0:
                break
        if left > 0:
            raise ValueError("local_first: pages exceed aggregate capacity")
        return counts


@register
class Coda(BwapDwp):
    """Compute-follows-data (DESIGN.md §11): ``bwap_dwp`` page placement
    plus work placement — the scheduler partitions each decode step into
    per-domain micro-batches (step stall = max over per-launch Eq.-1
    bottlenecks instead of one global max) and the engine re-homes hot
    shared pages (refcount>1, ranked by observatory heat) into fast
    domains with an all-holders remap, budgeted so migration never
    exceeds the stall it saves."""

    name = "coda"
    micro_batch = True
    rehome = True


# ---------------------------------------------------------------------------
# page-table helpers
# ---------------------------------------------------------------------------

def assign(policy: str | PlacementPolicy, ctx: PlacementContext) -> np.ndarray:
    """Page table ``page -> domain`` honouring the policy's counts (Alg. 1
    interleaves by the count vector, so fractions match exactly even after
    capacity clamping)."""
    c = resolve(policy).counts(ctx)
    return interleave.weighted_interleave(ctx.num_pages,
                                          np.maximum(c, 0) + 1e-9)


def weights(policy: str | PlacementPolicy,
            ctx: PlacementContext) -> np.ndarray:
    return interleave.normalize(resolve(policy).weights(ctx))
