"""Persistent tier: the third placement tier below swap slots (DESIGN.md §9).

"Dynamic Page Placement on Real Persistent Memory Systems" makes the case
that PMEM-class storage is best treated as one more *placement* tier with
its own bandwidth row in the cost model — not as a disk behind the runtime.
:class:`PersistentTier` is that tier for the memory fabric: the
:class:`~repro.placement.fabric.MemoryFabric` owns exactly one, below the
fast domains and the reserved swap slots. Three capabilities:

- **Eq.-1 cold demotion** — parked KV pages demote out of reserved swap
  slots into the tier, freeing the slot for hotter evictions. A demoted
  page keeps its table reference and view holds under a *handle id*
  (negative, starting at ``-2`` — ``pagetable.ROOT`` is ``-1``), so the
  swap forwarding map chases straight through the tier and ``swap_in``
  promotes the bytes back bit-exactly. Every demote/promote transfer is
  priced by :func:`repro.core.bwmodel.stall_cost` with the tier's
  bandwidth appended as one extra Eq.-1 row.
- **Restart-surviving prefix store** — pinned hot prefixes (popular system
  prompts) and refcount>1 trie chains are exported with their chain keys
  and K/V bytes, using the checkpoint subsystem's idioms: staging plans
  (:func:`repro.checkpoint.ckpt.plan_staging` at KV-page granularity),
  sha256 per array, atomic directory publish, and never-abort advisory
  semantics. A freshly constructed fabric re-imports them so the first
  request after an engine restart hits the trie instead of re-prefilling.
- **Peer page export/import** — a fabric serializes a page range (table
  slice, physical bytes, ledger charges) and a peer fabric adopts it; the
  layout metadata is stamped from ``launch/mesh`` axes and
  ``sharding/specs``' KV-pool partition spec so an importer can check the
  bytes were produced under a compatible sharding. This is the scale-out
  primitive: prefill/decode disaggregation is "export the prefix range to
  the decode fabric".

The tier emits ``demote`` / ``promote`` / ``restore`` on the fabric event
bus; the fabric routes them into :class:`DomainTelemetry` tier counters and
refreshes the per-tier occupancy gauges.
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import pathlib
import shutil
from typing import Sequence

import numpy as np

from repro.core import bwmodel
from repro.checkpoint.ckpt import StagingTier, plan_staging, publish_dir

FIRST_HANDLE = -2          # pagetable.ROOT == -1; handles count down from -2


def _sha256(b: bytes) -> str:
    return hashlib.sha256(b).hexdigest()


@dataclasses.dataclass(frozen=True)
class MeshMeta:
    """Mesh geometry without device state: enough for ``dp_axes`` /
    ``mp_axis`` / spec fitting, so export metadata never needs a live
    device mesh (an exporting serve host may not even run jax on the
    accelerator that produced the layout)."""

    shape: dict
    axis_names: tuple


def kv_layout_metadata(cfg, page_size: int, mesh=None) -> dict:
    """Layout stamp for a page-range export: mesh axes + the KV-pool
    partition spec the bytes were produced under."""
    from repro.launch import mesh as mesh_lib
    from repro.sharding import specs
    m = mesh if mesh is not None else MeshMeta(
        shape={"data": 4, "model": 2}, axis_names=("data", "model"))
    pspec = specs.kv_pool_spec(cfg, m, page_size)
    return {
        "mesh_axes": {a: int(m.shape[a]) for a in m.axis_names},
        "dp_axes": list(mesh_lib.dp_axes(m)),
        "mp_axis": mesh_lib.mp_axis(m),
        "kv_pool_spec": [e if e is None or isinstance(e, str) else list(e)
                         for e in pspec],
    }


@dataclasses.dataclass
class _Persisted:
    """One demoted page's bytes, held outside the pool arrays."""

    k: np.ndarray              # [L, page_size, nkv, hd]
    v: np.ndarray
    owner: str                 # view whose ledger carries the page


class PersistentTier:
    """Third placement tier of one memory fabric.

    ``bw_gbps`` is the tier's Eq.-1 bandwidth row; ``capacity_pages`` its
    demotion capacity; ``directory`` (optional) backs the prefix store on
    disk — without it the store lives in memory, which still survives a
    fabric teardown/rebuild (the tier object outlives the fabric) and is
    what the hermetic tests use.
    """

    def __init__(self, *, bw_gbps: float = 1.0, capacity_pages: int = 1024,
                 directory: str | pathlib.Path | None = None,
                 name: str = "pmem",
                 staging_tiers: list[StagingTier] | None = None,
                 staging_policy: str = "bwap_canonical"):
        assert bw_gbps > 0 and capacity_pages >= 0
        self.name = name
        self.bw_gbps = float(bw_gbps)
        self.capacity_pages = int(capacity_pages)
        self.directory = pathlib.Path(directory) if directory else None
        self.staging_tiers = staging_tiers
        self.staging_policy = staging_policy
        self.fabric = None
        self._entries: dict[int, _Persisted] = {}
        self._next = FIRST_HANDLE
        # prefix-store pin registry:
        # (view, tokens) -> {"view","tokens","pages","stamp"}; ``stamp`` is
        # a monotonic use-clock driving LRU eviction at the store's byte cap
        self._pins: dict[tuple, dict] = {}
        self._pin_clock = 0
        self.evicted_chains = 0          # LRU evictions at the byte cap
        self.skipped_chains = 0          # unpinned chains dropped over-cap
        self._mem_store: dict | None = None      # in-memory prefix store

    def bind(self, fabric) -> None:
        """Called by ``MemoryFabric.attach_persist`` — the fabric owns the
        tier; the tier never outlives its binding silently (rebinding after
        a teardown is exactly the restart path). Pins are runtime holds on
        the *previous* fabric's pages, so a rebind drops them — the durable
        prefix store is what survives. Demoted pages must have promoted or
        died before the old fabric went away; carrying their handles across
        a rebind would strand untracked bytes."""
        if fabric is not self.fabric:
            assert not self._entries, \
                "rebinding a tier with demoted pages still outstanding"
            self._pins.clear()
        self.fabric = fabric

    # -- accounting ----------------------------------------------------------

    def persisted_ids(self):
        return set(self._entries)

    def used_pages(self) -> int:
        return len(self._entries)

    def capacity_left(self) -> int:
        return self.capacity_pages - len(self._entries)

    def per_view_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for e in self._entries.values():
            out[e.owner] = out.get(e.owner, 0) + 1
        return out

    def stats(self) -> dict:
        return {
            "name": self.name,
            "bw_gbps": self.bw_gbps,
            "used_pages": self.used_pages(),
            "capacity_pages": self.capacity_pages,
            "pins": len(self._pins),
            "evicted_chains": self.evicted_chains,
            "skipped_chains": self.skipped_chains,
            "per_view": self.per_view_counts(),
        }

    def _geometry(self, pool) -> dict:
        cfg = pool.cfg
        g = pool.geometry
        return {
            "num_layers": int(g.num_layers),
            "page_size": int(pool.page_size),
            "num_kv_heads": int(cfg.num_kv_heads),
            "head_dim": int(cfg.head_dim_),
            "dtype": str(np.asarray(pool.k_pool).dtype),
            # geometry-polymorphic facts (DESIGN.md §12): the conversion
            # layer (cluster/convert.py) re-chunks across page_size when
            # kind and block tails agree, and refuses otherwise
            "kind": g.kind,
            "k_block": [int(x) for x in g.k_block],
            "v_block": [int(x) for x in g.v_block],
        }

    def _staging_plan(self, pool, nbytes: list[int]) -> dict:
        """Advisory staging plan at KV-page granularity — an unplaceable
        demand must never abort the export itself (ckpt semantics)."""
        tiers = self.staging_tiers or [StagingTier(
            self.name, self.bw_gbps,
            max(1, self.capacity_pages) * pool.page_bytes)]
        try:
            return plan_staging(nbytes, tiers, self.staging_policy,
                                page_bytes=pool.page_bytes)
        except ValueError as e:
            return {"policy": self.staging_policy, "error": str(e)}

    def _tier_seconds(self, view, live_pages: Sequence[int]) -> float:
        """Eq.-1 price of moving ``live_pages``'s bytes between their
        domains and this tier: the domain side and the tier row overlap,
        the stall is the slower of the two — the tier is just one more
        domain in Eq. 1."""
        pool = view.pool
        pb = pool.page_bytes
        per_domain = np.bincount([pool.domain_of(p) for p in live_pages],
                                 minlength=len(pool.domains)) * float(pb)
        return bwmodel.stall_cost(per_domain, self.fabric.bw_effective,
                                  tier_bytes=len(live_pages) * float(pb),
                                  tier_bw_gbps=self.bw_gbps)

    # -- Eq.-1 cold demotion ---------------------------------------------------

    def demote(self, view, slot_ids: Sequence[int]) -> tuple[list[int], float]:
        """Move parked pages' bytes out of reserved swap slots into the
        tier. Table references and view holds carry over onto fresh handle
        ids (``remap_physical`` + per-view ``_on_remap``, the same contract
        every other mover honors); the vacated slots are the caller's (the
        swap manager returns them to its reservation). Returns
        ``(handles, seconds)``."""
        fabric = self.fabric
        assert fabric is not None, "tier not attached to a fabric"
        slot_ids = [int(s) for s in slot_ids]
        assert len(slot_ids) <= self.capacity_left(), \
            "persistent tier capacity exhausted"
        pool = view.pool
        seconds = self._tier_seconds(view, slot_ids)
        k_host = np.asarray(pool.k_pool[:, slot_ids])
        v_host = np.asarray(pool.v_pool[:, slot_ids])
        handles = []
        for i, sid in enumerate(slot_ids):
            h = self._next
            self._next -= 1
            self._entries[h] = _Persisted(k_host[:, i].copy(),
                                          v_host[:, i].copy(), view.name)
            view.persisted += 1
            view.table.remap_physical(sid, h)
            for vv in fabric.views.values():
                vv._on_remap(sid, h)
            handles.append(h)
        fabric.emit("demote", view=view.name, pages=len(handles),
                    handles=list(handles), seconds=seconds)
        return handles, seconds

    def promote(self, view, handles: Sequence[int]) -> tuple[list[int], float]:
        """Bring demoted pages back into live fast-domain pages under the
        view's own placement cycle, bit-exactly. Mirrors
        ``FabricView.unpark_pages``: refs/holds/ownership follow the bytes.
        Returns ``(new_ids, seconds)``."""
        fabric = self.fabric
        pool = view.pool
        handles = [int(h) for h in handles]
        dst = [view._alloc_physical() for _ in handles]
        k_stack = np.stack([self._entries[h].k for h in handles], axis=1)
        v_stack = np.stack([self._entries[h].v for h in handles], axis=1)
        pool.k_pool = pool.k_pool.at[:, dst].set(k_stack)
        pool.v_pool = pool.v_pool.at[:, dst].set(v_stack)
        seconds = self._tier_seconds(view, dst)
        for h, d in zip(handles, dst):
            e = self._entries.pop(h)
            owner = fabric.views.get(e.owner)
            if owner is not None:
                owner.persisted -= 1
            view.table.remap_physical(h, d)
            fabric._own(view, d)
            for vv in fabric.views.values():
                vv._on_remap(h, d)
        fabric.emit("promote", view=view.name, pages=len(handles),
                    seconds=seconds)
        return dst, seconds

    def forget(self, handle: int) -> None:
        """Drop a demoted page whose last reference died (sequence freed
        while cold): the bytes are garbage, no transfer happens."""
        e = self._entries.pop(handle, None)
        if e is None:
            return
        owner = self.fabric.views.get(e.owner) if self.fabric else None
        if owner is not None:
            owner.persisted -= 1

    def read(self, handle: int) -> tuple[np.ndarray, np.ndarray]:
        """Bytes of a demoted page (tests/oracles; no transfer priced)."""
        e = self._entries[handle]
        return e.k, e.v

    # -- restart-surviving prefix store ---------------------------------------

    def pin(self, view, tokens: Sequence[int]):
        """Pin a registered prompt prefix: the tier takes its own holds on
        the chain (via a trie probe), so the pages survive refcount churn
        with zero live requests — the arbiter pins popular system prompts
        this way. Returns the pin key, or None if nothing is registered."""
        pages: list[int] = []
        n = view.probe_prefix(list(tokens), pages, count=False)
        if not pages:
            return None
        key = (view.name, tuple(tokens[:n]))
        if key in self._pins:
            view.release(pages)            # already pinned: undo dup holds
            self.touch_pin(key)
            return key
        self._pin_clock += 1
        self._pins[key] = {"view": view.name, "tokens": list(tokens[:n]),
                           "pages": pages, "stamp": self._pin_clock}
        return key

    def touch_pin(self, key) -> None:
        """Refresh a pin's LRU stamp: the arbiter touches the pins it
        re-selects each cycle, so a preamble that stays globally hot never
        ages into an eviction candidate."""
        entry = self._pins.get(key)
        if entry is not None:
            self._pin_clock += 1
            entry["stamp"] = self._pin_clock

    def unpin(self, key) -> None:
        entry = self._pins.pop(key, None)
        if entry is None or self.fabric is None:
            return
        view = self.fabric.views.get(entry["view"])
        if view is not None:
            view.release(entry["pages"])

    def release_pins(self) -> None:
        """Drop every pin's holds (fabric teardown / test cleanup)."""
        for key in list(self._pins):
            self.unpin(key)

    def pinned_pages(self) -> set[int]:
        out: set[int] = set()
        for entry in self._pins.values():
            out.update(entry["pages"])
        return out

    def _pin_stamp(self, view, tokens: Sequence[int]) -> int | None:
        """LRU stamp of the pin covering a chain, if any: a chain is
        "pinned" when some pin's token path is a prefix of it (chains are
        maximal, so they may extend past the pinned preamble)."""
        toks = tuple(int(t) for t in tokens)
        best = None
        for (vname, ptoks), entry in self._pins.items():
            if vname == view.name and toks[:len(ptoks)] == ptoks:
                best = max(best or 0, entry["stamp"])
        return best

    def _evict_chain_pins(self, view, tokens: Sequence[int]) -> None:
        """Drop every pin whose token path prefixes the evicted chain."""
        toks = tuple(int(t) for t in tokens)
        for key in [k for k in self._pins
                    if k[0] == view.name and toks[:len(k[1])] == k[1]]:
            self.unpin(key)

    def store_budget_bytes(self, pool) -> int:
        """The prefix store's byte cap: the tier's page capacity priced in
        the pool's page bytes. Demotion slots and the store share the same
        cap — the tier is one device, not two."""
        return self.capacity_pages * pool.page_bytes

    def export_prefixes(self, view, *, min_ref: int = 2) -> dict:
        """Export hot prefix chains — every pinned chain plus every chain
        whose pages are all held by ``min_ref``+ readers — with their chain
        keys (root-anchored token paths) and K/V bytes. Returns the
        manifest; the store (disk or memory) is replaced atomically.

        The store is capped at :meth:`store_budget_bytes`. Over the cap,
        chains are kept by priority — pinned chains in LRU order (most
        recently touched first), then unpinned chains — and the losers are
        *surfaced*, not silently dropped: a rejected pinned chain is
        unpinned and emits ``evict`` (the LRU eviction policy), a rejected
        unpinned chain emits ``export_skip``; both are counted in the
        observatory metrics."""
        pool = view.pool
        table = view.table
        pinned = self.pinned_pages()
        chains = table.export_chains(
            select=lambda pid: pid in pinned
            or table.ref.get(pid, 0) >= min_ref)
        # rank: pinned chains newest-stamp-first, then unpinned in table
        # order; greedy-fit against the byte cap in that priority order
        ranked = sorted(
            range(len(chains)),
            key=lambda i: (
                (0, -(self._pin_stamp(view, chains[i]["tokens"]) or 0))
                if self._pin_stamp(view, chains[i]["tokens"]) is not None
                else (1, i)))
        budget = self.store_budget_bytes(pool)
        pb = pool.page_bytes
        spent, kept = 0, []
        for i in ranked:
            ch = chains[i]
            nbytes = len(ch["phys"]) * pb
            if spent + nbytes <= budget:
                spent += nbytes
                kept.append(i)
                continue
            stamp = self._pin_stamp(view, ch["tokens"])
            if stamp is not None:
                self._evict_chain_pins(view, ch["tokens"])
                self.evicted_chains += 1
                if self.fabric is not None:
                    self.fabric.emit("evict", view=view.name,
                                     pages=len(ch["phys"]), chains=1)
            else:
                self.skipped_chains += 1
                if self.fabric is not None:
                    self.fabric.emit("export_skip", view=view.name,
                                     pages=len(ch["phys"]), chains=1)
        chains = [chains[i] for i in sorted(kept)]
        manifest = {
            "kind": "prefix_store",
            "geometry": self._geometry(pool),
            "chains": [],
        }
        arrays: dict[str, np.ndarray] = {}
        sizes = []
        for i, ch in enumerate(chains):
            k = np.asarray(pool.k_pool[:, ch["phys"]])
            v = np.asarray(pool.v_pool[:, ch["phys"]])
            fk, fv = f"chain_{i:05d}_k.npy", f"chain_{i:05d}_v.npy"
            arrays[fk], arrays[fv] = k, v
            sizes.append(k.nbytes + v.nbytes)
            manifest["chains"].append({
                "tokens": [int(t) for t in ch["tokens"]],
                "pages": len(ch["phys"]),
                "k": fk, "v": fv,
                "sha256_k": _sha256(k.tobytes()),
                "sha256_v": _sha256(v.tobytes()),
            })
        manifest["staging"] = self._staging_plan(pool, sizes or [0])
        if self.directory is None:
            self._mem_store = {"manifest": manifest, "arrays": arrays}
        else:
            self.directory.mkdir(parents=True, exist_ok=True)
            tmp = self.directory / f".tmp_prefix_store_{os.getpid()}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for fname, arr in arrays.items():
                np.save(tmp / fname, arr, allow_pickle=False)
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            publish_dir(tmp, self.directory / "prefix_store")
        return manifest

    def _load_store(self):
        if self.directory is None:
            if self._mem_store is None:
                return None, None
            return self._mem_store["manifest"], self._mem_store["arrays"]
        d = self.directory / "prefix_store"
        if not (d / "manifest.json").exists():
            return None, None
        manifest = json.loads((d / "manifest.json").read_text())
        arrays = {}
        for ch in manifest["chains"]:
            for key, sha in ((ch["k"], ch["sha256_k"]),
                             (ch["v"], ch["sha256_v"])):
                arr = np.load(d / key, allow_pickle=False)
                if _sha256(arr.tobytes()) != sha:
                    raise IOError(f"checksum mismatch in {d / key} — "
                                  "corrupt prefix store")
                arrays[key] = arr
        return manifest, arrays

    def import_prefixes(self, view) -> tuple[int, float]:
        """Re-import the prefix store into a (typically fresh) fabric:
        allocate pages under the view's placement cycle, scatter the K/V
        bytes, re-register the chain keys, and pin the chains so they
        survive until real traffic re-shares them. Chains that no longer
        fit the view's quota are skipped (never-abort). Returns
        ``(pages_restored, seconds)``."""
        fabric = self.fabric
        manifest, arrays = self._load_store()
        if manifest is None:
            return 0, 0.0
        pool = view.pool
        if manifest["geometry"] != self._geometry(pool):
            raise ValueError(
                f"prefix store geometry {manifest['geometry']} does not "
                f"match importing pool {self._geometry(pool)}")
        ps = pool.page_size
        restored, seconds = 0, 0.0
        for ch in manifest["chains"]:
            tokens = ch["tokens"]
            pages: list[int] = []
            matched = view.probe_prefix(tokens, pages, count=False)
            fresh: list[int] = []
            try:
                for _ in range(matched // ps, len(tokens) // ps):
                    fresh.append(view.append_page(pages))
            except RuntimeError:           # quota full: keep earlier chains
                view.release(pages)
                break
            if fresh:
                idx = list(range(matched // ps, len(tokens) // ps))
                pool.k_pool = pool.k_pool.at[:, fresh].set(
                    arrays[ch["k"]][:, idx])
                pool.v_pool = pool.v_pool.at[:, fresh].set(
                    arrays[ch["v"]][:, idx])
                secs = self._tier_seconds(view, fresh)
                seconds += secs
                fabric.emit("restore", view=view.name, pages=len(fresh),
                            seconds=secs)
            view.register_prefix(tokens, pages, len(tokens))
            key = (view.name, tuple(tokens))
            if key in self._pins:
                view.release(pages)        # chain already held by a pin
                self.touch_pin(key)
            else:
                self._pin_clock += 1
                self._pins[key] = {"view": view.name, "tokens": list(tokens),
                                   "pages": pages, "stamp": self._pin_clock}
            restored += len(fresh)
        return restored, seconds

    # -- peer page export / import --------------------------------------------

    def export_range(self, view, pages: Sequence[int], mesh=None, *,
                     tokens: Sequence[int] | None = None,
                     ntokens: int | None = None) -> dict:
        """Serialize a live page range: table slice (refcounts + trie
        chains restricted to the range), physical K/V bytes, the exporter's
        ledger charges, and the mesh/sharding layout stamp. Non-destructive:
        the exporter keeps its pages — the peer adopts a copy.

        ``tokens``/``ntokens`` annotate the range with its token path and
        valid-token count so a peer with a *different* page size can
        re-chunk the bytes (cluster/convert.py) — without them a mismatched
        import has no way to rebuild chain keys or trim write padding."""
        pool = view.pool
        pages = [int(p) for p in pages]
        assert all(p >= 0 for p in pages), \
            "export a live page range, not tier handles"
        pageset = set(pages)
        k = np.asarray(pool.k_pool[:, pages])
        v = np.asarray(pool.v_pool[:, pages])
        blob = {
            "kind": "page_range",
            "geometry": self._geometry(pool),
            "layout": kv_layout_metadata(pool.cfg, pool.page_size, mesh),
            "pages": pages,
            "tokens": None if tokens is None else [int(t) for t in tokens],
            "ntokens": int(ntokens if ntokens is not None
                           else len(pages) * pool.page_size),
            "ref": {int(p): int(view.table.ref.get(p, 0)) for p in pages},
            "chains": view.table.export_chains(
                select=lambda pid: pid in pageset),
            "ledger": {
                "view": view.name,
                "per_domain_pages": np.bincount(
                    [pool.domain_of(p) for p in pages],
                    minlength=len(pool.domains)).tolist(),
                "bytes": len(pages) * pool.page_bytes,
            },
            "staging": self._staging_plan(pool, [k.nbytes + v.nbytes]),
            "k": k, "v": v,
            "sha256": {"k": _sha256(k.tobytes()), "v": _sha256(v.tobytes())},
        }
        return blob

    def import_range(self, view, blob: dict) -> tuple[list[int], float]:
        """Adopt an exported page range into this fabric: allocate under
        the importing view's placement cycle and quota ledger, scatter the
        bytes, and rebuild the range's trie chains under remapped ids.
        Returns ``(new_ids, seconds)``; both fabrics' ledgers balance (the
        exporter still charges its copy, the importer charges its own)."""
        fabric = self.fabric
        pool = view.pool
        assert blob["kind"] == "page_range"
        if blob["geometry"] != self._geometry(pool):
            raise ValueError(
                f"page-range geometry {blob['geometry']} does not match "
                f"importing pool {self._geometry(pool)}")
        if _sha256(np.ascontiguousarray(blob["k"]).tobytes()) \
                != blob["sha256"]["k"] \
                or _sha256(np.ascontiguousarray(blob["v"]).tobytes()) \
                != blob["sha256"]["v"]:
            raise IOError("checksum mismatch in page-range blob")
        new_ids: list[int] = []
        for _ in blob["pages"]:
            view.append_page(new_ids)
        pool.k_pool = pool.k_pool.at[:, new_ids].set(blob["k"])
        pool.v_pool = pool.v_pool.at[:, new_ids].set(blob["v"])
        mapping = {int(old): new for old, new in zip(blob["pages"], new_ids)}
        view.table.import_chains(
            blob["chains"], lambda ch: [mapping[int(p)] for p in ch["phys"]])
        seconds = self._tier_seconds(view, new_ids)
        fabric.emit("restore", view=view.name, pages=len(new_ids),
                    seconds=seconds)
        return new_ids, seconds


def _wire_dtype(name: str) -> np.dtype:
    """Resolve a geometry dtype stamp, including the ml_dtypes families
    (bfloat16 & co) that plain ``np.dtype`` does not know by name."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def serialize_range(blob: dict) -> bytes:
    """Wire format for a page-range export: length-prefixed JSON header
    followed by the two ``np.save`` payloads. Peers on other hosts adopt
    ranges from exactly these bytes. Payloads travel as uint8 views —
    ``np.save`` flattens extension dtypes like bfloat16 to opaque void
    records — and the importer restores the geometry stamp's dtype."""
    head = {key: val for key, val in blob.items() if key not in ("k", "v")}
    raw = json.dumps(head).encode()
    buf = io.BytesIO()
    buf.write(len(raw).to_bytes(8, "little"))
    buf.write(raw)
    np.save(buf, np.ascontiguousarray(blob["k"]).view(np.uint8),
            allow_pickle=False)
    np.save(buf, np.ascontiguousarray(blob["v"]).view(np.uint8),
            allow_pickle=False)
    return buf.getvalue()


def deserialize_range(data: bytes) -> dict:
    buf = io.BytesIO(data)
    n = int.from_bytes(buf.read(8), "little")
    blob = json.loads(buf.read(n).decode())
    dtype = _wire_dtype(blob["geometry"]["dtype"])
    blob["k"] = np.load(buf, allow_pickle=False).view(dtype)
    blob["v"] = np.load(buf, allow_pickle=False).view(dtype)
    return blob
