"""Per-domain placement telemetry: counters + ring-buffer samples.

One :class:`DomainTelemetry` instance rides along with each page pool (and is
shared with its MigrationExecutor). Counters are cumulative since creation;
sample streams (latency, per-domain stall time) live in fixed-size ring
buffers so a long-running engine never grows memory. ``snapshot()`` is what
``ServeEngine.step()`` surfaces and what benchmarks/placement_bench.py dumps.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


class Ring:
    """Fixed-capacity overwrite-oldest sample buffer."""

    def __init__(self, capacity: int = 128):
        assert capacity > 0
        self._buf = np.zeros(capacity, dtype=np.float64)
        self._next = 0
        self._count = 0

    def push(self, value: float) -> None:
        self._buf[self._next] = float(value)
        self._next = (self._next + 1) % len(self._buf)
        self._count = min(self._count + 1, len(self._buf))

    def values(self) -> np.ndarray:
        """Samples oldest-first."""
        if self._count < len(self._buf):
            return self._buf[:self._count].copy()
        return np.roll(self._buf, -self._next)

    def mean(self) -> float:
        return float(self.values().mean()) if self._count else 0.0

    def last(self) -> float:
        return float(self._buf[(self._next - 1) % len(self._buf)]) \
            if self._count else 0.0

    def __len__(self) -> int:
        return self._count


class ClassSloCounters:
    """Per-priority-class SLO accounting (scheduler subsystem, DESIGN.md §5).

    One row per class: request lifecycle counts, deadline hits/misses, token
    throughput, and swap traffic attributed to the class. The scheduler's
    :class:`repro.scheduler.slo.SloTracker` drives these; they surface in the
    owning pool's ``DomainTelemetry.snapshot()`` so engine telemetry carries
    SLO state alongside placement state.
    """

    FIELDS = ("submitted", "completed", "preemptions", "ttft_met",
              "ttft_missed", "tpot_met", "tpot_missed", "goodput_tokens",
              "swap_out_pages", "swap_in_pages")

    def __init__(self):
        self._rows: dict[str, dict[str, int]] = {}

    def _row(self, cls: str) -> dict[str, int]:
        if cls not in self._rows:
            self._rows[cls] = {f: 0 for f in self.FIELDS}
        return self._rows[cls]

    def add(self, cls: str, field: str, n: int = 1) -> None:
        assert field in self.FIELDS, field
        self._row(cls)[field] += n

    def get(self, cls: str, field: str) -> int:
        return self._row(cls)[field]

    @property
    def classes(self) -> list[str]:
        return sorted(self._rows)

    def snapshot(self) -> dict:
        return {cls: dict(row) for cls, row in sorted(self._rows.items())}


class DomainTelemetry:
    """Placement event counters for one pool's memory domains.

    Per-domain: allocs, frees, migrations in/out, bytes in/out, and a ring of
    analytic stall-time samples (the Eq.-1 per-domain read time the engine
    computes each step). Global: a latency ring and planned-vs-executed
    migration counts (the tuner plans logical moves at cycle resolution; the
    executor reports physically moved pages). When a scheduler rides on the
    pool it attaches :class:`ClassSloCounters` (``slo``) and swap totals.
    """

    TIER_OPS = ("demote", "promote", "restore")

    def __init__(self, domain_names: Sequence[str], ring_capacity: int = 128):
        self.domain_names = list(domain_names)
        n = len(self.domain_names)
        self.allocs = np.zeros(n, dtype=np.int64)
        self.frees = np.zeros(n, dtype=np.int64)
        self.migrations_in = np.zeros(n, dtype=np.int64)
        self.migrations_out = np.zeros(n, dtype=np.int64)
        self.bytes_in = np.zeros(n, dtype=np.int64)
        self.bytes_out = np.zeros(n, dtype=np.int64)
        self.stall = [Ring(ring_capacity) for _ in range(n)]
        self.latency = Ring(ring_capacity)
        self.planned_moves = 0
        self.executed_moves = 0
        self.rebalances = 0
        self.swap_outs = 0           # preemption swap round-trips (pages)
        self.swap_ins = 0
        self.swap_seconds = 0.0      # Eq.-1 transfer time spent swapping
        # speculative decode (DESIGN.md §7): one verify step replaces up to
        # 1 + accepted decode steps; acceptance rate is the fraction of
        # drafted tokens the model's own argmax confirmed
        self.spec_steps = 0          # verify steps with at least one draft
        self.spec_drafted = 0        # draft tokens proposed
        self.spec_accepted = 0       # draft tokens accepted
        self.spec_emitted = 0        # tokens emitted by verify steps
        # persistent tier (DESIGN.md §9): demote = swap slot -> tier,
        # promote = tier -> fast domain (through the swap forwarding map),
        # restore = prefix-store re-import into a fresh fabric
        self.tier_pages = {op: 0 for op in self.TIER_OPS}
        self.tier_seconds = {op: 0.0 for op in self.TIER_OPS}
        self.tier_occupancy: dict[str, dict[str, int]] = {}
        self.slo: ClassSloCounters | None = None

    # -- event hooks --------------------------------------------------------

    def record_alloc(self, domain: int, pages: int = 1) -> None:
        self.allocs[domain] += pages

    def record_free(self, domain: int, pages: int = 1) -> None:
        self.frees[domain] += pages

    def record_migration(self, src_domain: int, dst_domain: int,
                         pages: int, nbytes: int) -> None:
        self.migrations_out[src_domain] += pages
        self.migrations_in[dst_domain] += pages
        self.bytes_out[src_domain] += nbytes
        self.bytes_in[dst_domain] += nbytes
        self.executed_moves += pages

    def record_plan(self, num_moves: int) -> None:
        self.planned_moves += num_moves

    def record_latency(self, seconds: float) -> None:
        self.latency.push(seconds)

    def record_stall(self, domain: int, seconds: float) -> None:
        self.stall[domain].push(seconds)

    def record_rebalance(self) -> None:
        self.rebalances += 1

    def record_swap(self, direction: str, pages: int,
                    seconds: float) -> None:
        assert direction in ("out", "in")
        if direction == "out":
            self.swap_outs += pages
        else:
            self.swap_ins += pages
        self.swap_seconds += float(seconds)

    def record_tier(self, op: str, pages: int, seconds: float) -> None:
        """One persistent-tier transfer (Eq.-1 priced, see bwmodel)."""
        assert op in self.TIER_OPS, op
        self.tier_pages[op] += int(pages)
        self.tier_seconds[op] += float(seconds)

    def record_tier_occupancy(self, tier: str, used: int,
                              capacity: int) -> None:
        """Gauge: pages resident in one placement tier right now."""
        self.tier_occupancy[tier] = {"used": int(used),
                                     "capacity": int(capacity)}

    def record_spec(self, drafted: int, accepted: int,
                    emitted: int) -> None:
        """One speculative verify step's draft/accept/emit totals."""
        self.spec_steps += 1
        self.spec_drafted += drafted
        self.spec_accepted += accepted
        self.spec_emitted += emitted

    def attach_slo(self) -> ClassSloCounters:
        """Create (or return) the per-class SLO counter block."""
        if self.slo is None:
            self.slo = ClassSloCounters()
        return self.slo

    # -- reporting ----------------------------------------------------------

    @property
    def bytes_moved(self) -> int:
        return int(self.bytes_in.sum())

    def snapshot(self) -> dict:
        domains = {}
        for i, name in enumerate(self.domain_names):
            domains[name] = {
                "allocs": int(self.allocs[i]),
                "frees": int(self.frees[i]),
                "migr_in": int(self.migrations_in[i]),
                "migr_out": int(self.migrations_out[i]),
                "bytes_in": int(self.bytes_in[i]),
                "bytes_out": int(self.bytes_out[i]),
                "stall_mean_s": self.stall[i].mean(),
            }
        out = {
            "domains": domains,
            "latency_mean_s": self.latency.mean(),
            "latency_last_s": self.latency.last(),
            "planned_moves": self.planned_moves,
            "executed_moves": self.executed_moves,
            "bytes_moved": self.bytes_moved,
            "rebalances": self.rebalances,
            "swap_outs": self.swap_outs,
            "swap_ins": self.swap_ins,
            "swap_seconds": self.swap_seconds,
            "spec": {
                "steps": self.spec_steps,
                "drafted": self.spec_drafted,
                "accepted": self.spec_accepted,
                "emitted": self.spec_emitted,
                "acceptance_rate": (self.spec_accepted
                                    / max(self.spec_drafted, 1)),
            },
            "tiers": {
                "ops": {op: {"pages": self.tier_pages[op],
                             "seconds": self.tier_seconds[op]}
                        for op in self.TIER_OPS},
                "occupancy": {k: dict(v)
                              for k, v in self.tier_occupancy.items()},
            },
        }
        if self.slo is not None:
            out["slo"] = self.slo.snapshot()
        return out
