"""Per-domain placement telemetry: counters + ring-buffer samples.

One :class:`DomainTelemetry` instance rides along with each page pool (and is
shared with its MigrationExecutor). Counters are cumulative since creation;
sample streams (latency, per-domain stall time) live in fixed-size ring
buffers so a long-running engine never grows memory. ``snapshot()`` is what
``ServeEngine.step()`` surfaces and what benchmarks/placement_bench.py dumps.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


class Ring:
    """Fixed-capacity overwrite-oldest sample buffer."""

    def __init__(self, capacity: int = 128):
        assert capacity > 0
        self._buf = np.zeros(capacity, dtype=np.float64)
        self._next = 0
        self._count = 0

    def push(self, value: float) -> None:
        self._buf[self._next] = float(value)
        self._next = (self._next + 1) % len(self._buf)
        self._count = min(self._count + 1, len(self._buf))

    def values(self) -> np.ndarray:
        """Samples oldest-first."""
        if self._count < len(self._buf):
            return self._buf[:self._count].copy()
        return np.roll(self._buf, -self._next)

    def mean(self) -> float:
        return float(self.values().mean()) if self._count else 0.0

    def last(self) -> float:
        return float(self._buf[(self._next - 1) % len(self._buf)]) \
            if self._count else 0.0

    def __len__(self) -> int:
        return self._count


class DomainTelemetry:
    """Placement event counters for one pool's memory domains.

    Per-domain: allocs, frees, migrations in/out, bytes in/out, and a ring of
    analytic stall-time samples (the Eq.-1 per-domain read time the engine
    computes each step). Global: a latency ring and planned-vs-executed
    migration counts (the tuner plans logical moves at cycle resolution; the
    executor reports physically moved pages).
    """

    def __init__(self, domain_names: Sequence[str], ring_capacity: int = 128):
        self.domain_names = list(domain_names)
        n = len(self.domain_names)
        self.allocs = np.zeros(n, dtype=np.int64)
        self.frees = np.zeros(n, dtype=np.int64)
        self.migrations_in = np.zeros(n, dtype=np.int64)
        self.migrations_out = np.zeros(n, dtype=np.int64)
        self.bytes_in = np.zeros(n, dtype=np.int64)
        self.bytes_out = np.zeros(n, dtype=np.int64)
        self.stall = [Ring(ring_capacity) for _ in range(n)]
        self.latency = Ring(ring_capacity)
        self.planned_moves = 0
        self.executed_moves = 0
        self.rebalances = 0

    # -- event hooks --------------------------------------------------------

    def record_alloc(self, domain: int, pages: int = 1) -> None:
        self.allocs[domain] += pages

    def record_free(self, domain: int, pages: int = 1) -> None:
        self.frees[domain] += pages

    def record_migration(self, src_domain: int, dst_domain: int,
                         pages: int, nbytes: int) -> None:
        self.migrations_out[src_domain] += pages
        self.migrations_in[dst_domain] += pages
        self.bytes_out[src_domain] += nbytes
        self.bytes_in[dst_domain] += nbytes
        self.executed_moves += pages

    def record_plan(self, num_moves: int) -> None:
        self.planned_moves += num_moves

    def record_latency(self, seconds: float) -> None:
        self.latency.push(seconds)

    def record_stall(self, domain: int, seconds: float) -> None:
        self.stall[domain].push(seconds)

    def record_rebalance(self) -> None:
        self.rebalances += 1

    # -- reporting ----------------------------------------------------------

    @property
    def bytes_moved(self) -> int:
        return int(self.bytes_in.sum())

    def snapshot(self) -> dict:
        domains = {}
        for i, name in enumerate(self.domain_names):
            domains[name] = {
                "allocs": int(self.allocs[i]),
                "frees": int(self.frees[i]),
                "migr_in": int(self.migrations_in[i]),
                "migr_out": int(self.migrations_out[i]),
                "bytes_in": int(self.bytes_in[i]),
                "bytes_out": int(self.bytes_out[i]),
                "stall_mean_s": self.stall[i].mean(),
            }
        return {
            "domains": domains,
            "latency_mean_s": self.latency.mean(),
            "latency_last_s": self.latency.last(),
            "planned_moves": self.planned_moves,
            "executed_moves": self.executed_moves,
            "bytes_moved": self.bytes_moved,
            "rebalances": self.rebalances,
        }
