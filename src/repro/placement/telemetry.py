"""Per-domain placement telemetry: counters + ring-buffer samples.

One :class:`DomainTelemetry` instance rides along with each page pool (and is
shared with its MigrationExecutor). Counters are cumulative since creation;
sample streams (latency, per-domain stall time) live in fixed-size ring
buffers so a long-running engine never grows memory. ``snapshot()`` is what
``ServeEngine.step()`` surfaces and what benchmarks/placement_bench.py dumps.

Since the fabric observatory (DESIGN.md §10) every counter here is *backed
by* the labeled metrics registry in :mod:`repro.obs.metrics`: each
``record_*`` call lands both in the legacy arrays (the ``snapshot()``
contract the whole test surface reads) and in a registry family with
domain/class/tier labels, so ``telemetry.metrics.prometheus_text()``
exposes the same state in Prometheus text format.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.obs.metrics import MetricsRegistry


class Ring:
    """Fixed-capacity overwrite-oldest sample buffer."""

    def __init__(self, capacity: int = 128):
        assert capacity > 0
        self._buf = np.zeros(capacity, dtype=np.float64)
        self._next = 0
        self._count = 0

    def push(self, value: float) -> None:
        self._buf[self._next] = float(value)
        self._next = (self._next + 1) % len(self._buf)
        self._count = min(self._count + 1, len(self._buf))

    def values(self) -> np.ndarray:
        """Samples oldest-first."""
        if self._count < len(self._buf):
            return self._buf[:self._count].copy()
        return np.roll(self._buf, -self._next)

    def mean(self) -> float:
        return float(self.values().mean()) if self._count else 0.0

    def last(self) -> float:
        return float(self._buf[(self._next - 1) % len(self._buf)]) \
            if self._count else 0.0

    def quantile(self, q: float) -> float:
        """q-th sample quantile (linear interpolation) over the window;
        0.0 when empty. ``quantile(0.5)``/``quantile(0.95)`` are the
        p50/p95 the drift ledger and engine snapshots report."""
        assert 0.0 <= q <= 1.0, q
        if self._count == 0:
            return 0.0
        if self._count < len(self._buf):
            window = self._buf[:self._count]
        else:
            window = self._buf            # full ring: order is irrelevant
        return float(np.quantile(window, q))

    def __len__(self) -> int:
        return self._count


class ClassSloCounters:
    """Per-priority-class SLO accounting (scheduler subsystem, DESIGN.md §5).

    One row per class: request lifecycle counts, deadline hits/misses, token
    throughput, and swap traffic attributed to the class. The scheduler's
    :class:`repro.scheduler.slo.SloTracker` drives these; they surface in the
    owning pool's ``DomainTelemetry.snapshot()`` so engine telemetry carries
    SLO state alongside placement state. With a ``registry`` they also back
    the ``repro_slo_events_total{cls,field}`` counter family.
    """

    FIELDS = ("submitted", "completed", "preemptions", "ttft_met",
              "ttft_missed", "tpot_met", "tpot_missed", "goodput_tokens",
              "swap_out_pages", "swap_in_pages")

    def __init__(self, registry: MetricsRegistry | None = None):
        self._rows: dict[str, dict[str, int]] = {}
        self._family = registry.counter(
            "repro_slo_events_total",
            "Per-priority-class SLO lifecycle counters.",
            ("cls", "field")) if registry is not None else None

    def _row(self, cls: str) -> dict[str, int]:
        if cls not in self._rows:
            self._rows[cls] = {f: 0 for f in self.FIELDS}
        return self._rows[cls]

    def add(self, cls: str, field: str, n: int = 1) -> None:
        assert field in self.FIELDS, field
        self._row(cls)[field] += n
        if self._family is not None:
            self._family.labels(cls, field).inc(n)

    def get(self, cls: str, field: str) -> int:
        return self._row(cls)[field]

    @property
    def classes(self) -> list[str]:
        return sorted(self._rows)

    def snapshot(self) -> dict:
        return {cls: dict(row) for cls, row in sorted(self._rows.items())}


class DomainTelemetry:
    """Placement event counters for one pool's memory domains.

    Per-domain: allocs, frees, migrations in/out, bytes in/out, and a ring of
    analytic stall-time samples (the Eq.-1 per-domain read time the engine
    computes each step). Global: a latency ring and planned-vs-executed
    migration counts (the tuner plans logical moves at cycle resolution; the
    executor reports physically moved pages). When a scheduler rides on the
    pool it attaches :class:`ClassSloCounters` (``slo``) and swap totals.
    Everything mirrors into ``self.metrics`` (labeled registry).
    """

    TIER_OPS = ("demote", "promote", "restore", "evict")

    def __init__(self, domain_names: Sequence[str], ring_capacity: int = 128):
        self.domain_names = list(domain_names)
        n = len(self.domain_names)
        self.allocs = np.zeros(n, dtype=np.int64)
        self.frees = np.zeros(n, dtype=np.int64)
        self.migrations_in = np.zeros(n, dtype=np.int64)
        self.migrations_out = np.zeros(n, dtype=np.int64)
        self.bytes_in = np.zeros(n, dtype=np.int64)
        self.bytes_out = np.zeros(n, dtype=np.int64)
        self.stall = [Ring(ring_capacity) for _ in range(n)]
        self.latency = Ring(ring_capacity)
        self.planned_moves = 0
        self.executed_moves = 0
        self.rebalances = 0
        self.swap_outs = 0           # preemption swap round-trips (pages)
        self.swap_ins = 0
        self.swap_seconds = 0.0      # Eq.-1 transfer time spent swapping
        # speculative decode (DESIGN.md §7): one verify step replaces up to
        # 1 + accepted decode steps; acceptance rate is the fraction of
        # drafted tokens the model's own argmax confirmed
        self.spec_steps = 0          # verify steps with at least one draft
        self.spec_drafted = 0        # draft tokens proposed
        self.spec_accepted = 0       # draft tokens accepted
        self.spec_emitted = 0        # tokens emitted by verify steps
        # persistent tier (DESIGN.md §9): demote = swap slot -> tier,
        # promote = tier -> fast domain (through the swap forwarding map),
        # restore = prefix-store re-import into a fresh fabric,
        # evict = LRU drop of a pinned chain at the prefix store's cap
        self.tier_pages = {op: 0 for op in self.TIER_OPS}
        self.tier_seconds = {op: 0.0 for op in self.TIER_OPS}
        self.tier_occupancy: dict[str, dict[str, int]] = {}
        # event-bus subscribers that raised (fabric.emit isolates them so
        # a broken observer never aborts the alloc/free hot path)
        self.subscriber_errors = 0
        self.slo: ClassSloCounters | None = None
        self._init_metrics(ring_capacity)

    def _init_metrics(self, ring_capacity: int) -> None:
        """Registry families mirroring the legacy counters. Per-domain
        children are pre-resolved into lists so the hot-path mirror is one
        list index + one float add."""
        m = self.metrics = MetricsRegistry()
        names = self.domain_names

        def per_domain(family):
            return [family.labels(nm) for nm in names]

        self._m_allocs = per_domain(m.counter(
            "repro_pages_allocated_total",
            "Pages allocated per domain (speculative rollback decrements).",
            ("domain",)))
        self._m_frees = per_domain(m.counter(
            "repro_pages_freed_total", "Pages freed per domain.",
            ("domain",)))
        migr = m.counter(
            "repro_migrated_pages_total",
            "Pages physically migrated, per domain and direction.",
            ("domain", "direction"))
        self._m_migr_in = [migr.labels(nm, "in") for nm in names]
        self._m_migr_out = [migr.labels(nm, "out") for nm in names]
        mb = m.counter(
            "repro_migrated_bytes_total",
            "Bytes physically migrated, per domain and direction.",
            ("domain", "direction"))
        self._m_bytes_in = [mb.labels(nm, "in") for nm in names]
        self._m_bytes_out = [mb.labels(nm, "out") for nm in names]
        stall = m.histogram(
            "repro_stall_seconds",
            "Eq.-1 per-domain read-time samples.", ("domain",))
        self._m_stall = [stall.labels(nm) for nm in names]
        self._m_latency = m.histogram(
            "repro_latency_seconds", "Per-step engine latency samples.")
        self._m_planned = m.counter(
            "repro_planned_moves_total", "Tuner-planned logical moves.")
        self._m_executed = m.counter(
            "repro_executed_moves_total", "Executor-moved physical pages.")
        self._m_rebalances = m.counter(
            "repro_rebalances_total", "Arbiter capacity rebalances.")
        swap = m.counter(
            "repro_swap_pages_total",
            "Preemption swap traffic in pages, by direction.",
            ("direction",))
        self._m_swap = {"out": swap.labels("out"), "in": swap.labels("in")}
        self._m_swap_seconds = m.counter(
            "repro_swap_seconds_total", "Eq.-1 seconds spent swapping.")
        spec = m.counter(
            "repro_spec_tokens_total",
            "Speculative decode token counts, by outcome.", ("outcome",))
        self._m_spec = {o: spec.labels(o)
                        for o in ("drafted", "accepted", "emitted")}
        self._m_spec_steps = m.counter(
            "repro_spec_steps_total", "Verify steps with >= 1 draft token.")
        tier_p = m.counter(
            "repro_tier_pages_total",
            "Pages moved by persistent-tier ops.", ("op",))
        tier_s = m.counter(
            "repro_tier_seconds_total",
            "Eq.-1 seconds spent on persistent-tier ops.", ("op",))
        self._m_tier_pages = {op: tier_p.labels(op) for op in self.TIER_OPS}
        self._m_tier_seconds = {op: tier_s.labels(op)
                                for op in self.TIER_OPS}
        self._m_tier_occ = m.gauge(
            "repro_tier_occupancy_pages",
            "Pages resident per placement tier right now.",
            ("tier", "kind"))
        self._m_sub_errors = m.counter(
            "repro_subscriber_errors_total",
            "Fabric event-bus subscribers that raised (isolated).",
            ("event",))

    # -- event hooks --------------------------------------------------------

    def record_alloc(self, domain: int, pages: int = 1) -> None:
        self.allocs[domain] += pages
        self._m_allocs[domain].inc(pages)

    def record_free(self, domain: int, pages: int = 1) -> None:
        self.frees[domain] += pages
        self._m_frees[domain].inc(pages)

    def record_migration(self, src_domain: int, dst_domain: int,
                         pages: int, nbytes: int) -> None:
        self.migrations_out[src_domain] += pages
        self.migrations_in[dst_domain] += pages
        self.bytes_out[src_domain] += nbytes
        self.bytes_in[dst_domain] += nbytes
        self._m_migr_out[src_domain].inc(pages)
        self._m_migr_in[dst_domain].inc(pages)
        self._m_bytes_out[src_domain].inc(nbytes)
        self._m_bytes_in[dst_domain].inc(nbytes)
        self.record_executed(pages)

    def record_executed(self, pages: int) -> None:
        """Physical pages the migration executor moved (also reached via
        :meth:`record_migration` when per-pair attribution is known)."""
        self.executed_moves += pages
        self._m_executed.inc(pages)

    def record_plan(self, num_moves: int) -> None:
        self.planned_moves += num_moves
        self._m_planned.inc(num_moves)

    def record_latency(self, seconds: float) -> None:
        self.latency.push(seconds)
        self._m_latency.observe(seconds)

    def record_stall(self, domain: int, seconds: float) -> None:
        self.stall[domain].push(seconds)
        self._m_stall[domain].observe(seconds)

    def record_rebalance(self) -> None:
        self.rebalances += 1
        self._m_rebalances.inc()

    def record_swap(self, direction: str, pages: int,
                    seconds: float) -> None:
        assert direction in ("out", "in")
        if direction == "out":
            self.swap_outs += pages
        else:
            self.swap_ins += pages
        self.swap_seconds += float(seconds)
        self._m_swap[direction].inc(pages)
        self._m_swap_seconds.inc(float(seconds))

    def record_tier(self, op: str, pages: int, seconds: float) -> None:
        """One persistent-tier transfer (Eq.-1 priced, see bwmodel)."""
        assert op in self.TIER_OPS, op
        self.tier_pages[op] += int(pages)
        self.tier_seconds[op] += float(seconds)
        self._m_tier_pages[op].inc(int(pages))
        self._m_tier_seconds[op].inc(float(seconds))

    def record_tier_occupancy(self, tier: str, used: int,
                              capacity: int) -> None:
        """Gauge: pages resident in one placement tier right now."""
        self.tier_occupancy[tier] = {"used": int(used),
                                     "capacity": int(capacity)}
        self._m_tier_occ.labels(tier, "used").set(used)
        self._m_tier_occ.labels(tier, "capacity").set(capacity)

    def record_spec(self, drafted: int, accepted: int,
                    emitted: int) -> None:
        """One speculative verify step's draft/accept/emit totals."""
        self.spec_steps += 1
        self.spec_drafted += drafted
        self.spec_accepted += accepted
        self.spec_emitted += emitted
        self._m_spec_steps.inc()
        self._m_spec["drafted"].inc(drafted)
        self._m_spec["accepted"].inc(accepted)
        self._m_spec["emitted"].inc(emitted)

    def record_subscriber_error(self, event: str) -> None:
        """A fabric event-bus subscriber raised; ``MemoryFabric.emit``
        isolated it so the alloc/free hot path survived."""
        self.subscriber_errors += 1
        self._m_sub_errors.labels(event).inc()

    def attach_slo(self) -> ClassSloCounters:
        """Create (or return) the per-class SLO counter block."""
        if self.slo is None:
            self.slo = ClassSloCounters(self.metrics)
        return self.slo

    # -- reporting ----------------------------------------------------------

    @property
    def bytes_moved(self) -> int:
        return int(self.bytes_in.sum())

    def prometheus_text(self) -> str:
        return self.metrics.prometheus_text()

    def snapshot(self) -> dict:
        domains = {}
        for i, name in enumerate(self.domain_names):
            domains[name] = {
                "allocs": int(self.allocs[i]),
                "frees": int(self.frees[i]),
                "migr_in": int(self.migrations_in[i]),
                "migr_out": int(self.migrations_out[i]),
                "bytes_in": int(self.bytes_in[i]),
                "bytes_out": int(self.bytes_out[i]),
                "stall_mean_s": self.stall[i].mean(),
                "stall_p95_s": self.stall[i].quantile(0.95),
            }
        out = {
            "domains": domains,
            "latency_mean_s": self.latency.mean(),
            "latency_last_s": self.latency.last(),
            "latency_p50_s": self.latency.quantile(0.5),
            "latency_p95_s": self.latency.quantile(0.95),
            "planned_moves": self.planned_moves,
            "executed_moves": self.executed_moves,
            "bytes_moved": self.bytes_moved,
            "rebalances": self.rebalances,
            "swap_outs": self.swap_outs,
            "swap_ins": self.swap_ins,
            "swap_seconds": self.swap_seconds,
            "subscriber_errors": self.subscriber_errors,
            "spec": {
                "steps": self.spec_steps,
                "drafted": self.spec_drafted,
                "accepted": self.spec_accepted,
                "emitted": self.spec_emitted,
                "acceptance_rate": (self.spec_accepted
                                    / max(self.spec_drafted, 1)),
            },
            "tiers": {
                "ops": {op: {"pages": self.tier_pages[op],
                             "seconds": self.tier_seconds[op]}
                        for op in self.TIER_OPS},
                "occupancy": {k: dict(v)
                              for k, v in self.tier_occupancy.items()},
            },
        }
        if self.slo is not None:
            out["slo"] = self.slo.snapshot()
        return out
