"""BWAP-paged KV cache: weighted page placement across memory domains.

The paper's mechanism, applied to serving: decode-time KV pages live in a
pool that spans memory *domains* of asymmetric bandwidth (local HBM, pod-peer
HBM over ICI, cross-pod HBM over DCI, host DRAM — topology.tpu_domains_topology).
Placement of new pages follows a policy from the placement registry
(default ``bwap_dwp``: Eq. 2/5 canonical weights scaled by the DWP tuner's
online proximity estimate); migrations between domains execute as batched
gather/scatter through placement.executor, exactly like mbind page migration
but one XLA op per batch instead of one copy per page.

Physically the pool is one array [total_pages, page_size, nkv, hd] per layer;
domain d owns the contiguous page-id range [offset_d, offset_d + n_d), so the
paged_attention kernel (kernels/paged_attention) is domain-oblivious and the
page table *is* the placement. Per-domain counters and stall samples are
collected in placement.telemetry (DESIGN.md §3.4).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bwmodel, interleave
from repro.core.dwp import DWPConfig, DWPTuner
from repro.models.config import ModelConfig
from repro.placement import policy as placement_policy
from repro.placement.executor import MigrationExecutor
from repro.placement.geometry import PageGeometry, geometry_for
from repro.placement.pagetable import PageTable
from repro.placement.telemetry import DomainTelemetry


@dataclasses.dataclass(frozen=True)
class MemoryDomain:
    name: str
    num_pages: int
    read_bw: float       # GB/s toward the worker chips
    is_worker: bool      # counts as "worker node" for DWP


def default_domains(total_pages: int) -> list[MemoryDomain]:
    """A 2-pod serving deployment's domain mix (DESIGN.md §2 table)."""
    from repro.core import topology as topo
    n = total_pages
    return [
        MemoryDomain("hbm_local", int(n * 0.35), topo.V5E_HBM_BW, True),
        MemoryDomain("hbm_peer_1hop", int(n * 0.25), topo.V5E_ICI_BW, False),
        MemoryDomain("hbm_peer_2hop", int(n * 0.20), topo.V5E_ICI_BW / 2,
                     False),
        MemoryDomain("hbm_pod1", int(n * 0.10), topo.V5E_DCI_BW, False),
        MemoryDomain("host_dram", n - int(n * 0.35) - int(n * 0.25)
                     - int(n * 0.20) - int(n * 0.10), topo.V5E_PCIE_BW,
                     False),
    ]


class BwapPagePool:
    """Paged KV storage with policy-driven placement. One pool per model
    (layers stacked on axis 0 so a layer's pool is pool[l]).

    ``tuner`` may be supplied externally (the domain arbiter passes a
    CoScheduledTuner for best-effort tenants); anything with ``.assignment``
    and ``.dwp`` works. When external, ``record_latency`` does not feed it —
    the owner (arbiter) drives it with the right stall streams.
    """

    def __init__(self, cfg: ModelConfig, domains: Sequence[MemoryDomain],
                 page_size: int = 16, dwp_config: DWPConfig | None = None,
                 seed: int = 0, policy: str = "bwap_dwp",
                 tuner=None, telemetry: DomainTelemetry | None = None,
                 geometry: PageGeometry | None = None):
        self.cfg = cfg
        self.domains = list(domains)
        self.page_size = page_size
        self.policy = placement_policy.resolve(policy)
        self.total_pages = sum(d.num_pages for d in self.domains)
        self.offsets = np.cumsum([0] + [d.num_pages for d in self.domains])
        # what one page *is* for this model group (DESIGN.md §12); the
        # default resolved from cfg reproduces the historical dense
        # [nl, pages, page_size, nkv, hd] layout bit-for-bit
        self.geometry = geometry if geometry is not None \
            else geometry_for(cfg, page_size)
        # the growth unit is the geometry's (identical for the default
        # paged layout; constant-footprint geometries pin their own)
        self.page_size = self.geometry.page_size
        cdt = jnp.dtype(cfg.compute_dtype)
        k_shape, v_shape = self.geometry.array_shapes(self.total_pages)
        self.k_pool = jnp.zeros(k_shape, cdt)
        self.v_pool = jnp.zeros(v_shape, cdt)
        self.free: list[list[int]] = [
            list(range(self.offsets[i], self.offsets[i + 1]))
            for i in range(len(self.domains))]
        # swap-slot reservations per domain (reserve_pages): off the free
        # lists AND off the capacities any placement decision sees
        self.reserved = np.zeros(len(self.domains), dtype=np.int64)

        self.bw = np.asarray([d.read_bw for d in self.domains])
        # bandwidth-descending fallback order for exhausted allocation cycles
        # (computed once; alloc_page is on the decode hot path)
        self._bw_order = [int(i) for i in np.argsort(-self.bw, kind="stable")]
        self.workers = tuple(i for i, d in enumerate(self.domains)
                             if d.is_worker)
        # canonical weights over domains (Eq. 2: single worker group)
        self.canonical = placement_policy.weights(
            "bwap_canonical", self._ctx(0.0))
        self.telemetry = telemetry or DomainTelemetry(
            [d.name for d in self.domains])
        self.executor = MigrationExecutor(telemetry=self.telemetry)
        # logical→physical indirection: refcounts, prefix trie, CoW forks.
        # The pool stays the *physical* allocator; the serving stack (engine,
        # scheduler, swap) goes through the fabric view for page lifetime.
        self.table = PageTable(self)
        self._external_tuner = tuner is not None
        self.tuner = tuner if tuner is not None else DWPTuner(
            self.canonical, list(self.workers),
            num_pages=4096,  # allocation-cycle resolution
            config=dwp_config or DWPConfig(n=8, c=2),
            on_migrate=self._on_tuner_plan)
        self._cycle_pos = 0
        # Alg. 1 lays sub-ranges out contiguously (uniform region first); an
        # allocation cycle must be stationary, so walk it in a fixed shuffle
        # (sized to the tuner's actual cycle — external tuners may differ
        # from the internal 4096-slot resolution).
        self._perm = np.random.default_rng(seed).permutation(
            len(self.tuner.assignment))

    # -- placement ----------------------------------------------------------

    def _ctx(self, dwp: float) -> placement_policy.PlacementContext:
        # effective capacities: swap reservations are parking space, not
        # allocatable pages — policies must not count them
        return placement_policy.PlacementContext(
            bandwidths=np.asarray([d.read_bw for d in self.domains]),
            num_pages=self.total_pages,
            workers=tuple(i for i, d in enumerate(self.domains)
                          if d.is_worker),
            dwp=dwp,
            capacities=np.asarray([d.num_pages for d in self.domains])
            - self.reserved)

    @property
    def weights(self) -> np.ndarray:
        return self.policy.weights(self._ctx(float(self.tuner.dwp)))

    def _on_tuner_plan(self, plan: interleave.MigrationPlan) -> None:
        self.telemetry.record_plan(plan.num_moves)

    def domain_of(self, page_id: int) -> int:
        return int(np.searchsorted(self.offsets, page_id, side="right") - 1)

    def alloc_page(self) -> int:
        """Next page id, following the weighted allocation cycle (Alg. 1
        pattern over the tuner's current assignment); falls back to the
        closest domain with free pages (precomputed bandwidth order)."""
        cycle = self.tuner.assignment
        for _ in range(len(cycle)):
            want = int(cycle[self._perm[self._cycle_pos % len(self._perm)]])
            self._cycle_pos += 1
            if self.free[want]:
                self.telemetry.record_alloc(want)
                return self.free[want].pop()
        for i in self._bw_order:
            if self.free[i]:
                self.telemetry.record_alloc(i)
                return self.free[i].pop()
        raise RuntimeError("KV pool exhausted")

    def free_pages(self, pages: Sequence[int]):
        for pid in pages:
            dom = self.domain_of(pid)
            self.free[dom].append(int(pid))
            self.telemetry.record_free(dom)

    # -- speculative allocation rollback --------------------------------------

    def alloc_marker(self) -> int:
        """Opaque allocation-cycle position; bracket a speculative
        ``alloc_page`` with markers to make it undoable (``undo_alloc``)."""
        return self._cycle_pos

    def return_speculative(self, pid: int) -> None:
        """Free-list LIFO return + alloc-count revert — the cycle-agnostic
        half of a speculative rollback, shared by the pool's own
        ``undo_alloc`` and fabric views' per-view cycle rollback."""
        dom = self.domain_of(pid)
        self.free[dom].append(int(pid))
        self.telemetry.record_alloc(dom, -1)

    def undo_alloc(self, pid: int, marker_before: int,
                   marker_after: int) -> None:
        """Return a speculatively-allocated page as if the allocation never
        happened: the page goes back on *top* of its free list (LIFO — the
        next alloc re-issues the same id), and when no allocation happened
        since (``marker_after`` is still current) the weighted allocation
        cycle rewinds too, so future placement matches a run that never
        allocated. The telemetry alloc count reverts rather than logging a
        free — rejected speculation is not page churn."""
        self.return_speculative(pid)
        if self._cycle_pos == marker_after:
            self._cycle_pos = marker_before

    def reserve_pages(self, domain: int, n: int) -> list[int]:
        """Take ``n`` free pages out of ``domain``'s free list without
        counting them as allocations: the scheduler's swap manager holds
        them as parking slots for preempted KV state, so ``alloc_page``
        never hands them to live sequences. The reservation also leaves the
        domain's *capacity* as the DWP tuner sees it (swap-aware DWP)."""
        if n > len(self.free[domain]):
            raise RuntimeError(
                f"cannot reserve {n} pages in domain "
                f"{self.domains[domain].name!r}: {len(self.free[domain])} "
                "free")
        taken = [self.free[domain].pop() for _ in range(n)]
        self.reserved[domain] += n
        self._refresh_tuner_capacity()
        return taken

    def unreserve_page(self, pid: int) -> None:
        """Return one reserved slot to the allocator (inverse of a single
        page of ``reserve_pages``): the page rejoins its domain's free list
        and the reservation ledger and tuner capacity follow. Reservations
        only ever change through ``reserve_pages``/``unreserve_page`` — the
        old bulk ``set_reserved_counts`` resync back-channel is gone."""
        dom = self.domain_of(pid)
        self.free[dom].append(int(pid))
        assert self.reserved[dom] > 0, "unreserve without a reservation"
        self.reserved[dom] -= 1
        self._refresh_tuner_capacity()

    def _refresh_tuner_capacity(self) -> None:
        """Feed the tuner the *effective* (unreserved) capacities so its
        allocation cycle never promises a reserved-away page. Domains with
        no reservation stay uncapped (np.inf) — canonical over-weighting of
        a small fast domain is a policy choice the fallback order absorbs;
        promising pages a reservation holds is simply wrong."""
        if self._external_tuner or not hasattr(self.tuner,
                                               "set_capacity_fractions"):
            return
        caps = np.asarray([d.num_pages for d in self.domains],
                          dtype=np.float64) - self.reserved
        allocatable = float(caps.sum())
        if allocatable <= 0:
            return
        frac = np.where(self.reserved > 0, caps / allocatable, np.inf)
        self.tuner.set_capacity_fractions(frac)

    def free_count(self) -> int:
        """Pages currently allocatable (reserved swap slots excluded —
        they are not on the free lists)."""
        return sum(len(f) for f in self.free)

    @property
    def slow_domains(self) -> tuple[int, ...]:
        """Non-worker domains — where preempted KV state parks."""
        return tuple(i for i, d in enumerate(self.domains)
                     if not d.is_worker)

    def bytes_per_domain(self, page_ids: Sequence[int]) -> np.ndarray:
        """Per-domain resident bytes of a page set (Eq.-1 input)."""
        out = np.zeros(len(self.domains))
        for pid in page_ids:
            out[self.domain_of(pid)] += self.page_bytes
        return out

    # -- data path ------------------------------------------------------------

    def write_token(self, layer_slot_kv: tuple, page_id: int, slot: int):
        """Write one token's K/V across all layers: layer_slot_kv =
        (k [L,nkv,hd], v [L,nkv,hd])."""
        k, v = layer_slot_kv
        self.k_pool = self.k_pool.at[:, page_id, slot].set(k)
        self.v_pool = self.v_pool.at[:, page_id, slot].set(v)

    def write_decode_batch(self, layer: int, page_ids, slots, k, v):
        """Scatter a whole decode batch's K/V for one layer in one op:
        page_ids/slots [B], k/v [B, nkv, hd]."""
        self.k_pool = self.k_pool.at[layer, page_ids, slots].set(k)
        self.v_pool = self.v_pool.at[layer, page_ids, slots].set(v)

    # -- DWP tuning / migration -------------------------------------------------

    def record_latency(self, seconds: float) -> bool:
        """Feed a decode-step latency sample; returns True when the tuner
        moved the allocation cycle (callers then migrate live sequences).
        Externally-tuned pools (arbiter tenants) only log the sample — the
        arbiter feeds the co-scheduled tuner with the right stall streams."""
        self.telemetry.record_latency(seconds)
        if self._external_tuner:
            return False
        before = self.tuner.assignment.copy()
        self.tuner.record(seconds)
        return not np.array_equal(before, self.tuner.assignment)

    def migrate_sequence(self, page_ids: list[int],
                         table: PageTable | None = None) -> list[int]:
        """Re-place an existing sequence's pages per the current weights
        (the incremental migration of §III-B2): returns new page ids.
        All physical copies happen in one batched gather/scatter.

        Shared pages (refcount > 1 under ``table``, defaulting to this
        pool's own table) are *pinned* — the caller speaks for only one of
        their holders — and moved table-tracked pages are remapped so
        refcounts and trie nodes follow. Pages the table never saw (raw
        callers that allocate via ``alloc_page`` directly) move with no
        bookkeeping, as before."""
        tbl = table if table is not None else self.table
        target = interleave.weighted_interleave(len(page_ids), self.weights)
        new_ids: list[int] = []
        src: list[int] = []
        dst: list[int] = []
        for pid, dom in zip(page_ids, target):
            cur = self.domain_of(pid)
            if tbl.shared(pid) or cur == int(dom) or not self.free[int(dom)]:
                new_ids.append(int(pid))
                continue
            nid = self.free[int(dom)].pop()
            src.append(int(pid))
            dst.append(nid)
            new_ids.append(nid)
        if src:
            (self.k_pool, self.v_pool), _ = self.executor.execute(
                (self.k_pool, self.v_pool), src, dst,
                src_domains=[self.domain_of(p) for p in src],
                dst_domains=[self.domain_of(p) for p in dst])
            for s, d in zip(src, dst):
                if s in tbl.ref:
                    tbl.remap_physical(s, d)
                self.free[self.domain_of(s)].append(s)  # after batched copy
        return new_ids

    # -- capacity (arbiter rebalancing) ---------------------------------------

    def live_pages(self) -> list[list[int]]:
        """Allocated page ids per domain, ascending."""
        out = []
        for i in range(len(self.domains)):
            free = set(self.free[i])
            out.append([p for p in range(self.offsets[i], self.offsets[i + 1])
                        if p not in free])
        return out

    def rebalance(self, new_sizes: Sequence[int]) -> np.ndarray:
        """Resize per-domain capacity (tenant join/leave): rebuilds the pool
        arrays at the new sizes, carrying live pages over in one batched
        copy. Live pages that no longer fit their domain spill to the
        fastest domain with room. Returns ``id_map`` (old page id -> new page
        id, -1 for pages that were free) so engines can remap page tables."""
        new_sizes = [int(n) for n in new_sizes]
        assert len(new_sizes) == len(self.domains)
        live = self.live_pages()
        new_offsets = np.cumsum([0] + new_sizes)
        placed: list[list[int]] = [[] for _ in self.domains]  # old ids per new domain
        overflow: list[int] = []
        for d, pages in enumerate(live):
            placed[d] = pages[:new_sizes[d]]
            overflow.extend(pages[new_sizes[d]:])
        for pid in overflow:
            for d in self._bw_order:
                if len(placed[d]) < new_sizes[d]:
                    placed[d].append(pid)
                    break
            else:
                raise ValueError("rebalance: live pages exceed new capacity")
        old_ids: list[int] = []
        new_ids: list[int] = []
        for d, pages in enumerate(placed):
            old_ids.extend(pages)
            new_ids.extend(range(int(new_offsets[d]),
                                 int(new_offsets[d]) + len(pages)))
        total = int(new_offsets[-1])
        k_shape, v_shape = self.geometry.array_shapes(total)
        new_k = jnp.zeros(k_shape, self.k_pool.dtype)
        new_v = jnp.zeros(v_shape, self.v_pool.dtype)
        (self.k_pool, self.v_pool), _ = self.executor.copy(
            (self.k_pool, self.v_pool), (new_k, new_v), old_ids, new_ids)
        id_map = np.full(self.total_pages, -1, dtype=np.int64)
        id_map[np.asarray(old_ids, dtype=np.int64)] = new_ids
        self.domains = [dataclasses.replace(d, num_pages=n)
                        for d, n in zip(self.domains, new_sizes)]
        self.total_pages = total
        self.offsets = new_offsets
        taken = [set(range(int(new_offsets[d]),
                           int(new_offsets[d]) + len(placed[d])))
                 for d in range(len(self.domains))]
        self.free = [[p for p in range(int(new_offsets[d]),
                                       int(new_offsets[d + 1]))
                      if p not in taken[d]]
                     for d in range(len(self.domains))]
        self.table.remap(id_map)
        self.telemetry.record_rebalance()
        return id_map

    # -- analytics ---------------------------------------------------------------

    def occupancy(self) -> dict[str, float]:
        """Live-allocated fraction of each domain's *allocatable* capacity.

        Reserved swap slots are parking space, not allocatable pages: they
        belong in neither the numerator (they hold no live sequence) nor the
        denominator. The old ``used / num_pages`` ratio kept reserved slots
        in the denominator, so a fully-allocated domain reported < 1.0 —
        phantom free headroom that made capacity-reading consumers (the DWP
        waterfill among them) over-allocate into a domain that had nothing
        left (regression: tests/test_fabric.py)."""
        out = {}
        for i, d in enumerate(self.domains):
            cap = d.num_pages - int(self.reserved[i])
            used = cap - len(self.free[i])
            out[d.name] = used / max(cap, 1)
        return out

    def used_pages(self) -> np.ndarray:
        """Live-allocated pages per domain (reserved parking slots are not
        live allocations and are excluded, matching ``occupancy``)."""
        return np.asarray([d.num_pages - int(self.reserved[i])
                           - len(self.free[i])
                           for i, d in enumerate(self.domains)])

    @property
    def page_bytes(self) -> int:
        """Bytes of one page across all layers, K+V — from the geometry,
        never from ``2 * page_size * nkv * hd`` (wrong for MLA latent
        caches with asymmetric k/v widths, and for SSM state pages)."""
        return self.geometry.page_bytes

    def expected_read_time(self, page_ids: Sequence[int]) -> float:
        """Analytic per-token KV read time for a sequence (the max-parallel-
        transfer model of Eq. 1, ``core.bwmodel.stall_cost``). Feeds
        per-domain stall samples into telemetry."""
        per_domain = self.bytes_per_domain(page_ids)
        times = per_domain / (self.bw * 1e9)
        for d, t in enumerate(times):
            self.telemetry.record_stall(d, float(t))
        return bwmodel.stall_cost(per_domain, self.bw)
