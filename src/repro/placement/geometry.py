"""Page geometry — what one physical page *is* for a model group
(DESIGN.md §12).

`BwapPagePool` historically baked in the dense-transformer layout
``[nl, pages, page_size, nkv, hd]`` twice over (one array for K, a
``zeros_like`` clone for V) and derived ``page_bytes`` from
``2 * page_size * nkv * hd``.  That is wrong for every other cache the
repo already carries configs for:

* **MLA latent K/V** (deepseek_v3, granite_moe): the per-token cache is
  one shared rope key of width ``qk_rope_head_dim`` plus one latent
  vector of width ``kv_lora_rank`` — asymmetric k/v widths, an order of
  magnitude smaller than materialized heads.
* **SSM recurrent state** (hymba/xlstm, ``models/ssm.py``): a sequence
  is ONE page of constant-size state that migrates between domains but
  never appends; "fork" means copy the state, not extend a CoW chain.
* **Encoder cross-attention K/V** (whisper): written once per
  utterance, read-only afterwards, shareable across every decode
  session of the same audio — a fixed page count set by the encoder
  frame budget, not by generated tokens.

`PageGeometry` captures exactly the three facts the placement stack
needs — bytes per page, the pages-for-tokens growth law, and the
shareability class — so pool/pagetable/fabric/scheduler stay
geometry-agnostic.  The default constructed from a `ModelConfig`
(:func:`geometry_for`) reproduces the historical layout bit-for-bit,
which is what keeps the whole PR 1–8 single-group test surface
unchanged.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PageGeometry:
    """Shape and growth law of one model group's physical pages.

    ``k_block`` / ``v_block`` are the trailing array dims of one page;
    the pool materializes ``(num_layers, total_pages) + k_block`` and
    ``(num_layers, total_pages) + v_block``.  They may differ (MLA) —
    nothing in the stack may assume ``v = zeros_like(k)``.

    ``fixed_pages`` non-None marks a constant-footprint geometry: a
    "sequence" owns exactly that many pages from birth and never grows
    (SSM state, encoder K/V).  ``shareable`` gates the prefix trie and
    CoW forks — non-shareable groups fork by copying state into fresh
    pages instead of refcounting a chain.
    """

    kind: str
    page_size: int                       # tokens per page (growth unit)
    num_layers: int
    itemsize: int                        # bytes per element
    k_block: tuple[int, ...]
    v_block: tuple[int, ...]
    shareable: bool = True
    fixed_pages: int | None = None

    def __post_init__(self):
        assert self.page_size >= 1 and self.num_layers >= 1
        assert self.itemsize >= 1 and self.k_block and self.v_block
        if self.fixed_pages is not None:
            assert self.fixed_pages >= 1

    # -- the three facts the stack consumes -----------------------------------

    @property
    def page_bytes(self) -> int:
        """Physical bytes of one page across all layers (k + v arrays)."""
        return ((math.prod(self.k_block) + math.prod(self.v_block))
                * self.itemsize * self.num_layers)

    @property
    def grows(self) -> bool:
        """Whether sequences of this geometry append pages as they decode."""
        return self.fixed_pages is None

    def pages_for_tokens(self, tokens: int) -> int:
        """Growth law: pages a sequence of ``tokens`` tokens occupies.
        Constant-footprint geometries hold ``fixed_pages`` regardless."""
        if self.fixed_pages is not None:
            return self.fixed_pages
        return -(-int(tokens) // self.page_size)

    def array_shapes(self, total_pages: int) -> tuple[tuple[int, ...],
                                                      tuple[int, ...]]:
        """(k_pool shape, v_pool shape) for a pool of ``total_pages``."""
        lead = (self.num_layers, int(total_pages))
        return lead + self.k_block, lead + self.v_block


# -- concrete geometries -------------------------------------------------------

def paged_kv_geometry(cfg, page_size: int) -> PageGeometry:
    """Standard dense-transformer paged K/V: symmetric
    ``[page_size, nkv, hd]`` blocks.  ``page_bytes`` reduces to the
    historical ``2 * page_size * nkv * hd * itemsize * num_layers``."""
    block = (page_size, cfg.num_kv_heads, cfg.head_dim_)
    return PageGeometry(
        kind="paged_kv", page_size=page_size, num_layers=cfg.num_layers,
        itemsize=jnp.dtype(cfg.compute_dtype).itemsize,
        k_block=block, v_block=block, shareable=True)


def mla_latent_geometry(cfg, page_size: int) -> PageGeometry:
    """MLA latent-compressed K/V (arXiv:2412.19437): per token the cache
    holds one shared rope key (``qk_rope_head_dim``) in the k array and
    one latent vector (``kv_lora_rank``) in the v array — asymmetric
    widths, far below ``2 * nkv * hd``."""
    assert cfg.mla is not None, f"{cfg.name}: no MLA config"
    return PageGeometry(
        kind="mla_latent", page_size=page_size, num_layers=cfg.num_layers,
        itemsize=jnp.dtype(cfg.compute_dtype).itemsize,
        k_block=(page_size, 1, cfg.mla.qk_rope_head_dim),
        v_block=(page_size, 1, cfg.mla.kv_lora_rank), shareable=True)


def ssm_state_geometry(cfg) -> PageGeometry:
    """Constant-size recurrent state as a 1-page never-growing
    "sequence".  The page migrates under BWAP like any other, but the
    growth law pins it at one page and the shareability class is off:
    recurrent state is mutated in place every step, so a fork must COPY
    the state into a fresh page — a CoW chain would alias live state.

    Mamba-style (``cfg.ssm``): k holds the ``[d_inner, state_dim]`` SSM
    state, v the ``[conv_dim, d_inner]`` conv tail.  xLSTM
    (``cfg.xlstm``): k holds per-head ``[dh, dh]`` mLSTM matrix memory,
    v the ``[dh]`` normalizer."""
    itemsize = jnp.dtype(cfg.compute_dtype).itemsize
    if cfg.xlstm is not None:
        nh, dh = cfg.num_heads, cfg.head_dim_
        k_block, v_block = (1, nh, dh * dh), (1, nh, dh)
    else:
        assert cfg.ssm is not None, f"{cfg.name}: no SSM/xLSTM config"
        inner = cfg.ssm.expand * cfg.d_model
        k_block = (1, inner, cfg.ssm.state_dim)
        v_block = (1, cfg.ssm.conv_dim, inner)
    return PageGeometry(
        kind="ssm_state", page_size=1, num_layers=cfg.num_layers,
        itemsize=itemsize, k_block=k_block, v_block=v_block,
        shareable=False, fixed_pages=1)


def encoder_kv_geometry(cfg, page_size: int) -> PageGeometry:
    """Read-only encoder cross-attention K/V (whisper): written once by
    the encoder, then a fixed ``ceil(enc_frames / page_size)`` pages
    shared by every decode session of the same utterance — a shareable
    tier like the prefix trie, but with a constant footprint."""
    assert cfg.enc_dec, f"{cfg.name}: not an encoder-decoder config"
    block = (page_size, cfg.num_kv_heads, cfg.head_dim_)
    return PageGeometry(
        kind="encoder_kv", page_size=page_size, num_layers=cfg.enc_layers,
        itemsize=jnp.dtype(cfg.compute_dtype).itemsize,
        k_block=block, v_block=block, shareable=True,
        fixed_pages=-(-cfg.enc_frames // page_size))


def geometry_for(cfg, page_size: int) -> PageGeometry:
    """Default geometry for a model config's *decode-path* cache.

    MLA configs get the latent layout, pure-SSM families the 1-page
    state, everything else (dense/vlm/hybrid attention, whisper
    *decoder* self-attention) the standard paged K/V — so every pool
    constructed before this module existed resolves to a geometry whose
    shapes and ``page_bytes`` are bit-identical to the old hardcoded
    layout.  Encoder K/V is never a default: it is a second cache
    alongside the decoder's, requested explicitly via
    :func:`encoder_kv_geometry`."""
    if cfg.mla is not None:
        return mla_latent_geometry(cfg, page_size)
    if cfg.family == "ssm":
        return ssm_state_geometry(cfg)
    return paged_kv_geometry(cfg, page_size)
