"""hymba-1.5b [hybrid]: 32L d=1600 25H (kv 5) ff 5504, vocab 32001,
parallel attention + mamba heads, SSM state 16, sliding window 1024 with
3 global layers (first/middle/last). [arXiv:2411.13676; hf-verified]"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", num_layers=32, d_model=1600,
    num_heads=25, num_kv_heads=5, d_ff=5504, vocab_size=32001,
    head_dim=64, ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2),
    sliding_window=1024, global_layers=(0, 15, 31))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="hybrid", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        ssm=SSMConfig(state_dim=4, conv_dim=4, expand=2),
        sliding_window=16, global_layers=(0, 3))
