"""gemma-2b [dense]: 18L d=2048 8H MQA (kv 1) ff 16384, vocab 256000, GeGLU,
head_dim 256. [arXiv:2403.08295; hf-verified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense", num_layers=18, d_model=2048,
    num_heads=8, num_kv_heads=1, d_ff=16384, vocab_size=256000,
    head_dim=256, act="geglu", embed_scale=True, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-smoke", family="dense", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=1, d_ff=128, vocab_size=256, head_dim=32,
        act="geglu", embed_scale=True, tie_embeddings=True)
