"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (kv 8) expert-ff 512,
vocab 49155, 40 experts top-8. [hf:ibm-granite; hf-verified]"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=40, top_k=8, d_expert=512),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=32, vocab_size=256,
        tie_embeddings=True,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=32))
