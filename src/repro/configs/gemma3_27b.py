"""gemma3-27b [dense]: 62L d=5376 32H (kv 16) ff 21504, vocab 262144,
5:1 local:global sliding window, GeGLU, head_dim 128.
[hf:google/gemma-3; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense", num_layers=62, d_model=5376,
    num_heads=32, num_kv_heads=16, d_ff=21504, vocab_size=262144,
    head_dim=128, act="geglu", embed_scale=True, tie_embeddings=True,
    sliding_window=1024, global_every=6, rope_theta=1e6,
    seq_shard_activations=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense", num_layers=6, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        act="geglu", embed_scale=True, tie_embeddings=True,
        sliding_window=16, global_every=6)
