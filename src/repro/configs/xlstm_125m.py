"""xlstm-125m [ssm]: 12L d=768 4H, vocab 50304, mLSTM blocks with sLSTM at
the 1/4 and 3/4 positions (xLSTM[7:1]-style mix). [arXiv:2405.04517;
unverified]"""

from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", num_layers=12, d_model=768,
    num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304,
    xlstm=XLSTMConfig(slstm_at=(3, 9)))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm", num_layers=4, d_model=64,
        num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=256,
        xlstm=XLSTMConfig(slstm_at=(1,)))
