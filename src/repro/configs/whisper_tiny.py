"""whisper-tiny [audio]: 4+4L enc-dec d=384 6H ff 1536, vocab 51865,
conv frontend STUB (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio", num_layers=4, d_model=384,
    num_heads=6, num_kv_heads=6, d_ff=1536, vocab_size=51865,
    norm="layernorm", act="gelu", use_rope=False, enc_dec=True,
    enc_layers=4, enc_frames=1500, frontend="audio_stub", max_seq=65536,
    train_accum_override=8)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        norm="layernorm", act="gelu", use_rope=False, enc_dec=True,
        enc_layers=2, enc_frames=32, frontend="audio_stub", max_seq=512)
