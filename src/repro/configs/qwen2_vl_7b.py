"""qwen2-vl-7b [vlm]: 28L d=3584 28H (kv 4) ff 18944, vocab 152064, M-RoPE,
dynamic-resolution vision frontend as a STUB (input_specs provides
precomputed patch embeddings). [arXiv:2409.12191; hf-verified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm", num_layers=28, d_model=3584,
    num_heads=28, num_kv_heads=4, d_ff=18944, vocab_size=152064,
    qkv_bias=True, mrope=True, mrope_sections=(16, 24, 24),
    frontend="vision_stub", vision_patches=1024, rope_theta=1e6,
    seq_shard_activations=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        qkv_bias=True, mrope=True, mrope_sections=(2, 3, 3),
        frontend="vision_stub", vision_patches=8)
