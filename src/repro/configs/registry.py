"""Architecture registry, input shapes, and per-cell input specs.

The 10 assigned architectures are selectable via ``--arch <id>``; each pairs
with the 4 LM shapes (train_4k / prefill_32k / decode_32k / long_500k).
``long_500k`` requires sub-quadratic sequence state and only runs for the
SSM/hybrid families (skips are explicit, with reasons — DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.lm import LM
from repro.models.whisper import EncDecLM

ARCH_IDS = [
    "granite-moe-3b-a800m",
    "deepseek-v3-671b",
    "qwen2-0.5b",
    "internlm2-20b",
    "gemma3-27b",
    "gemma-2b",
    "hymba-1.5b",
    "xlstm-125m",
    "qwen2-vl-7b",
    "whisper-tiny",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).smoke_config()


def make_model(cfg: ModelConfig):
    return EncDecLM(cfg) if cfg.enc_dec else LM(cfg)


def cell_supported(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """Whether (arch x shape) is a valid dry-run cell; reason if skipped."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("quadratic full attention at 524288 tokens "
                       "(assignment: long_500k only for SSM/hybrid)")
    return True, ""


def _scale_batch(cfg: ModelConfig, shape: Shape,
                 scale: float) -> tuple[int, int]:
    b = max(1, int(shape.global_batch * scale))
    s = max(8, int(shape.seq_len * scale)) if scale < 1 else shape.seq_len
    return b, s


def input_specs(cfg: ModelConfig, shape: Shape, *, batch: int | None = None,
                seq: int | None = None) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the step
    (weak-type-correct, shardable, no device allocation)."""
    b = batch if batch is not None else shape.global_batch
    s = seq if seq is not None else shape.seq_len
    i32 = jnp.int32
    cdt = jnp.dtype(cfg.compute_dtype)
    sds = jax.ShapeDtypeStruct

    if shape.kind in ("train", "prefill"):
        if cfg.enc_dec:
            return {"frames": sds((b, cfg.enc_frames, cfg.d_model), cdt),
                    "tokens": sds((b, s), i32)}
        batch_d: dict[str, Any] = {}
        if cfg.frontend == "vision_stub":
            p = min(cfg.vision_patches, s // 2)
            batch_d["patch_embeds"] = sds((b, p, cfg.d_model), cdt)
            batch_d["tokens"] = sds((b, s - p), i32)
            if cfg.mrope:
                batch_d["positions"] = sds((3, b, s), i32)
        else:
            batch_d["tokens"] = sds((b, s), i32)
        return batch_d

    # decode: one new token against a cache of capacity == seq_len
    model = make_model(cfg)
    if cfg.enc_dec:
        cache = jax.eval_shape(
            lambda: model.init_cache(None, b, s, cfg.enc_frames))
    else:
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return {"cache": cache,
            "tokens": sds((b, 1), i32),
            "position": sds((), i32)}


def step_fn(cfg: ModelConfig, shape: Shape, model=None):
    """The pure function the dry-run lowers for this cell (no optimizer —
    train/trainstep.py builds the full train_step with optimizer update)."""
    model = model or make_model(cfg)
    if shape.kind == "train":
        def train_loss(params, batch):
            return model.loss(params, batch)
        return train_loss
    if shape.kind == "prefill":
        def prefill(params, batch):
            return model.prefill(params, batch)
        return prefill

    def decode(params, batch):
        return model.decode_step(params, batch["cache"], batch["tokens"],
                                 batch["position"])
    return decode
