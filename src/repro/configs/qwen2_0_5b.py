"""qwen2-0.5b [dense]: 24L d=896 14H (kv 2) ff 4864, vocab 151936, QKV bias,
tied embeddings. [arXiv:2407.10671; hf-verified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense", num_layers=24, d_model=896,
    num_heads=14, num_kv_heads=2, d_ff=4864, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1e6)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke", family="dense", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
        qkv_bias=True, tie_embeddings=True)
