"""deepseek-v3-671b [moe]: 61L d=7168 128H MLA, 1 shared + 256 routed top-8,
first 3 layers dense (d_ff 18432), MTP depth 1, vocab 129280.
[arXiv:2412.19437; hf-verified]"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=2048,
    vocab_size=129280,
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048,
                  num_shared_experts=1, first_k_dense=3, d_ff_dense=18432),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    mtp_depth=1,
    param_dtype="bfloat16",   # 671B: bf16 params + 8-bit Adam (optimizer.py)
    seq_shard_activations=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-smoke", family="moe", num_layers=5, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=48, vocab_size=256,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=32,
                      num_shared_experts=1, first_k_dense=1, d_ff_dense=48),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        mtp_depth=1)
