"""BWAP-weighted optimizer-state placement (weighted ZeRO).

Two placement problems from the paper mapped onto the optimizer state:

1. **Tiered placement** (Yu et al. [43], the work BWAP generalizes): shard
   optimizer pages between per-chip HBM (fast, scarce) and host DRAM over
   PCIe (slow, abundant). Eq. 1's max-parallel-transfer time says the split
   should follow w_d ∝ bw_d, NOT all-HBM-until-full: streaming the update
   from both tiers concurrently hides the slower tier behind the faster one.

2. **Heterogeneous rank weighting** (Eq. 5): when DP ranks see asymmetric
   bandwidth toward a worker partition (co-scheduled neighbours, cross-pod
   ranks), per-rank shard sizes follow minbw(rank) — Alg. 1 assigns pages.

Both emit page tables consumed by the update step; `stream_update_time`
is the Eq.-1 cost model used by benchmarks/bwap_tpu.py, and
`weighted_allgather` is a runnable shard_map demonstration (tests run it on
8 host devices).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import interleave
from repro.placement import policy as placement_policy


@dataclasses.dataclass(frozen=True)
class TierSpec:
    name: str
    bw_gbps: float        # stream bandwidth toward the compute chip
    capacity_pages: int


def _ctx(num_pages: int, tiers: list[TierSpec],
         dwp: float = 0.0) -> placement_policy.PlacementContext:
    return placement_policy.PlacementContext(
        bandwidths=np.asarray([t.bw_gbps for t in tiers]),
        num_pages=num_pages, workers=(0,), dwp=dwp,
        capacities=np.asarray([t.capacity_pages for t in tiers]))


def weighted_page_partition(num_pages: int, weights) -> np.ndarray:
    """Alg. 1 page table: page -> owner (tier or rank)."""
    return interleave.weighted_interleave(num_pages,
                                          interleave.normalize(weights))


def tier_split(num_pages: int, tiers: list[TierSpec],
               dwp: float = 0.0) -> np.ndarray:
    """Optimizer pages over memory tiers: the registry's ``bwap_dwp`` policy
    (canonical weights ∝ bw, DWP shifts mass toward tier 0 — the
    worker-local HBM); capacity overflow spills ∝ bandwidth, keeping Eq.-1
    transfer times balanced under pressure."""
    return placement_policy.assign("bwap_dwp", _ctx(num_pages, tiers, dwp))


def stream_update_time(assignment: np.ndarray, tiers: list[TierSpec],
                       page_bytes: int) -> float:
    """Eq. 1: the update step streams pages from all tiers in parallel;
    completion = the slowest tier's transfer (read + write back)."""
    t = 0.0
    for i, tier in enumerate(tiers):
        n = int((assignment == i).sum())
        t = max(t, 2.0 * n * page_bytes / (tier.bw_gbps * 1e9))
    return t


def uniform_split(num_pages: int, tiers: list[TierSpec]) -> np.ndarray:
    """The uniform-workers analogue: spread evenly over tiers (subject to
    capacity), ignoring bandwidth."""
    return placement_policy.assign("uniform", _ctx(num_pages, tiers))


def hbm_first_split(num_pages: int, tiers: list[TierSpec]) -> np.ndarray:
    """The first-touch analogue: fill the fastest tier, then spill."""
    return placement_policy.assign("local_first", _ctx(num_pages, tiers))


# ---------------------------------------------------------------------------
# Runnable weighted all-gather (shard_map) — heterogeneous rank shards
# ---------------------------------------------------------------------------

def weighted_allgather(x_pages, owner: np.ndarray, mesh, axis: str = "data"):
    """All-gather pages whose ownership follows a weighted page table.

    x_pages: [num_pages, page] array (each rank holds its owned pages,
    others zero); owner: [num_pages] rank ids. Returns the full table on
    every rank. Implementation: masked psum — communication volume is
    proportional to the pages actually owned, so weighted tables shift
    traffic exactly as the placement dictates.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    owner_dev = jnp.asarray(owner, jnp.int32)

    def body(xp):
        rank = jax.lax.axis_index(axis)
        mine = (owner_dev == rank)[:, None].astype(xp.dtype)
        return jax.lax.psum(xp * mine, axis)

    return shard_map(body, mesh=mesh, in_specs=P(None, None),
                     out_specs=P(None, None))(x_pages)
