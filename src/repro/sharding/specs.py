"""Sharding rules: pytree path -> PartitionSpec.

Rules name the *trailing* dims of each parameter kind, so stacked-layer
leading axes (scan groups) are handled uniformly. Every axis assignment is
divisibility-guarded (GSPMD/jit rejects uneven input shardings): if a dim
does not divide over the proposed mesh axes, the rule falls back (e.g.
granite's 40 experts fall back from expert-parallel to expert-TP over d_ff).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import dp_axes
from repro.models.config import ModelConfig

Tail = tuple  # trailing-dim spec entries (None | str | tuple[str, ...])


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    return int(np.prod([mesh.shape[a] for a in entry]))


def _fit(mesh, shape: tuple[int, ...], tail: Tail) -> P:
    """Pad the tail to ndim with leading Nones; drop non-dividing axes."""
    ndim = len(shape)
    tail = tuple(tail)[-ndim:] if len(tail) > ndim else tail
    full = (None,) * (ndim - len(tail)) + tuple(tail)
    out = []
    for dim, entry in zip(shape, full):
        if entry is not None and dim % _axis_size(mesh, entry) == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def _in_moe(path) -> bool:
    keys = {getattr(p, "key", None) for p in path}
    return "moe" in keys and "shared" not in keys


# trailing-dim rules per leaf name (col-parallel, row-parallel, replicated)
_COL = {"wq", "wk", "wv", "wq_b", "wkv_b", "w_up", "w_gate", "up", "wx",
        "in_proj", "w_dt", "w_bc", "skip", "conv_w", "proj"}
_ROW = {"wo", "w_down", "down", "out_proj"}
_VEC = {"bq", "bk", "bv", "b_dt", "gn", "d_skip", "b_if"}


def param_spec_for(cfg: ModelConfig, mesh, path, shape) -> P:
    name = _leaf_name(path)
    mp = "model"
    msize = mesh.shape[mp]
    # Attention TP must be HEAD-ALIGNED: sharding [*, n_heads*head_dim] is
    # only usable if whole heads land on each shard — otherwise GSPMD
    # reshards the [S,S] score tensors (observed: a 14 GiB all-reduce on
    # qwen2's 14 heads over model=16). Misaligned archs replicate attention
    # weights and parallelize attention over batch only.
    heads_ok = cfg.num_heads % msize == 0
    kv_ok = (cfg.num_kv_heads % msize == 0) and heads_ok
    if _in_moe(path) and name in ("w_gate", "w_up", "w_down"):
        # expert-parallel: E over (data, model) when it divides (deepseek's
        # 256e — required to fit), else E over model (granite's padded 48e),
        # else expert-TP over the FFN dim.
        e = shape[-3]
        if e % _axis_size(mesh, ("data", mp)) == 0:
            return _fit(mesh, shape, (("data", mp), None, None))
        if e % msize == 0:
            return _fit(mesh, shape, (mp, None, None))
        if name == "w_down":
            return _fit(mesh, shape, (None, mp, None))
        return _fit(mesh, shape, (None, None, mp))
    if name == "embed":
        return _fit(mesh, shape, (mp, None))
    if name == "head":
        return _fit(mesh, shape, (None, mp))
    if name == "router":
        return P(*(None,) * len(shape))
    if name == "a_log":
        return _fit(mesh, shape, (mp, None))
    if name in ("wq", "wq_b", "wkv_b"):
        return _fit(mesh, shape, (None, mp)) if heads_ok else \
            P(*(None,) * len(shape))
    if name in ("wk", "wv"):
        # reference path repeats KV to full query heads, so KV projections
        # can stay sharded only when the *query* heads align too
        return _fit(mesh, shape, (None, mp)) if kv_ok else \
            P(*(None,) * len(shape))
    if name == "wo":
        return _fit(mesh, shape, (mp, None)) if heads_ok else \
            P(*(None,) * len(shape))
    if name == "bq":
        return _fit(mesh, shape, (mp,)) if heads_ok else \
            P(*(None,) * len(shape))
    if name in ("bk", "bv"):
        return _fit(mesh, shape, (mp,)) if kv_ok else \
            P(*(None,) * len(shape))
    if name in _COL:
        return _fit(mesh, shape, (None, mp))
    if name in _ROW:
        return _fit(mesh, shape, (mp, None))
    if name in _VEC:
        return _fit(mesh, shape, (mp,))
    return P(*(None,) * len(shape))   # norms, small tensors: replicated


def param_shardings(cfg: ModelConfig, mesh, param_specs) -> Any:
    def f(path, leaf):
        return NamedSharding(mesh, param_spec_for(cfg, mesh, path,
                                                  leaf.shape))
    return jax.tree_util.tree_map_with_path(f, param_specs)


# ---------------------------------------------------------------------------
# batch / cache / state shardings
# ---------------------------------------------------------------------------

def batch_shardings(cfg: ModelConfig, mesh, batch_specs) -> Any:
    dp = dp_axes(mesh)

    def f(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        if name == "position":
            return NamedSharding(mesh, P())
        if name == "positions":           # [3, B, S]
            return NamedSharding(mesh, _fit(mesh, shape, (None, dp, None)))
        if name in ("tokens", "frames", "patch_embeds"):
            return NamedSharding(mesh, _fit(mesh, shape,
                                            (dp,) + (None,) * (len(shape)
                                                               - 1)))
        return cache_leaf_sharding(cfg, mesh, path, leaf)
    return jax.tree_util.tree_map_with_path(f, batch_specs)


def cache_leaf_sharding(cfg: ModelConfig, mesh, path, leaf):
    """KV caches / SSM states: batch over dp when divisible; otherwise the
    long-context axis (cache capacity) spreads over dp; heads/inner over
    model when divisible, else capacity over model too."""
    dp = dp_axes(mesh)
    name = _leaf_name(path)
    shape = leaf.shape
    mp = "model"

    def fit_first(cands: list[Tail]) -> P:
        """Pick the candidate with the highest shard degree — taking the
        first partial fit left internlm2's 825 GB KV cache 16-way (151 GiB/
        device) when a 256-way candidate was next in line."""
        best, best_deg = P(*(None,) * len(shape)), 1
        for tail in cands:
            p = _fit(mesh, shape, tail)
            deg = 1
            for entry in p:
                if entry is not None:
                    deg *= _axis_size(mesh, entry)
            if deg > best_deg:
                best, best_deg = p, deg
        return best

    if name in ("k", "v"):          # [L?, B, C, nkv, hd]
        return NamedSharding(mesh, fit_first(
            [(dp, None, mp, None), (dp, mp, None, None),
             (None, (dp + (mp,)), None, None), (None, dp, None, None)]))
    if name in ("ckv", "kpe"):      # [L?, B, C, r] (MLA latent)
        return NamedSharding(mesh, fit_first(
            [(dp, mp, None), (None, (dp + (mp,)), None), (None, dp, None)]))
    if name in ("ck", "cv"):        # whisper cross KV [B, F, nkv, hd]
        return NamedSharding(mesh, fit_first(
            [(dp, None, mp, None), (dp, None, None, None)]))
    if name == "pos":               # [L?, B, C]
        return NamedSharding(mesh, fit_first(
            [(dp, None), (None, dp + (mp,)), (None, dp)]))
    if name == "conv":              # [L?, B, K-1, inner]
        return NamedSharding(mesh, fit_first(
            [(dp, None, mp), (None, None, mp)]))
    if name == "h":                 # mamba [L?, B, inner, state]
        return NamedSharding(mesh, fit_first(
            [(dp, mp, None), (None, mp, None)]))
    if name in ("c", "n", "m"):     # mlstm/slstm states [L?, B, H, ...]
        return NamedSharding(mesh, fit_first(
            [(dp, mp) + (None,) * (len(shape) - 2),
             (dp,) + (None,) * (len(shape) - 1)]))
    return NamedSharding(mesh, P(*(None,) * len(shape)))


def kv_pool_spec(cfg: ModelConfig, mesh, page_size: int) -> P:
    """Layout spec for a paged KV pool ``[L, pages, page_size, nkv, hd]``.

    KV heads shard over 'model' when they divide; the page axis stays
    replicated — the page table, not GSPMD, is the placement mechanism
    there. This is the layout metadata a fabric stamps onto peer page-range
    exports (persistence tier, DESIGN.md §9) so an importer can check the
    bytes were produced under a compatible sharding.
    """
    shape = (cfg.num_layers, 1, page_size, cfg.num_kv_heads, cfg.head_dim_)
    return _fit(mesh, shape, (None, None, None, "model", None))


# ---------------------------------------------------------------------------
# ZeRO-1 optimizer-state sharding
# ---------------------------------------------------------------------------

def zero_spec(mesh, pspec: P, shape: tuple[int, ...]) -> P:
    """Extend a param spec: shard the largest free dim over 'data'
    (uniform ZeRO baseline; the BWAP-weighted variant lives in zero.py).
    No-op if the spec already consumes the data axis (e.g. deepseek expert
    tensors sharded E x ('data','model'))."""
    entries = list(pspec) + [None] * (len(shape) - len(pspec))
    used: set[str] = set()
    for entry in entries:
        if isinstance(entry, str):
            used.add(entry)
        elif entry is not None:
            used.update(entry)
    if "data" in used:
        return P(*entries)
    data = mesh.shape["data"]
    best, best_dim = -1, -1
    for i, (dim, entry) in enumerate(zip(shape, entries)):
        if entry is None and dim % data == 0 and dim > best:
            best, best_dim = dim, i
    if best_dim >= 0:
        entries[best_dim] = "data"
    return P(*entries)


def grad_shardings(cfg: ModelConfig, mesh, param_specs) -> Any:
    """ZeRO-sharded gradient layout (reduce-scattered accumulator for the
    microbatch loop): param spec + 'data' extension."""
    def f(path, leaf):
        pspec = param_spec_for(cfg, mesh, path, leaf.shape)
        return NamedSharding(mesh, zero_spec(mesh, pspec, leaf.shape))
    return jax.tree_util.tree_map_with_path(f, param_specs)


def opt_shardings(cfg: ModelConfig, mesh, opt_state_specs) -> Any:
    """Shardings for the optimizer-state pytree (init_opt_state layout).

    fp32 moments / master params: param spec + ZeRO 'data' extension.
    int8 block-quantized moments ({"q","scale"}): flat block dim sharded over
    as many mesh axes as divide (671B-scale states must spread over the whole
    pod, not just the data axis).
    """
    def f(path, leaf):
        name = _leaf_name(path)
        if name == "step":
            return NamedSharding(mesh, P())
        if name in ("q", "scale"):
            # sharding-aligned layout: q [*param_lead, nb, block],
            # scale [*param_lead, nb] — inherit the PARAM's spec on the
            # leading dims (path[:-1] names the param), extend with None
            parent = [p for p in path if hasattr(p, "key")
                      and str(p.key) not in ("q", "scale", "m", "v")]
            extra = 2 if name == "q" else 1
            lead = leaf.shape[:len(leaf.shape) - extra]
            if lead:
                param_shape = lead + (int(np.prod(leaf.shape[len(lead):])),)
                pspec = param_spec_for(cfg, mesh, tuple(parent), param_shape)
                entries = (list(pspec) + [None] * len(param_shape))[
                    :len(param_shape) - 1]
                spec = P(*entries, *(None,) * extra)
                ps = zero_spec(mesh, spec, leaf.shape)
                return NamedSharding(mesh, ps)
            # flat fallback: shard the block dim over whatever divides
            for axes in (("pod", "data", "model"), ("data", "model"),
                         ("data",), ("model",)):
                axes = tuple(a for a in axes if a in mesh.axis_names)
                if axes and leaf.shape[0] % _axis_size(mesh, axes) == 0:
                    return NamedSharding(
                        mesh, P(axes, *(None,) * (len(leaf.shape) - 1)))
            return NamedSharding(mesh, P(*(None,) * len(leaf.shape)))
        pspec = param_spec_for(cfg, mesh, path, leaf.shape)
        return NamedSharding(mesh, zero_spec(mesh, pspec, leaf.shape))
    return jax.tree_util.tree_map_with_path(f, opt_state_specs)
