"""Benchmark harness — one section per paper table/figure plus the TPU
adaptation and dry-run/roofline aggregation.

Run: PYTHONPATH=src python -m benchmarks.run [--skip-dryrun-table]
Writes paper-table JSON to benchmarks/results/, the gate-carrying
BENCH_*.json artifacts to the repo root (benchmarks.artifacts contract,
checked at the end), and a human summary to stdout.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def _dump(name: str, data) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(
        json.dumps(data, indent=1, default=float))


def _hdr(title: str):
    print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-dryrun-table", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from benchmarks import bwap_tpu, paper_claims

    t0 = time.time()

    _hdr("Fig. 1b — placement policies vs offline hill-climb (machine A)")
    f1 = paper_claims.fig1b_placement(args.seed)
    _dump("fig1b", f1)
    print(f"{'app':6s} {'first_touch':>11s} {'autonuma':>9s} "
          f"{'unif_workers':>12s} {'unif_all':>9s}  (perf normalized to "
          f"hill-climb optimum = 1.0)")
    for app, row in f1.items():
        print(f"{app:6s} {row['first_touch']:11.3f} {row['autonuma']:9.3f} "
              f"{row['uniform_workers']:12.3f} {row['uniform_all']:9.3f}")
    gaps = [1 - max(r['uniform_workers'], r['uniform_all'])
            for r in f1.values()]
    print(f"-> uniform policies leave {min(gaps) * 100:.0f}%.."
          f"{max(gaps) * 100:.0f}% on the table vs the hill-climbed optimum "
          "(paper Fig. 1b claim)")

    _hdr("Figs. 2-3 — BWAP speedups vs uniform-workers (co-scheduled)")
    f23 = paper_claims.fig23_speedups(args.seed)
    _dump("fig23", f23)
    best_bwap = 1.0
    best_vs_ft = 1.0
    for key, apps in f23.items():
        line = f"{key:14s} "
        for app, r in apps.items():
            line += f"{app}:{r['bwap']:.2f}x "
            best_bwap = max(best_bwap, r["bwap"])
            # bwap speedup vs first-touch = (t_ft/t_uw) * (t_uw/t_bwap)
            best_vs_ft = max(best_vs_ft,
                             r["bwap"] / max(r["first_touch"], 1e-9))
        print(line)
    print(f"-> max BWAP speedup vs uniform-workers: {best_bwap:.2f}x "
          f"(paper: up to 1.66x); vs first-touch: {best_vs_ft:.2f}x "
          f"(paper: up to 4x)")

    _hdr("Table II — ideal DWP values found by the iterative search")
    t2 = paper_claims.table2_dwp(args.seed)
    _dump("table2", t2)
    for key, apps in t2.items():
        print(f"{key:14s} " + "  ".join(f"{a}:{v:.0%}"
                                        for a, v in apps.items()))

    _hdr("Fig. 4 — DWP search: stall-rate convexity & tuner accuracy")
    f4 = paper_claims.fig4_dwp_curve(args.seed)
    _dump("fig4", f4)
    for key, r in f4.items():
        print(f"{key}: static opt DWP={r['static_opt_dwp']:.1f} "
              f"tuner={r['tuner_dwp']:.1f} within-1-step="
              f"{r['within_one_step']} time/stall corr="
              f"{r['time_stall_correlation']:.3f}")

    _hdr("§IV-B — DWP tuner overhead (paper: <= 4%)")
    ov = paper_claims.overhead(args.seed)
    _dump("overhead", ov)
    for app, r in ov.items():
        print(f"{app:6s} overhead {r['overhead_pct']:5.2f}%")
    print(f"-> max overhead "
          f"{max(r['overhead_pct'] for r in ov.values()):.2f}%")

    _hdr("Observation 3 — cluster-scaled weight variance reduction")
    o3 = paper_claims.observation3_scaling(args.seed)
    _dump("observation3", o3)
    print(f"per-node CV raw={o3['cv_raw']:.3f} scaled={o3['cv_scaled']:.3f} "
          f"reduction={o3['reduction']:.0%} (paper: ~1/3)")

    _hdr("BWAP on TPU memory domains (DESIGN.md §2)")
    kv = bwap_tpu.kv_placement()
    _dump("tpu_kv", kv)
    print(f"KV decode read time: uniform-all "
          f"{kv['read_time_uniform_all_ms']:.2f} ms, hbm-spill-host "
          f"{kv['read_time_hbm_spill_host_ms']:.2f} ms, BWAP "
          f"{kv['read_time_bwap_ms']:.2f} ms "
          f"(x{kv['speedup_vs_uniform']:.2f} vs uniform, "
          f"x{kv['speedup_vs_spill']:.2f} vs spill)")
    ot = bwap_tpu.optimizer_tiers()
    _dump("tpu_opt_tiers", ot)
    print(f"offloaded Adam step: uniform {ot['update_ms_uniform']:.1f} ms, "
          f"peer-first {ot['update_ms_peer_first_spill']:.1f} ms, BWAP "
          f"{ot['update_ms_bwap']:.1f} ms "
          f"(x{ot['speedup_vs_uniform']:.2f} / "
          f"x{ot['speedup_vs_peer_first']:.2f})")

    _hdr("Scheduler — goodput vs swap placement (oversubscribed KV)")
    from benchmarks import scheduler_bench
    # check=False: the sweep accepts arbitrary --seed values; the hard
    # goodput gate runs on the benchmark's own (CI) entry point
    scheduler_bench.compare(requests=8, max_new=12, seed=args.seed,
                            check=False)

    _hdr("Prefix sharing — peak KV footprint, reuse on vs off")
    scheduler_bench.prefix_compare(seed=args.seed, check=False)

    _hdr("Memory fabric — cross-tenant prefix tier + swap loans vs "
         "isolated partitions")
    # check=False: the sweep accepts arbitrary --seed values; the hard
    # >=1.2x best-effort-goodput gate runs on the benchmark's own (CI)
    # entry point. Emits BENCH_fabric.json.
    scheduler_bench.fabric_compare(seed=args.seed, check=False)

    _hdr("Persistence tier — warm vs cold restart TTFT (shared prefixes)")
    # check=False: the sweep accepts arbitrary --seed values; the hard
    # token-identity + >=1.3x TTFT gate runs on the benchmark's own (CI)
    # entry point. Emits BENCH_persist.json.
    scheduler_bench.persist_compare(seed=args.seed, check=False)

    _hdr("Compute-follows-data — micro-batch decode + hot-page re-homing "
         "vs global batching")
    # check=False: the sweep accepts arbitrary --seed values; the hard
    # token-identity + >=1.15x goodput gate runs on the benchmark's own
    # (CI) entry point. Emits BENCH_coda.json.
    scheduler_bench.coda_compare(seed=args.seed, check=False)

    _hdr("Speculative decode — steps saved vs greedy (token-identical)")
    from benchmarks import serve_bench
    # check=False: the sweep accepts arbitrary --seed values; the hard
    # token-identity + step-ratio gate runs on the benchmark's own (CI)
    # entry point. Emits BENCH_serve.json (goodput, acceptance rate,
    # decode steps saved, prefill forward tokens).
    serve_bench.speculative_compare(seed=args.seed, check=False)

    _hdr("Observatory — Eq.-1 calibration loop + tracing overhead")
    from benchmarks import obs_bench
    # check=False: the sweep accepts arbitrary --seed values; the hard
    # convergence + <5%-overhead gates run on the benchmark's own (CI)
    # entry point. Emits BENCH_obs.json.
    obs_bench.suite(seed=args.seed, check=False)

    _hdr("Placement runtime microbenchmarks (migration executor floor)")
    from benchmarks import placement_bench
    placement_bench.suite(pages=1024)

    if not args.skip_dryrun_table:
        _hdr("Dry-run + roofline aggregation")
        from benchmarks import roofline_table
        print(roofline_table.render())

    # every suite above must have landed its BENCH_*.json at the repo
    # root — a missing artifact fails the sweep (and the CI step)
    from benchmarks import artifacts
    artifacts.check()

    print(f"\n[benchmarks done in {time.time() - t0:.1f}s; JSON in "
          f"{RESULTS}]")


if __name__ == "__main__":
    main()
