"""Observability benchmark: calibration-loop convergence + tracing
overhead (DESIGN.md §10). Emits BENCH_obs.json (benchmarks.artifacts).

Two gates:

1. **Calibration loop.** The pool's analytic bandwidth profile is planted
   *wrong* (one slow domain 2x optimistic, another 2x pessimistic); a
   drift-ledger probe supplies per-domain measured transfer times from
   the ground-truth bandwidths (standing in for hardware counters). The
   ledger stages seconds-per-page samples and feeds ``fabric.calibrate``
   — after the run, ``bw_effective`` must sit within 10% of ground truth
   on every domain that carried traffic. Before calibration the planted
   error is 100%, so the gate proves the loop, not the initial profile.
   Runs twice: global batching and micro-batch decode (DESIGN.md §11) —
   the latter gates the per-launch drift attribution.

2. **Tracing overhead.** The scheduler-bench workload runs with the full
   observatory (tracer + metrics + heat) and without; the traced run must
   cost <5% extra wall time and produce token-identical outputs. Runs are
   interleaved and best-of-N to shed host noise; the drift probe is off
   in both so the virtual clock — and therefore the schedule — is
   bit-identical.

Run: PYTHONPATH=src python -m benchmarks.obs_bench
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from benchmarks import artifacts
from benchmarks.scheduler_bench import _domains, run_config
from repro.configs import registry
from repro.core.dwp import DWPConfig
from repro.models.lm import LM
from repro.obs import Observatory
from repro.scheduler import (KVSwapManager, PriorityClass, RequestScheduler,
                             SloSpec, WorkloadSpec, generate)
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BwapPagePool, MemoryDomain

# ground truth the probe measures against; the profile handed to the pool
# is planted wrong on the slow domains (hbm_peer 2x optimistic, host_dram
# 2x pessimistic) so the calibration loop has a real 100% error to close
BW_PROFILE = {"hbm_local": 819.0, "hbm_peer_1hop": 0.0025,
              "host_dram": 0.0004}
BW_TRUE = {"hbm_local": 819.0, "hbm_peer_1hop": 0.00125,
           "host_dram": 0.0008}
CAL_TOL = 0.10
OVERHEAD_TOL = 0.05
MIN_SAMPLES = 5          # a domain needs this many probe samples to gate


def _model():
    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    model = LM(cfg)
    return cfg, model.init(jax.random.PRNGKey(0))


def calibration_loop(seed: int = 0, check: bool = True,
                     micro_batch: bool = False) -> dict:
    """``micro_batch=True`` runs the same loop with per-domain decode
    launches (DESIGN.md §11): the ledger then bills each launch only for
    the domains it actually read (``observe_launches``), and convergence
    proves the per-launch attribution — a launch's bottleneck time
    credited to an idle domain would drag its ratio off truth."""
    cfg, params = _model()
    names = list(BW_PROFILE)
    domains = [
        MemoryDomain(names[0], 10, BW_PROFILE[names[0]], True),
        MemoryDomain(names[1], 24, BW_PROFILE[names[1]], False),
        MemoryDomain(names[2], 60, BW_PROFILE[names[2]], False),
    ]
    pool = BwapPagePool(cfg, domains, page_size=4,
                        dwp_config=DWPConfig(n=10 ** 6, c=1))
    bw_true = np.asarray([BW_TRUE[n] for n in names])
    bw_profile = np.asarray([BW_PROFILE[n] for n in names])

    def probe(kind, bytes_per_domain):
        # "hardware counters": per-domain seconds under the true bandwidths
        return np.asarray(bytes_per_domain) / (bw_true * 1e9)

    swap = KVSwapManager(pool, placement="bwap_canonical",
                         reserve_fraction=0.9)
    sched = RequestScheduler(pool, max_batch=4, prefill_token_budget=32,
                             default_max_new=12, swap=swap,
                             micro_batch=micro_batch)
    eng = ServeEngine(cfg, params, pool, scheduler=sched,
                      wall_clock=False, sim_step_s=0.01, rehome=False)
    obs = Observatory(pool, tracer=False, heat=False, probe=probe,
                      calibrate_every=2)
    trace = generate(WorkloadSpec(
        kind="poisson", num_requests=10, mean_interarrival_s=0.004,
        prompt_mean=14, prompt_max=28, max_new=12,
        vocab_size=cfg.vocab_size, seed=seed))
    for t in trace:
        eng.submit(t.prompt, max_new=t.max_new, arrival_s=t.arrival_s)
    steps = multi = 0
    while (eng.active or eng.waiting) and steps < 1500:
        info = eng.step()
        if info.get("launches", 0) > 1:
            multi += 1
        steps += 1

    s = obs.drift.summary()
    bw_eff = np.asarray(s["bw_effective_gbps"])
    rel_err = np.abs(bw_eff - bw_true) / bw_true
    err_before = np.abs(bw_profile - bw_true) / bw_true
    gated = [i for i in range(len(names))
             if s["domain_samples"][i] >= MIN_SAMPLES]
    row = {
        "domains": names,
        "bw_profile_gbps": [float(b) for b in bw_profile],
        "bw_true_gbps": [float(b) for b in bw_true],
        "bw_effective_gbps": [float(b) for b in bw_eff],
        "rel_err_before": [float(e) for e in err_before],
        "rel_err_after": [float(e) for e in rel_err],
        "gated_domains": [names[i] for i in gated],
        "observations": s["observations"],
        "calibrations": s["calibrations"],
        "domain_samples": s["domain_samples"],
        "ratio_p50": s["kinds"]["batch_read"]["ratio_p50"],
        "ratio_p95": s["kinds"]["batch_read"]["ratio_p95"],
        "finished": len(eng.finished),
        "requests": len(trace),
        "micro_batch": micro_batch,
        "multi_launch_steps": multi,
        "tolerance": CAL_TOL,
    }
    mode = "micro-batch" if micro_batch else "global"
    print(f"calibration ({mode}): {s['calibrations']} calibrations over "
          f"{s['observations']} observations, {len(eng.finished)}/"
          f"{len(trace)} requests"
          + (f", {multi} multi-launch steps" if micro_batch else ""))
    for i, n in enumerate(names):
        mark = "gated" if i in gated else f"{s['domain_samples'][i]} samples"
        print(f"  {n:14s} profile {bw_profile[i]:.5g} true {bw_true[i]:.5g} "
              f"-> effective {bw_eff[i]:.5g} GB/s  err "
              f"{err_before[i]:.0%} -> {rel_err[i]:.2%}  ({mark})")
    if check:
        assert len(eng.finished) == len(trace), "calibration run failed"
        # both planted-skew domains must have carried enough traffic to
        # gate — otherwise the bench proves nothing
        assert {names[1], names[2]} <= set(row["gated_domains"]), \
            f"planted domains not exercised: {row['gated_domains']}"
        if micro_batch:
            assert multi > 0, \
                "micro-batch calibration never partitioned a step"
        for i in gated:
            assert err_before[i] <= CAL_TOL or rel_err[i] < err_before[i], \
                f"{names[i]}: calibration made the error worse"
            assert rel_err[i] <= CAL_TOL, \
                (f"{names[i]}: bw_effective {bw_eff[i]:.5g} not within "
                 f"{CAL_TOL:.0%} of ground truth {bw_true[i]:.5g} "
                 f"(err {rel_err[i]:.1%})")
    return row


def _overhead_run(cfg, params, trace, *, with_obs: bool):
    """One scheduler-bench-shaped run; returns (wall_s, tokens, obs)."""
    pool = BwapPagePool(cfg, _domains(), page_size=4,
                        dwp_config=DWPConfig(n=10 ** 6, c=1))
    swap = KVSwapManager(pool, placement="bwap_canonical",
                         reserve_fraction=0.95)
    sched = RequestScheduler(
        pool, max_batch=6, prefill_token_budget=32,
        classes=[PriorityClass("interactive", 2,
                               SloSpec(ttft_s=0.3, tpot_s=0.03)),
                 PriorityClass("batch", 0,
                               SloSpec(ttft_s=1.5, tpot_s=0.06))],
        default_class="batch", default_max_new=16, swap=swap)
    eng = ServeEngine(cfg, params, pool, scheduler=sched,
                      wall_clock=False, sim_step_s=0.005)
    # no drift probe: the virtual clock (and thus the schedule) must be
    # bit-identical with and without the observatory
    obs = Observatory(pool, drift=False) if with_obs else None
    for t in trace:
        eng.submit(t.prompt, cls=t.cls, max_new=t.max_new,
                   arrival_s=t.arrival_s)
    t0 = time.monotonic()
    steps = 0
    while (eng.active or eng.waiting) and steps < 3000:
        eng.step()
        steps += 1
    wall = time.monotonic() - t0
    tokens = [tuple(s.tokens) for s in sorted(eng.finished,
                                              key=lambda s: s.sid)]
    return wall, tokens, obs


def overhead(seed: int = 0, repeats: int = 3, check: bool = True) -> dict:
    cfg, params = _model()
    trace = generate(WorkloadSpec(
        kind="bursty", num_requests=10, mean_interarrival_s=0.01,
        prompt_mean=24, prompt_max=40, max_new=16,
        vocab_size=cfg.vocab_size,
        class_mix=(("interactive", 0.25), ("batch", 0.75)), seed=seed))
    _overhead_run(cfg, params, trace, with_obs=False)   # warm jit caches
    base, traced = [], []
    tokens_base = tokens_traced = obs = None
    for _ in range(repeats):                            # interleaved pairs
        w, tokens_base, _n = _overhead_run(cfg, params, trace,
                                           with_obs=False)
        base.append(w)
        w, tokens_traced, obs = _overhead_run(cfg, params, trace,
                                              with_obs=True)
        traced.append(w)
    best_base, best_traced = min(base), min(traced)
    pct = (best_traced - best_base) / best_base * 100.0
    identical = tokens_base == tokens_traced
    tracer = obs.tracer
    preempted = sorted({e["tid"] - 1 for e in tracer.spans("swap_out")})
    span_counts = {n: len(tracer.spans(n))
                   for n in ("admit", "queued", "prefill", "decode",
                             "swap_out", "swap_in", "finish")}
    row = {
        "base_s": best_base, "traced_s": best_traced,
        "overhead_pct": pct, "token_identical": identical,
        "trace_events": len(tracer.events),
        "span_counts": span_counts,
        "preempted_requests": preempted,
        "heat_live_pages": obs.heat.live_pages(),
        "repeats": repeats, "tolerance_pct": OVERHEAD_TOL * 100.0,
    }
    print(f"overhead: base {best_base * 1e3:.0f} ms, traced "
          f"{best_traced * 1e3:.0f} ms ({pct:+.2f}%; best of {repeats}); "
          f"{row['trace_events']} trace events, token_identical="
          f"{identical}")
    print("  spans: " + " ".join(f"{k}={v}"
                                 for k, v in span_counts.items()))
    if check:
        assert identical, "tracing changed the decoded tokens"
        assert pct < OVERHEAD_TOL * 100.0, \
            f"tracing overhead {pct:.2f}% exceeds {OVERHEAD_TOL:.0%}"
        assert preempted, "workload produced no preemption to trace"
        sid = preempted[0]
        for name in ("admit", "prefill", "decode", "swap_out", "swap_in",
                     "finish"):
            assert tracer.spans(name, sid=sid), \
                f"preempted request {sid} missing {name!r} span"
    return row


def suite(seed: int = 0, check: bool = True) -> dict:
    cal = calibration_loop(seed=seed, check=check)
    cal_micro = calibration_loop(seed=seed, check=check, micro_batch=True)
    ov = overhead(seed=seed, check=check)
    out = {"calibration": cal, "calibration_micro": cal_micro,
           "overhead": ov}
    artifacts.dump("BENCH_obs.json", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-check", action="store_true")
    args = ap.parse_args()
    suite(seed=args.seed, check=not args.no_check)
