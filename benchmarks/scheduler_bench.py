"""Scheduler benchmark: goodput vs swap-placement policy.

The scenario the subsystem exists for: total KV footprint over-subscribes
``hbm_local`` (and the unreserved pool), so the run completes only through
preemption — and *where* the victims' pages park decides how much virtual
time the swap transfers burn. Three placements over the slow domains:

- ``bwap_canonical`` — spread ∝ slow-domain bandwidth (Eq. 2 over the slow
  subspace): transfers overlap across domains, Eq.-1 time ~ bytes / Σbw.
- ``local_first``    — everything into the fastest slow domain until full:
  one domain serializes the transfer, time ~ bytes / bw_max.
- ``uniform``        — equal spread: the slowest domain gates the batch.

Everything is virtual-clock deterministic (``wall_clock=False`` + a fixed
per-step compute stand-in), so the goodput ordering is a property of the
placement, not of host noise. Acceptance (ISSUE 2): zero failed requests in
every config, and BWAP-weighted swap beats ``local_first`` on goodput.

Run: PYTHONPATH=src python -m benchmarks.scheduler_bench [--requests 12]
Writes benchmarks/results/scheduler.json.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

import jax
import numpy as np

from repro.configs import registry
from repro.core.dwp import DWPConfig
from repro.models.lm import LM
from repro.scheduler import (KVSwapManager, PriorityClass, RequestScheduler,
                             SloSpec, WorkloadSpec, generate, total_kv_pages)
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BwapPagePool, MemoryDomain

RESULTS = pathlib.Path(__file__).resolve().parent / "results"

PLACEMENTS = ("bwap_canonical", "local_first", "uniform")


def _domains():
    """Slow bandwidths scaled so one sequence's swap transfer is
    commensurate with a few decode steps — placement quality must show up
    in the clock. Page size is 4 below, so sequences span 8-16 pages and
    the per-domain split has room to differ between policies."""
    return [
        MemoryDomain("hbm_local", 20, 819.0, True),
        MemoryDomain("hbm_peer_1hop", 30, 0.00125, False),
        MemoryDomain("hbm_pod1_dci", 30, 0.000325, False),
        MemoryDomain("host_dram", 80, 0.0004, False),
    ]


def run_config(placement: str, cfg, params, trace, *, max_new: int,
               sim_step_s: float = 0.005) -> dict:
    pool = BwapPagePool(cfg, _domains(), page_size=4,
                        dwp_config=DWPConfig(n=10 ** 6, c=1))  # tuner frozen
    swap = KVSwapManager(pool, placement=placement, reserve_fraction=0.95)
    sched = RequestScheduler(
        pool, max_batch=6, prefill_token_budget=32,
        classes=[PriorityClass("interactive", 2,
                               SloSpec(ttft_s=0.3, tpot_s=0.03)),
                 PriorityClass("batch", 0,
                               SloSpec(ttft_s=1.5, tpot_s=0.06))],
        default_class="batch", default_max_new=max_new, swap=swap)
    eng = ServeEngine(cfg, params, pool, scheduler=sched, wall_clock=False,
                      sim_step_s=sim_step_s)
    for t in trace:
        eng.submit(t.prompt, cls=t.cls, max_new=t.max_new,
                   arrival_s=t.arrival_s)
    steps = 0
    while (eng.active or eng.waiting) and steps < 3000:
        eng.step()
        steps += 1
    tel = pool.telemetry.snapshot()
    slo = sched.slo.summary(sched.now)
    return {
        "placement": placement,
        "finished": len(eng.finished),
        "requests": len(trace),
        "failed": len(trace) - len(eng.finished),
        "steps": steps,
        "makespan_s": sched.now,
        "swap_pages": tel["swap_outs"],
        "swap_seconds": tel["swap_seconds"],
        "goodput_tok_s": slo["goodput_tok_s"],
        "good_tokens": slo["good_tokens"],
        "classes": slo["classes"],
    }


def compare(requests: int = 12, max_new: int = 24, seed: int = 0,
            check: bool = True) -> dict:
    """Run every placement on one trace, print the table, enforce the
    acceptance criteria, dump JSON. Used by __main__ and benchmarks.run."""
    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = generate(WorkloadSpec(
        kind="bursty", num_requests=requests,
        mean_interarrival_s=0.01, prompt_mean=24, prompt_max=40,
        max_new=max_new, vocab_size=cfg.vocab_size,
        class_mix=(("interactive", 0.25), ("batch", 0.75)), seed=seed))
    hbm = _domains()[0].num_pages
    footprint = total_kv_pages(trace, 4)
    print(f"workload: {len(trace)} requests, KV footprint {footprint} pages "
          f"vs hbm_local {hbm} (x{footprint / hbm:.1f} oversubscribed)")

    rows = {}
    for placement in PLACEMENTS:
        r = run_config(placement, cfg, params, trace, max_new=max_new)
        rows[placement] = r
        print(f"  {placement:15s} goodput {r['goodput_tok_s']:7.1f} tok/s  "
              f"makespan {r['makespan_s']:.2f}s  swaps {r['swap_pages']:3d} "
              f"pages ({r['swap_seconds'] * 1e3:6.0f} ms)  "
              f"failed {r['failed']}")

    bwap = rows["bwap_canonical"]["goodput_tok_s"]
    lf = rows["local_first"]["goodput_tok_s"]
    print(f"-> BWAP-weighted swap vs local_first: "
          f"{bwap / max(lf, 1e-9):.3f}x goodput")
    if check:
        for placement, r in rows.items():
            assert r["failed"] == 0, \
                f"{placement}: {r['failed']} requests failed under swap"
        assert rows["bwap_canonical"]["swap_pages"] > 0, \
            "benchmark exerted no memory pressure — nothing was swapped"
        assert bwap > lf, (
            f"BWAP swap placement must beat local_first on goodput "
            f"(got {bwap:.1f} vs {lf:.1f} tok/s)")

    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "scheduler.json").write_text(
        json.dumps(rows, indent=1, default=float))
    print(f"[JSON in {RESULTS / 'scheduler.json'}]")
    return rows


def prefix_compare(requests: int = 12, max_new: int = 8, seed: int = 0,
                   check: bool = True) -> dict:
    """Prefix-reuse on/off over a shared-prefix heavy-tail trace: identical
    requests, identical virtual clock — the only difference is whether the
    page table's trie maps identical prompt prefixes onto shared physical
    pages. Reported: peak physical vs logical page footprint, prefill
    forward tokens (O(n) incremental prefill skips matched pages entirely),
    goodput. Acceptance (ISSUE 3): >= 1.5x peak-physical-footprint
    reduction with reuse on, token-identical outputs."""
    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = generate(WorkloadSpec(
        kind="heavy_tail", num_requests=requests,
        mean_interarrival_s=0.002, prompt_mean=6, prompt_max=24,
        max_new=max_new, vocab_size=cfg.vocab_size, seed=seed,
        prefix_len=32, prefix_groups=2, prefix_frac=0.9))

    def run(reuse: bool) -> dict:
        domains = [MemoryDomain("hbm_local", 96, 819.0, True),
                   MemoryDomain("hbm_peer_1hop", 96, 0.05, False),
                   MemoryDomain("host_dram", 96, 0.016, False)]
        pool = BwapPagePool(cfg, domains, page_size=4,
                            dwp_config=DWPConfig(n=10 ** 6, c=1))
        sched = RequestScheduler(pool, max_batch=requests,
                                 prefill_token_budget=64,
                                 default_max_new=max_new)
        eng = ServeEngine(cfg, params, pool, scheduler=sched,
                          wall_clock=False, sim_step_s=0.005,
                          prefix_reuse=reuse)
        for t in trace:
            eng.submit(t.prompt, max_new=t.max_new, arrival_s=t.arrival_s)
        peak_phys = peak_logical = steps = 0
        while (eng.active or eng.waiting) and steps < 3000:
            info = eng.step()
            pt = info.get("pagetable", {})
            peak_phys = max(peak_phys, pt.get("physical_pages", 0))
            peak_logical = max(peak_logical, pt.get("logical_pages", 0))
            steps += 1
        slo = sched.slo.summary(sched.now)
        return {
            "prefix_reuse": reuse,
            "finished": len(eng.finished),
            "steps": steps,
            "peak_physical_pages": peak_phys,
            "peak_logical_pages": peak_logical,
            "prefill_tokens_computed": eng.prefill_tokens_computed,
            "cow_faults": pool.table.cow_faults,
            "prefix_hit_pages": pool.table.prefix_hit_pages,
            "makespan_s": sched.now,
            "goodput_tok_s": slo["goodput_tok_s"],
            "tokens": {s.sid: list(s.tokens) for s in eng.finished},
        }

    on, off = run(True), run(False)
    ratio = off["peak_physical_pages"] / max(on["peak_physical_pages"], 1)
    for r in (on, off):
        print(f"  prefix_reuse={str(r['prefix_reuse']):5s} "
              f"peak phys {r['peak_physical_pages']:4d} pages "
              f"(logical {r['peak_logical_pages']:4d})  prefill fwd "
              f"{r['prefill_tokens_computed']:5d} tok  goodput "
              f"{r['goodput_tok_s']:7.1f} tok/s  cow {r['cow_faults']}")
    print(f"-> prefix reuse shrinks peak physical KV footprint "
          f"{ratio:.2f}x (prefill fwd tokens "
          f"{off['prefill_tokens_computed'] / max(on['prefill_tokens_computed'], 1):.2f}x)")
    if check:
        assert on["finished"] == off["finished"] == len(trace)
        assert on["tokens"] == off["tokens"], \
            "prefix sharing changed generated tokens"
        assert ratio >= 1.5, (
            f"prefix reuse must cut peak physical footprint >= 1.5x "
            f"(got {ratio:.2f}x)")
        assert on["prefill_tokens_computed"] < off["prefill_tokens_computed"]
    rows = {"reuse_on": {k: v for k, v in on.items() if k != "tokens"},
            "reuse_off": {k: v for k, v in off.items() if k != "tokens"},
            "footprint_reduction": ratio}
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "prefix_reuse.json").write_text(
        json.dumps(rows, indent=1, default=float))
    print(f"[JSON in {RESULTS / 'prefix_reuse.json'}]")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-prefix", action="store_true")
    args = ap.parse_args()
    compare(args.requests, args.new, args.seed)
    if not args.skip_prefix:
        print("\nprefix sharing — peak KV footprint, reuse on vs off")
        prefix_compare(seed=args.seed)


if __name__ == "__main__":
    main()
