"""Scheduler benchmark: goodput vs swap-placement policy.

The scenario the subsystem exists for: total KV footprint over-subscribes
``hbm_local`` (and the unreserved pool), so the run completes only through
preemption — and *where* the victims' pages park decides how much virtual
time the swap transfers burn. Three placements over the slow domains:

- ``bwap_canonical`` — spread ∝ slow-domain bandwidth (Eq. 2 over the slow
  subspace): transfers overlap across domains, Eq.-1 time ~ bytes / Σbw.
- ``local_first``    — everything into the fastest slow domain until full:
  one domain serializes the transfer, time ~ bytes / bw_max.
- ``uniform``        — equal spread: the slowest domain gates the batch.

Everything is virtual-clock deterministic (``wall_clock=False`` + a fixed
per-step compute stand-in), so the goodput ordering is a property of the
placement, not of host noise. Acceptance (ISSUE 2): zero failed requests in
every config, and BWAP-weighted swap beats ``local_first`` on goodput.

Run: PYTHONPATH=src python -m benchmarks.scheduler_bench [--requests 12]
Writes BENCH_scheduler.json / BENCH_prefix.json / BENCH_fabric.json /
BENCH_persist.json at the repo root (benchmarks.artifacts contract).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from benchmarks import artifacts
from repro.configs import registry
from repro.core.dwp import DWPConfig
from repro.models.lm import LM
from repro.scheduler import (KVSwapManager, PriorityClass, RequestScheduler,
                             SloSpec, WorkloadSpec, generate, total_kv_pages)
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BwapPagePool, MemoryDomain

PLACEMENTS = ("bwap_canonical", "local_first", "uniform")


def _domains():
    """Slow bandwidths scaled so one sequence's swap transfer is
    commensurate with a few decode steps — placement quality must show up
    in the clock. Page size is 4 below, so sequences span 8-16 pages and
    the per-domain split has room to differ between policies."""
    return [
        MemoryDomain("hbm_local", 20, 819.0, True),
        MemoryDomain("hbm_peer_1hop", 30, 0.00125, False),
        MemoryDomain("hbm_pod1_dci", 30, 0.000325, False),
        MemoryDomain("host_dram", 80, 0.0004, False),
    ]


def run_config(placement: str, cfg, params, trace, *, max_new: int,
               sim_step_s: float = 0.005) -> dict:
    pool = BwapPagePool(cfg, _domains(), page_size=4,
                        dwp_config=DWPConfig(n=10 ** 6, c=1))  # tuner frozen
    swap = KVSwapManager(pool, placement=placement, reserve_fraction=0.95)
    sched = RequestScheduler(
        pool, max_batch=6, prefill_token_budget=32,
        classes=[PriorityClass("interactive", 2,
                               SloSpec(ttft_s=0.3, tpot_s=0.03)),
                 PriorityClass("batch", 0,
                               SloSpec(ttft_s=1.5, tpot_s=0.06))],
        default_class="batch", default_max_new=max_new, swap=swap)
    eng = ServeEngine(cfg, params, pool, scheduler=sched, wall_clock=False,
                      sim_step_s=sim_step_s)
    for t in trace:
        eng.submit(t.prompt, cls=t.cls, max_new=t.max_new,
                   arrival_s=t.arrival_s)
    steps = 0
    while (eng.active or eng.waiting) and steps < 3000:
        eng.step()
        steps += 1
    tel = pool.telemetry.snapshot()
    slo = sched.slo.summary(sched.now)
    return {
        "placement": placement,
        "finished": len(eng.finished),
        "requests": len(trace),
        "failed": len(trace) - len(eng.finished),
        "steps": steps,
        "makespan_s": sched.now,
        "swap_pages": tel["swap_outs"],
        "swap_seconds": tel["swap_seconds"],
        "goodput_tok_s": slo["goodput_tok_s"],
        "good_tokens": slo["good_tokens"],
        "classes": slo["classes"],
    }


def compare(requests: int = 12, max_new: int = 24, seed: int = 0,
            check: bool = True) -> dict:
    """Run every placement on one trace, print the table, enforce the
    acceptance criteria, dump JSON. Used by __main__ and benchmarks.run."""
    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = generate(WorkloadSpec(
        kind="bursty", num_requests=requests,
        mean_interarrival_s=0.01, prompt_mean=24, prompt_max=40,
        max_new=max_new, vocab_size=cfg.vocab_size,
        class_mix=(("interactive", 0.25), ("batch", 0.75)), seed=seed))
    hbm = _domains()[0].num_pages
    footprint = total_kv_pages(trace, 4)
    print(f"workload: {len(trace)} requests, KV footprint {footprint} pages "
          f"vs hbm_local {hbm} (x{footprint / hbm:.1f} oversubscribed)")

    rows = {}
    for placement in PLACEMENTS:
        r = run_config(placement, cfg, params, trace, max_new=max_new)
        rows[placement] = r
        print(f"  {placement:15s} goodput {r['goodput_tok_s']:7.1f} tok/s  "
              f"makespan {r['makespan_s']:.2f}s  swaps {r['swap_pages']:3d} "
              f"pages ({r['swap_seconds'] * 1e3:6.0f} ms)  "
              f"failed {r['failed']}")

    bwap = rows["bwap_canonical"]["goodput_tok_s"]
    lf = rows["local_first"]["goodput_tok_s"]
    print(f"-> BWAP-weighted swap vs local_first: "
          f"{bwap / max(lf, 1e-9):.3f}x goodput")
    if check:
        for placement, r in rows.items():
            assert r["failed"] == 0, \
                f"{placement}: {r['failed']} requests failed under swap"
        assert rows["bwap_canonical"]["swap_pages"] > 0, \
            "benchmark exerted no memory pressure — nothing was swapped"
        assert bwap > lf, (
            f"BWAP swap placement must beat local_first on goodput "
            f"(got {bwap:.1f} vs {lf:.1f} tok/s)")

    artifacts.dump("BENCH_scheduler.json", rows)
    return rows


def prefix_compare(requests: int = 12, max_new: int = 8, seed: int = 0,
                   check: bool = True) -> dict:
    """Prefix-reuse on/off over a shared-prefix heavy-tail trace: identical
    requests, identical virtual clock — the only difference is whether the
    page table's trie maps identical prompt prefixes onto shared physical
    pages. Reported: peak physical vs logical page footprint, prefill
    forward tokens (O(n) incremental prefill skips matched pages entirely),
    goodput. Acceptance (ISSUE 3): >= 1.5x peak-physical-footprint
    reduction with reuse on, token-identical outputs."""
    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = generate(WorkloadSpec(
        kind="heavy_tail", num_requests=requests,
        mean_interarrival_s=0.002, prompt_mean=6, prompt_max=24,
        max_new=max_new, vocab_size=cfg.vocab_size, seed=seed,
        prefix_len=32, prefix_groups=2, prefix_frac=0.9))

    def run(reuse: bool) -> dict:
        domains = [MemoryDomain("hbm_local", 96, 819.0, True),
                   MemoryDomain("hbm_peer_1hop", 96, 0.05, False),
                   MemoryDomain("host_dram", 96, 0.016, False)]
        pool = BwapPagePool(cfg, domains, page_size=4,
                            dwp_config=DWPConfig(n=10 ** 6, c=1))
        sched = RequestScheduler(pool, max_batch=requests,
                                 prefill_token_budget=64,
                                 default_max_new=max_new)
        eng = ServeEngine(cfg, params, pool, scheduler=sched,
                          wall_clock=False, sim_step_s=0.005,
                          prefix_reuse=reuse)
        for t in trace:
            eng.submit(t.prompt, max_new=t.max_new, arrival_s=t.arrival_s)
        peak_phys = peak_logical = steps = 0
        while (eng.active or eng.waiting) and steps < 3000:
            info = eng.step()
            pt = info.get("pagetable", {})
            peak_phys = max(peak_phys, pt.get("physical_pages", 0))
            peak_logical = max(peak_logical, pt.get("logical_pages", 0))
            steps += 1
        slo = sched.slo.summary(sched.now)
        return {
            "prefix_reuse": reuse,
            "finished": len(eng.finished),
            "steps": steps,
            "peak_physical_pages": peak_phys,
            "peak_logical_pages": peak_logical,
            "prefill_tokens_computed": eng.prefill_tokens_computed,
            "cow_faults": pool.table.cow_faults,
            "prefix_hit_pages": pool.table.prefix_hit_pages,
            "makespan_s": sched.now,
            "goodput_tok_s": slo["goodput_tok_s"],
            "tokens": {s.sid: list(s.tokens) for s in eng.finished},
        }

    on, off = run(True), run(False)
    ratio = off["peak_physical_pages"] / max(on["peak_physical_pages"], 1)
    for r in (on, off):
        print(f"  prefix_reuse={str(r['prefix_reuse']):5s} "
              f"peak phys {r['peak_physical_pages']:4d} pages "
              f"(logical {r['peak_logical_pages']:4d})  prefill fwd "
              f"{r['prefill_tokens_computed']:5d} tok  goodput "
              f"{r['goodput_tok_s']:7.1f} tok/s  cow {r['cow_faults']}")
    print(f"-> prefix reuse shrinks peak physical KV footprint "
          f"{ratio:.2f}x (prefill fwd tokens "
          f"{off['prefill_tokens_computed'] / max(on['prefill_tokens_computed'], 1):.2f}x)")
    if check:
        assert on["finished"] == off["finished"] == len(trace)
        assert on["tokens"] == off["tokens"], \
            "prefix sharing changed generated tokens"
        assert ratio >= 1.5, (
            f"prefix reuse must cut peak physical footprint >= 1.5x "
            f"(got {ratio:.2f}x)")
        assert on["prefill_tokens_computed"] < off["prefill_tokens_computed"]
    rows = {"reuse_on": {k: v for k, v in on.items() if k != "tokens"},
            "reuse_off": {k: v for k, v in off.items() if k != "tokens"},
            "footprint_reduction": ratio}
    artifacts.dump("BENCH_prefix.json", rows)
    return rows


def fabric_compare(seed: int = 0, check: bool = True) -> dict:
    """Two-tenant memory fabric vs isolated partitions (ISSUE 5, CI-gated).

    Tenant A (high-priority) serves long-running requests whose prompts
    open with per-group system preambles; tenant B (best-effort) bursts
    over the same groups with a tight quota, plus a mid-run interactive
    sub-burst. The fabric run enables the cross-tenant read-only prefix
    tier and the swap-slot loan broker; the isolated run keeps identical
    quotas with both disabled. Virtual-clock deterministic.

    Gates: token-identical outputs across modes, zero failures;
    best-effort goodput >= 1.2x isolated (shared prefixes shrink B's
    physical footprint -> more concurrency per page of quota, and loans
    let its interactive burst preempt instead of queue); priority-tenant
    SLO no worse (goodput and TTFT p95 within 2%)."""
    from repro.placement.arbiter import DomainArbiter, DomainSpec, Priority

    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    params = LM(cfg).init(jax.random.PRNGKey(0))
    specs = [DomainSpec("hbm_local", 144, 819.0),
             DomainSpec("hbm_peer_1hop", 96, 0.05),
             DomainSpec("hbm_pod1_dci", 72, 0.0125),
             DomainSpec("host_dram", 192, 0.016)]
    # every best-effort request opens with a DIFFERENT system preamble,
    # each registered by a long-running priority request: all sharing is
    # cross-tenant (intra-tenant reuse would mask the fabric's effect)
    groups = 10
    rng = np.random.default_rng(seed)
    preambles = [rng.integers(1, cfg.vocab_size, 32).tolist()
                 for _ in range(groups)]
    a_prompts = [preambles[g] + rng.integers(1, cfg.vocab_size, 4).tolist()
                 for g in range(groups)]
    b_bulk = [(preambles[i]
               + rng.integers(1, cfg.vocab_size, 4).tolist())
              for i in range(groups)]
    b_hi = [(preambles[i]
             + rng.integers(1, cfg.vocab_size, 2).tolist())
            for i in range(3)]

    def run(shared: bool) -> dict:
        arb = DomainArbiter(specs, page_size=4, seed=seed)
        ta = arb.register("A", cfg, priority=Priority.HIGH, share=0.55,
                          share_prefix=shared)
        tb = arb.register("B", cfg, priority=Priority.BEST_EFFORT,
                          share=0.12, share_prefix=shared,
                          dwp_config=DWPConfig(n=10 ** 6, c=1))
        swap_a = KVSwapManager(ta.view, reserve_fraction=0.3,
                               lend=shared, borrow=shared)
        swap_b = KVSwapManager(tb.view, reserve_pages={"host_dram": 2},
                               lend=shared, borrow=shared)
        eng_a = ServeEngine(cfg, params, ta.view, wall_clock=False,
                            sim_step_s=0.005,
                            scheduler=RequestScheduler(
                                ta.view, max_batch=groups,
                                default_max_new=40, swap=swap_a,
                                conservative_admission=True,
                                classes=[PriorityClass(
                                    "A", 10,
                                    SloSpec(ttft_s=1.0, tpot_s=0.1))]))
        eng_b = ServeEngine(cfg, params, tb.view, wall_clock=False,
                            sim_step_s=0.005,
                            scheduler=RequestScheduler(
                                tb.view, max_batch=8, default_max_new=12,
                                swap=swap_b,
                                conservative_admission=True,
                                classes=[PriorityClass("B_hi", 5)]))
        for p in a_prompts:
            eng_a.submit(list(p))
        for _ in range(3):             # A prefills + registers the tier
            eng_a.step()
        for p in b_bulk:
            eng_b.submit(list(p))
        peak_shared = step = 0
        while (eng_a.active or eng_a.waiting or eng_b.active
               or eng_b.waiting) and step < 2000:
            if step == 6:              # interactive burst mid-bulk
                for p in b_hi:
                    eng_b.submit(list(p), cls="B_hi", max_new=8)
            if eng_a.active or eng_a.waiting:
                eng_a.step()
            if eng_b.active or eng_b.waiting:
                eng_b.step()
            step += 1
            peak_shared = max(peak_shared, arb.fabric.cross_shared_pages())
        # loan-cycle epilogue: the lender recalls everything it lent
        outstanding = sum(len(ln.slots) for ln in arb.fabric.loans
                          if ln.lender == "A")
        if outstanding:
            got, _ = ta.view.recall_loans(outstanding)
            assert got == outstanding, "idle loaned slots must all return"
        arb.fabric.check_invariants()
        slo_a = eng_a.scheduler.slo.summary(eng_a.scheduler.now)
        slo_b = eng_b.scheduler.slo.summary(eng_b.scheduler.now)
        loans = arb.fabric.stats()["loans"]
        return {
            "shared": shared,
            "steps": step,
            "a_finished": len(eng_a.finished),
            "b_finished": len(eng_b.finished),
            "a_goodput_tok_s": slo_a["goodput_tok_s"],
            "a_ttft_p95_s": slo_a["classes"]["A"]["ttft_p95_s"],
            "b_goodput_tok_s": slo_b["goodput_tok_s"],
            "b_makespan_s": eng_b.scheduler.now,
            "b_hi_ttft_mean_s": slo_b["classes"]["B_hi"]["ttft_mean_s"],
            "b_preemptions": slo_b["classes"]["B"]["preemptions"],
            "peak_cross_shared_pages": peak_shared,
            "loans_granted": sum(ln["granted"] for ln in loans),
            "loans_reclaimed": sum(ln["reclaimed"] for ln in loans),
            "tokens": {
                "A": [list(s.tokens) for s in
                      sorted(eng_a.finished, key=lambda s: s.sid)],
                "B": [list(s.tokens) for s in
                      sorted(eng_b.finished, key=lambda s: s.sid)],
            },
        }

    fab, iso = run(True), run(False)
    ratio = fab["b_goodput_tok_s"] / max(iso["b_goodput_tok_s"], 1e-9)
    for r in (fab, iso):
        mode = "fabric " if r["shared"] else "isolated"
        print(f"  {mode} B goodput {r['b_goodput_tok_s']:7.1f} tok/s "
              f"(makespan {r['b_makespan_s']:.2f}s, "
              f"B_hi ttft {r['b_hi_ttft_mean_s'] * 1e3:5.1f} ms, "
              f"preempts {r['b_preemptions']})  A goodput "
              f"{r['a_goodput_tok_s']:6.1f}  xshared "
              f"{r['peak_cross_shared_pages']:3d}p  loans "
              f"{r['loans_granted']}")
    print(f"-> fabric vs isolated: {ratio:.2f}x best-effort goodput")
    if check:
        assert fab["tokens"] == iso["tokens"], \
            "fabric sharing/loans changed generated tokens"
        assert fab["a_finished"] == iso["a_finished"] == len(a_prompts)
        assert fab["b_finished"] == iso["b_finished"] \
            == len(b_bulk) + len(b_hi)
        assert fab["peak_cross_shared_pages"] > 0, \
            "no cross-tenant prefix sharing happened"
        assert fab["loans_granted"] > 0 and fab["loans_reclaimed"] > 0, \
            "no swap-slot loan cycle happened"
        assert iso["loans_granted"] == 0
        assert ratio >= 1.2, (
            f"fabric must lift best-effort goodput >= 1.2x isolated "
            f"(got {ratio:.2f}x)")
        assert fab["a_goodput_tok_s"] >= 0.98 * iso["a_goodput_tok_s"], \
            "priority-tenant goodput regressed under the fabric"
        assert fab["a_ttft_p95_s"] <= 1.02 * iso["a_ttft_p95_s"] + 1e-9, \
            "priority-tenant TTFT p95 regressed under the fabric"
    rows = {"fabric": {k: v for k, v in fab.items() if k != "tokens"},
            "isolated": {k: v for k, v in iso.items() if k != "tokens"},
            "best_effort_goodput_ratio": ratio}
    artifacts.dump("BENCH_fabric.json", rows)
    return rows


def persist_compare(seed: int = 0, check: bool = True) -> dict:
    """Warm-restart vs cold-restart TTFT over a shared-prefix trace
    (ISSUE 6, CI-gated).

    Phase 1 boots engine A with a persistent tier, serves one request per
    preamble group (the trie now holds each group's system preamble),
    pins the preamble chains and exports them to the on-disk store.
    Phase 2 then submits one shared-prefix trace three ways:

    - engine A continues uninterrupted          -> the token oracle;
    - engine B is a restart (fresh pool, fresh fabric, fresh tier bound
      to the same store) that imports the prefixes    -> warm;
    - engine C is a restart with no store           -> cold.

    Gates: generated tokens identical across A/B/C (a restart must never
    change output), B's very first engine step hits the restored trie,
    and cold mean TTFT / warm mean TTFT >= 1.3x. Virtual-clock
    deterministic; the separation comes from cold re-prefilling every
    48-token preamble in 16-token chunks while decode batches are
    already costing time. Writes BENCH_persist.json at the repo root."""
    from repro.placement.fabric import as_view
    from repro.placement.persist import PersistentTier

    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    params = LM(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    groups, requests = 2, 8
    preambles = [rng.integers(1, cfg.vocab_size, 48).tolist()
                 for _ in range(groups)]
    warmup = [preambles[g] + rng.integers(1, cfg.vocab_size, 4).tolist()
              for g in range(groups)]
    phase2 = [preambles[i % groups]
              + rng.integers(1, cfg.vocab_size, 2 + i % 4).tolist()
              for i in range(requests)]
    store = artifacts.ROOT / "benchmarks" / "results" / "persist_store"
    store.mkdir(parents=True, exist_ok=True)

    def boot(tier):
        pool = BwapPagePool(cfg, [
            MemoryDomain("hbm_local", 64, 819.0, True),
            MemoryDomain("hbm_peer_1hop", 48, 0.05, False),
            MemoryDomain("host_dram", 64, 0.016, False),
        ], page_size=4, dwp_config=DWPConfig(n=10 ** 6, c=1))
        view = as_view(pool)
        if tier is not None:
            view.fabric.attach_persist(tier)
        sched = RequestScheduler(pool, max_batch=requests,
                                 prefill_token_budget=16,
                                 default_max_new=8)
        eng = ServeEngine(cfg, params, pool, scheduler=sched,
                          wall_clock=False, sim_step_s=0.005)
        return pool, view, eng

    def drain(eng) -> int:
        steps = 0
        while (eng.active or eng.waiting) and steps < 3000:
            eng.step()
            steps += 1
        return steps

    def run_phase2(eng, pool) -> dict:
        for p in phase2:
            eng.submit(list(p), arrival_s=0.0)
        hits0 = pool.table.prefix_hit_pages
        eng.step()
        first_hits = pool.table.prefix_hit_pages - hits0
        steps = drain(eng) + 1
        slo = eng.scheduler.slo.summary(eng.scheduler.now)
        toks = [list(s.tokens) for s in
                sorted(eng.finished, key=lambda s: s.sid)[-len(phase2):]]
        return {"steps": steps,
                "finished": len(eng.finished),
                "ttft_mean_s": slo["classes"]["default"]["ttft_mean_s"],
                "first_step_prefix_hit_pages": first_hits,
                "tokens": toks}

    # phase 1: serve the preamble groups, then pin + export their chains
    tier_a = PersistentTier(bw_gbps=0.008, capacity_pages=64,
                            directory=store)
    pool_a, view_a, eng_a = boot(tier_a)
    for p in warmup:
        eng_a.submit(list(p))
    # pin while the warmup requests are live: the trie drops a chain when
    # its last holder releases, so the pin's own holds must land between
    # prefill (registration) and sequence finish
    pinned = [None] * groups
    steps = 0
    while (eng_a.active or eng_a.waiting) and steps < 3000:
        eng_a.step()
        steps += 1
        for g, p in enumerate(preambles):
            if pinned[g] is None:
                pinned[g] = tier_a.pin(view_a, p)
    assert all(k is not None for k in pinned), \
        "warmup left no preamble chain to pin"
    manifest = tier_a.export_prefixes(view_a)
    view_a.fabric.check_invariants()

    oracle = run_phase2(eng_a, pool_a)        # A continues uninterrupted

    tier_b = PersistentTier(bw_gbps=0.008, capacity_pages=64,
                            directory=store)  # restart: reload the store
    pool_b, view_b, eng_b = boot(tier_b)
    restored, restore_s = tier_b.import_prefixes(view_b)
    view_b.fabric.check_invariants()
    warm = run_phase2(eng_b, pool_b)

    pool_c, view_c, eng_c = boot(None)        # cold restart: empty trie
    cold = run_phase2(eng_c, pool_c)
    identical = warm["tokens"] == cold["tokens"] == oracle["tokens"]

    ratio = cold["ttft_mean_s"] / max(warm["ttft_mean_s"], 1e-9)
    for name, r in (("oracle", oracle), ("warm", warm), ("cold", cold)):
        print(f"  {name:7s} ttft_mean {r['ttft_mean_s'] * 1e3:7.1f} ms  "
              f"first-step prefix hits {r['first_step_prefix_hit_pages']:3d} "
              f"pages  steps {r['steps']:3d}")
    print(f"-> warm restart ({len(manifest['chains'])} chains, {restored} "
          f"pages, restore {restore_s * 1e3:.2f} ms) vs cold: "
          f"{ratio:.2f}x mean TTFT")
    if check:
        assert identical, "restart (warm or cold) changed generated tokens"
        assert restored > 0, "prefix store restored nothing"
        assert warm["first_step_prefix_hit_pages"] > 0, \
            "first request after warm restart missed the restored trie"
        assert cold["first_step_prefix_hit_pages"] == 0, \
            "cold restart had a non-empty trie — not a restart baseline"
        assert warm["finished"] == cold["finished"] == len(phase2)
        assert ratio >= 1.3, (
            f"warm restart must beat cold on mean TTFT >= 1.3x "
            f"(got {ratio:.2f}x)")
    rows = {"oracle": {k: v for k, v in oracle.items() if k != "tokens"},
            "warm": {k: v for k, v in warm.items() if k != "tokens"},
            "cold": {k: v for k, v in cold.items() if k != "tokens"},
            "restored_pages": restored,
            "restore_seconds": restore_s,
            "exported_chains": len(manifest["chains"]),
            "ttft_cold_over_warm": ratio,
            "token_identical": identical}
    artifacts.dump("BENCH_persist.json", rows)
    return rows


def coda_compare(seed: int = 0, check: bool = True) -> dict:
    """Compute-follows-data vs global batching (ISSUE 8, CI-gated).

    A ``domain_skew`` trace: a back-to-back flood of long prompts fills
    the fast domain, so the steady tail's shared 32-token template lands
    in the slow domains. The flood is short-lived (max_new trimmed to 4);
    the sharers decode long, and the fast domain is sized to hold their
    whole steady-state footprint once the flood drains. Under ``coda``
    the engine partitions each decode step into per-bottleneck-domain
    launches and — once the flood frees fast pages — re-homes the hot
    shared prefix into ``hbm_local`` with an all-holders remap, so the
    sharers' remaining steps stop paying the slow-domain Eq.-1 stall.
    ``bwap_dwp`` (global) runs the identical trace with one launch per
    step and no re-homing: allocation never revisits placement and
    ``migrate()`` refuses shared pages, so the prefix stays pinned in
    slow memory for the rest of the run even though fast pages are free.

    Gates: token-identical outputs by sid, zero failures, fabric
    invariants clean after the run, coda re-homed > 0 pages, and coda
    goodput >= 1.15x global. Virtual-clock deterministic."""
    from repro.obs.observatory import Observatory
    from repro.placement.fabric import as_view

    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    params = LM(cfg).init(jax.random.PRNGKey(0))
    trace = generate(WorkloadSpec(
        kind="domain_skew", num_requests=6, skew_frac=0.5,
        mean_interarrival_s=0.02, prompt_mean=2, prompt_max=48,
        max_new=32, vocab_size=cfg.vocab_size, seed=seed,
        prefix_len=32, prefix_groups=1, prefix_frac=1.0))
    # the flood only exists to claim fast pages — trim its decode so the
    # fast domain frees up while the sharers still have most of their
    # tokens left to pay for, but keep it alive long enough that the
    # sharers' template prefills while fast is still full (flood prompts
    # are pinned at prompt_max)
    trace = [dataclasses.replace(t, max_new=10) if len(t.prompt) == 48
             else t for t in trace]

    def run(policy: str) -> dict:
        # hbm_local is sized so the flood's 36 prompt pages fill it while
        # the sharers prefill (template -> slow), yet the sharers' whole
        # steady-state footprint (8 prefix + bodies + 24 growth pages)
        # fits once the flood drains — the shared prefix is then the ONLY
        # slow-domain residue, and only re-homing can move it
        pool = BwapPagePool(cfg, [
            MemoryDomain("hbm_local", 34, 819.0, True),
            MemoryDomain("hbm_peer_1hop", 24, 0.00125, False),
            MemoryDomain("host_dram", 40, 0.0004, False),
        ], page_size=4, policy=policy,
            dwp_config=DWPConfig(n=10 ** 6, c=1))
        view = as_view(pool)
        Observatory(pool, tracer=False, drift=False)  # heat for re-homing
        sched = RequestScheduler(pool, max_batch=8,
                                 prefill_token_budget=32,
                                 default_max_new=32)
        eng = ServeEngine(cfg, params, pool, scheduler=sched,
                          wall_clock=False, sim_step_s=0.01)
        for t in trace:
            eng.submit(t.prompt, max_new=t.max_new, arrival_s=t.arrival_s)
        steps = multi = 0
        while (eng.active or eng.waiting) and steps < 3000:
            info = eng.step()
            if info.get("launches", 0) > 1:
                multi += 1
            steps += 1
        view.fabric.check_invariants()
        slo = sched.slo.summary(sched.now)
        return {
            "policy": policy,
            "finished": len(eng.finished),
            "failed": len(trace) - len(eng.finished),
            "steps": steps,
            "multi_launch_steps": multi,
            "rehomed_pages": eng.rehomed_pages,
            "makespan_s": sched.now,
            "goodput_tok_s": slo["goodput_tok_s"],
            "tokens": {s.sid: list(s.tokens) for s in eng.finished},
        }

    coda, glob = run("coda"), run("bwap_dwp")
    identical = coda["tokens"] == glob["tokens"]
    ratio = coda["goodput_tok_s"] / max(glob["goodput_tok_s"], 1e-9)
    for r in (coda, glob):
        print(f"  {r['policy']:9s} goodput {r['goodput_tok_s']:7.1f} tok/s "
              f"makespan {r['makespan_s']:.3f}s  steps {r['steps']:3d} "
              f"(multi-launch {r['multi_launch_steps']:3d})  rehomed "
              f"{r['rehomed_pages']:2d} pages  failed {r['failed']}")
    print(f"-> compute-follows-data vs global batching: {ratio:.2f}x "
          f"goodput (token-identical: {identical})")
    if check:
        assert identical, \
            "micro-batching/re-homing changed generated tokens"
        assert coda["failed"] == glob["failed"] == 0
        assert coda["rehomed_pages"] > 0, \
            "no hot shared page was re-homed — the scenario lost its teeth"
        assert glob["rehomed_pages"] == 0
        assert coda["multi_launch_steps"] > 0, \
            "coda never partitioned a decode step"
        assert ratio >= 1.15, (
            f"compute-follows-data must beat global batching >= 1.15x "
            f"goodput (got {ratio:.2f}x)")
    rows = {"coda": {k: v for k, v in coda.items() if k != "tokens"},
            "global": {k: v for k, v in glob.items() if k != "tokens"},
            "goodput_ratio": ratio,
            "token_identical": identical}
    artifacts.dump("BENCH_coda.json", rows)
    return rows


def zoo_compare(seed: int = 0, check: bool = True) -> dict:
    """Capacity market vs static partitions across three page geometries
    (ISSUE 9, CI-gated; DESIGN.md §12).

    One byte arena hosts a chat transformer (paged K/V, bursting), an
    idle ASR tenant (read-only encoder K/V, a few resident utterances),
    and an idle SSM tenant (1-page constant state). The market run lets
    the chat burst annex the idle groups' funding at its Eq.-1 stall
    price and repay on drain; the static run pins each group to its
    share. Virtual-clock deterministic.

    Gates: chat tokens and ASR/SSM state digests identical across modes,
    zero failures; >= 1 lease granted from an idle group and fully
    repaid (outstanding 0, funding restored); market chat goodput
    >= 1.2x static; zoo byte ledgers balanced throughout."""
    from repro.placement.geometry import encoder_kv_geometry
    from repro.placement.zoo import ByteDomain, PageFabricZoo
    from repro.serve.zoo import EncoderKVDriver, SSMStateDriver, ZooServer

    chat_cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                                   num_layers=1, compute_dtype="float32")
    ssm_cfg = registry.get_smoke_config("xlstm-125m")
    asr_cfg = registry.get_smoke_config("whisper-tiny")
    params = LM(chat_cfg).init(jax.random.PRNGKey(0))
    arena = [ByteDomain("hbm_local", 64 * 1024, 819.0, True),
             ByteDomain("host_dram", 192 * 1024, 8.0)]
    rng = np.random.default_rng(seed)
    # 12 requests x 12 pages peak = 144 pages vs 64 funded: the static
    # run decodes in waves, the market run annexes idle ASR/SSM funding
    # and runs the whole burst concurrently
    prompts = [rng.integers(1, chat_cfg.vocab_size, 16).tolist()
               for _ in range(12)]

    def run(market: bool) -> dict:
        zoo = PageFabricZoo(arena, seed=seed)
        chat = zoo.register("chat", chat_cfg, share=0.25, page_size=4,
                            dwp_config=DWPConfig(n=10 ** 6, c=1))
        zoo.register("ssm", ssm_cfg, share=0.25)
        zoo.register("asr", asr_cfg, share=0.5, page_size=4,
                     geometry=encoder_kv_geometry(asr_cfg, 4))
        start_quota = {n: g.view.quota.copy()
                       for n, g in zoo.groups.items()}
        srv = ZooServer(zoo, market=market)
        ssm_drv = SSMStateDriver(zoo.groups["ssm"].view, sessions=1)
        asr_drv = EncoderKVDriver(zoo.groups["asr"].view, utterances=3)
        asr_drv.attach(0)              # one decode session reads along
        srv.add_driver("ssm", ssm_drv)
        srv.add_driver("asr", asr_drv)
        eng = ServeEngine(chat_cfg, params, chat.view, wall_clock=False,
                          sim_step_s=0.005,
                          scheduler=RequestScheduler(
                              chat.view, max_batch=12,
                              prefill_token_budget=64,
                              default_max_new=32,
                              conservative_admission=True))
        srv.add_engine("chat", eng)
        for p in prompts:              # the burst: everything at once
            eng.submit(list(p))
        steps = srv.drain()
        # market and static drains take different step counts; bring the
        # perpetual SSM recurrence to a fixed step so digests compare
        assert ssm_drv.steps < 512
        while ssm_drv.steps < 512:
            ssm_drv.step()
        zoo.check_invariants()
        idle_leases = [ln for ln in zoo.leases
                       if ln.granted_bytes > 0 and ln.lender != "chat"]
        slo = eng.scheduler.slo.summary(eng.scheduler.now)
        return {
            "market": market,
            "steps": steps,
            "finished": len(eng.finished),
            "failed": len(prompts) - len(eng.finished),
            "goodput_tok_s": slo["goodput_tok_s"],
            "makespan_s": eng.scheduler.now,
            "granted_bytes": sum(ln.granted_bytes for ln in zoo.leases),
            "repaid_bytes": sum(ln.repaid_bytes for ln in zoo.leases),
            "outstanding_bytes": zoo.outstanding_bytes(),
            "idle_lenders": sorted({ln.lender for ln in idle_leases}),
            "funding_restored": all(
                (zoo.groups[n].view.quota == q).all()
                for n, q in start_quota.items()),
            "tokens": [list(s.tokens) for s in
                       sorted(eng.finished, key=lambda s: s.sid)],
            "ssm_digests": ssm_drv.digests(),
            "asr_digests": asr_drv.digests(),
        }

    mkt, sta = run(True), run(False)
    ratio = mkt["goodput_tok_s"] / max(sta["goodput_tok_s"], 1e-9)
    for r in (mkt, sta):
        mode = "market" if r["market"] else "static"
        print(f"  {mode:6s} chat goodput {r['goodput_tok_s']:7.1f} tok/s "
              f"makespan {r['makespan_s']:.3f}s  steps {r['steps']:3d}  "
              f"annexed {r['granted_bytes'] / 1024:5.1f} KiB from "
              f"{r['idle_lenders'] or '-'}  repaid "
              f"{r['repaid_bytes'] / 1024:5.1f} KiB  failed {r['failed']}")
    identical = (mkt["tokens"] == sta["tokens"]
                 and mkt["ssm_digests"] == sta["ssm_digests"]
                 and mkt["asr_digests"] == sta["asr_digests"])
    print(f"-> capacity market vs static partitions: {ratio:.2f}x chat "
          f"goodput (token-identical per model: {identical})")
    if check:
        assert identical, \
            "the capacity market changed tokens or state digests"
        assert mkt["failed"] == sta["failed"] == 0
        assert mkt["idle_lenders"], \
            "market never annexed an idle group's funding"
        assert mkt["granted_bytes"] > 0 \
            and mkt["repaid_bytes"] == mkt["granted_bytes"] \
            and mkt["outstanding_bytes"] == 0, \
            "annexed funding was not fully repaid on recall"
        assert mkt["funding_restored"], \
            "group funding did not return to its registered shares"
        assert sta["granted_bytes"] == 0
        assert ratio >= 1.2, (
            f"capacity market must lift chat goodput >= 1.2x static "
            f"partitions (got {ratio:.2f}x)")
    rows = {"market": {k: v for k, v in mkt.items()
                       if k not in ("tokens", "ssm_digests", "asr_digests")},
            "static": {k: v for k, v in sta.items()
                       if k not in ("tokens", "ssm_digests", "asr_digests")},
            "goodput_ratio": ratio,
            "token_identical": identical}
    artifacts.dump("BENCH_zoo.json", rows)
    return rows


def disagg_compare(seed: int = 0, check: bool = True) -> dict:
    """Prefill/decode disaggregation over the BWAP-priced page wire vs
    single-host serving (ISSUE 10, CI-gated; DESIGN.md §13).

    A prefill-heavy burst: every prompt is long (its prefill dominates),
    every completion short. On the single host, arriving prompts chunk
    their prefill through the same steps that decode earlier requests, so
    each admission pays queued decode time before its first token. The
    cluster admits prompts to a dedicated prefill host (``max_new=1`` —
    near-pure prefill steps, which cost zero virtual time) and hands the
    finished prompt range to the decode host over the interconnect; the
    decode host's trie adopts the imported chains and only the tail page
    re-prefills. The hosts deliberately run *different* page sizes
    (prefill 4, decode/single 8) so every handoff exercises
    convert-on-import.

    Gates: token-identical to the single host, >= 1.15x TTFT-weighted
    goodput (goodput / mean TTFT — the metric disaggregation exists to
    move), every handoff converted, both fabrics' ledgers balanced.
    Virtual-clock deterministic. Writes BENCH_disagg.json."""
    from repro.cluster import ClusterRouter, Interconnect, Link, PageChannel
    from repro.placement.fabric import as_view
    from repro.placement.persist import PersistentTier

    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    params = LM(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size, int(n)).tolist()
               for n in rng.integers(40, 56, 10)]
    max_new = 6

    def host(page_size):
        pool = BwapPagePool(cfg, [
            MemoryDomain("hbm_local", 96, 819.0, True),
            MemoryDomain("host_dram", 96, 0.016, False),
        ], page_size=page_size, dwp_config=DWPConfig(n=10 ** 6, c=1))
        view = as_view(pool)
        view.fabric.attach_persist(
            PersistentTier(bw_gbps=8.0, capacity_pages=256))
        sched = RequestScheduler(pool, max_batch=10,
                                 prefill_token_budget=32,
                                 default_max_new=max_new)
        eng = ServeEngine(cfg, params, pool, scheduler=sched,
                          wall_clock=False, sim_step_s=0.005)
        return view, eng

    # single host: prefills and decodes share every step
    view_s, eng_s = host(8)
    for p in prompts:
        eng_s.submit(list(p), max_new=max_new)
    steps = 0
    while (eng_s.active or eng_s.waiting) and steps < 3000:
        eng_s.step()
        steps += 1
    slo = eng_s.scheduler.slo.summary(eng_s.scheduler.now)
    single = {
        "finished": len(eng_s.finished),
        "steps": steps,
        "makespan_s": eng_s.scheduler.now,
        "ttft_mean_s": slo["ttft_mean_s"],
        "goodput_tok_s": slo["goodput_tok_s"],
        "ttft_weighted_goodput": slo["ttft_weighted_goodput"],
    }
    single_toks = [list(s.tokens) for s in
                   sorted(eng_s.finished, key=lambda s: s.sid)]

    # cluster: prefill host (ps 4) -> Eq.-5-striped wire -> decode host
    view_p, eng_p = host(4)
    view_d, eng_d = host(8)
    wire = Interconnect([Link("nvl", 0.2, latency_s=1e-4),
                         Link("rdma", 0.05, latency_s=5e-4)])
    channel = PageChannel(wire, chunk_bytes=1 << 14)
    router = ClusterRouter(eng_p, eng_d, channel,
                           saturation_horizon_s=0.25)
    rids = [router.submit(list(p), max_new=max_new) for p in prompts]
    router.drain()
    disagg_toks = [router.result(r) for r in rids]
    summ = router.summary()
    identical = disagg_toks == single_toks
    view_p.fabric.check_invariants()
    view_d.fabric.check_invariants()
    disagg = {
        "finished": summ["completed"],
        "handoffs": summ["handoffs"],
        "fallbacks": summ["fallbacks"],
        "converted_imports": channel.converted_imports,
        "wire_bytes": wire.sent_bytes,
        "wire_busy_s": wire.busy_seconds,
        "makespan_s": summ["elapsed_s"],
        "ttft_mean_s": summ["ttft_mean_s"],
        "goodput_tok_s": summ["goodput_tok_s"],
        "ttft_weighted_goodput": summ["ttft_weighted_goodput"],
    }
    ratio = disagg["ttft_weighted_goodput"] \
        / max(single["ttft_weighted_goodput"], 1e-9)
    for name, r in (("single", single), ("disagg", disagg)):
        print(f"  {name:7s} ttft_mean {r['ttft_mean_s'] * 1e3:6.1f} ms  "
              f"goodput {r['goodput_tok_s']:7.1f} tok/s  "
              f"ttft-weighted {r['ttft_weighted_goodput']:9.0f}  "
              f"makespan {r['makespan_s']:.3f}s")
    print(f"-> disaggregated vs single host: {ratio:.2f}x TTFT-weighted "
          f"goodput ({disagg['handoffs']} handoffs, "
          f"{disagg['converted_imports']} converted imports, "
          f"{disagg['wire_bytes'] / 1024:.0f} KiB on the wire; "
          f"token-identical: {identical})")
    if check:
        assert identical, "disaggregation changed generated tokens"
        assert single["finished"] == disagg["finished"] == len(prompts)
        assert disagg["handoffs"] == len(prompts) \
            and disagg["fallbacks"] == 0, "the wire saturated mid-benchmark"
        assert disagg["converted_imports"] == disagg["handoffs"], \
            "mismatched page sizes must convert on every import"
        assert ratio >= 1.15, (
            f"disaggregation must lift TTFT-weighted goodput >= 1.15x "
            f"single-host (got {ratio:.2f}x)")
    rows = {"single": single, "disagg": disagg,
            "ttft_goodput_ratio": ratio,
            "token_identical": identical}
    artifacts.dump("BENCH_disagg.json", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-prefix", action="store_true")
    ap.add_argument("--skip-fabric", action="store_true")
    ap.add_argument("--skip-persist", action="store_true")
    ap.add_argument("--skip-coda", action="store_true")
    ap.add_argument("--skip-zoo", action="store_true")
    ap.add_argument("--skip-disagg", action="store_true")
    ap.add_argument("--only-disagg", action="store_true")
    args = ap.parse_args()
    if args.only_disagg:
        print("disaggregated serving — prefill/decode split over the "
              "page wire vs single host")
        disagg_compare(seed=args.seed)
        return
    compare(args.requests, args.new, args.seed)
    if not args.skip_prefix:
        print("\nprefix sharing — peak KV footprint, reuse on vs off")
        prefix_compare(seed=args.seed)
    if not args.skip_fabric:
        print("\nmemory fabric — two tenants, prefix tier + swap loans "
              "vs isolated")
        fabric_compare(seed=args.seed)
    if not args.skip_persist:
        print("\npersistence tier — warm vs cold restart TTFT")
        persist_compare(seed=args.seed)
    if not args.skip_coda:
        print("\ncompute-follows-data — micro-batch decode + re-homing "
              "vs global batching")
        coda_compare(seed=args.seed)
    if not args.skip_zoo:
        print("\npage-geometry zoo — capacity market vs static partitions")
        zoo_compare(seed=args.seed)
    if not args.skip_disagg:
        print("\ndisaggregated serving — prefill/decode split over the "
              "page wire vs single host")
        disagg_compare(seed=args.seed)


if __name__ == "__main__":
    main()
