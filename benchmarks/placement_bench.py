"""Placement runtime microbenchmarks.

Main event: the batched migration executor (one gather/scatter per array)
against the seed's per-page ``at[].set`` Python loop, on a 4096-page
migration — the executor must win by >= 5x (ISSUE acceptance floor; in
practice the gap is orders of magnitude, since the loop materializes a full
pool copy per page). Also times policy weight/assignment computation and
pool allocation throughput.

Run: PYTHONPATH=src python -m benchmarks.placement_bench [--pages 4096]
Writes BENCH_placement.json at the repo root (benchmarks.artifacts).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.placement import policy as placement_policy
from repro.placement.executor import MigrationExecutor


def _time(fn, repeats: int = 1) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_migration(num_moves: int) -> dict:
    """Move ``num_moves`` pages from the first half of a pool to free pages
    in the second half — k and v arrays, like a KV pool."""
    total = 2 * num_moves
    nl, ps, nkv, hd = 1, 2, 1, 8
    k = jnp.arange(nl * total * ps * nkv * hd, dtype=jnp.float32).reshape(
        nl, total, ps, nkv, hd)
    v = k + 1.0
    src = np.arange(num_moves, dtype=np.int64)
    dst = np.arange(num_moves, dtype=np.int64) + num_moves
    ex = MigrationExecutor()

    t_batched = _time(lambda: ex.execute((k, v), src, dst)[0], repeats=3)
    t_looped = _time(lambda: ex.execute_looped((k, v), src, dst)[0])

    (bk, bv), _ = ex.execute((k, v), src, dst)
    (lk, lv), _ = ex.execute_looped((k, v), src, dst)
    assert bool(jnp.array_equal(bk, lk)) and bool(jnp.array_equal(bv, lv)), \
        "batched executor diverged from the per-page oracle"

    return {
        "num_moves": num_moves,
        "batched_s": t_batched,
        "per_page_loop_s": t_looped,
        "speedup": t_looped / max(t_batched, 1e-12),
    }


def bench_policies(num_pages: int = 65536) -> dict:
    ctx = placement_policy.PlacementContext(
        bandwidths=np.asarray([819.0, 50.0, 25.0, 12.5, 16.0]),
        num_pages=num_pages, workers=(0,), dwp=0.4,
        capacities=np.full(5, num_pages, dtype=np.int64))
    out = {}
    for name in placement_policy.available():
        t0 = time.perf_counter()
        a = placement_policy.assign(name, ctx)
        out[name] = {
            "assign_s": time.perf_counter() - t0,
            "fractions": (np.bincount(a, minlength=5) / num_pages).tolist(),
        }
    return out


def bench_alloc(num_pages: int = 4096) -> dict:
    import dataclasses

    from repro.configs import registry
    from repro.serve.kvcache import BwapPagePool, MemoryDomain

    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    domains = [
        MemoryDomain("hbm_local", num_pages // 2, 819.0, True),
        MemoryDomain("hbm_peer", num_pages // 4, 50.0, False),
        MemoryDomain("host", num_pages - num_pages // 2 - num_pages // 4,
                     16.0, False),
    ]
    pool = BwapPagePool(cfg, domains, page_size=4)
    t0 = time.perf_counter()
    ids = [pool.alloc_page() for _ in range(num_pages)]
    dt = time.perf_counter() - t0
    assert len(set(ids)) == num_pages
    return {"pages": num_pages, "alloc_s": dt,
            "pages_per_s": num_pages / dt}


def suite(pages: int = 4096) -> dict:
    """Run all three microbenchmarks, enforce the executor floor, dump
    BENCH_placement.json. Used by __main__ and benchmarks.run."""
    print(f"migration executor: batched vs per-page loop "
          f"({pages}-page migration)")
    mig = bench_migration(pages)
    print(f"  batched   {mig['batched_s'] * 1e3:9.2f} ms")
    print(f"  per-page  {mig['per_page_loop_s'] * 1e3:9.2f} ms")
    print(f"  -> speedup {mig['speedup']:.1f}x (acceptance floor: 5x)")
    assert mig["speedup"] >= 5.0, "batched executor under the 5x floor"

    print("\nplacement policies (65536-page assignment):")
    pol = bench_policies()
    for name, r in pol.items():
        frac = ", ".join(f"{f:.2f}" for f in r["fractions"])
        print(f"  {name:15s} {r['assign_s'] * 1e3:7.2f} ms  [{frac}]")

    print("\npage-pool allocation throughput:")
    al = bench_alloc()
    print(f"  {al['pages']} pages in {al['alloc_s'] * 1e3:.1f} ms "
          f"({al['pages_per_s']:.0f} pages/s)")

    from benchmarks import artifacts
    rows = {"migration": mig, "policies": pol, "alloc": al}
    artifacts.dump("BENCH_placement.json", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pages", type=int, default=4096)
    args = ap.parse_args()
    suite(args.pages)


if __name__ == "__main__":
    main()
