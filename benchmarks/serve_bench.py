"""Serving benchmark: speculative multi-token decode vs greedy baseline.

Every decode step is a full batched KV read across memory domains — under
BWAP's Eq.-1 clock that read is the dominant serving cost, so the lever is
not making a step cheaper but *taking fewer steps*. Speculative decode
(DESIGN.md §7) drafts continuations with a CPU-side n-gram self-drafter and
verifies them in one batched prefill-mode attention launch; every accepted
draft token deletes one whole decode step while output tokens stay
**token-identical to greedy** (the verify step accepts only what the
model's own argmax confirms).

The trace is repetition-friendly (``prompt_loop_len``: templated prompt
bodies) — the regime prompt-lookup drafting exists for. Both runs share one
virtual-clock setup, so step counts and goodput are deterministic.

Acceptance (ISSUE 4, gated in CI):
- token-identical outputs, zero failed requests in both configs;
- >= ``min_step_ratio`` (1.3x) fewer decode steps with speculation on.

Run: PYTHONPATH=src python -m benchmarks.serve_bench [--requests 6]
Writes BENCH_serve.json at the repo root (goodput, acceptance rate,
decode steps saved, prefill forward tokens — the machine-tracked perf
trajectory of the serving stack).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import registry
from repro.core.dwp import DWPConfig
from repro.models.lm import LM
from repro.scheduler import (RequestScheduler, WorkloadSpec, generate)
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BwapPagePool, MemoryDomain
from repro.serve.spec import PromptLookupDrafter


def _run(cfg, params, trace, *, max_new: int, drafter,
         sim_step_s: float = 0.005) -> dict:
    domains = [MemoryDomain("hbm_local", 64, 819.0, True),
               MemoryDomain("hbm_peer_1hop", 64, 0.05, False),
               MemoryDomain("host_dram", 64, 0.016, False)]
    pool = BwapPagePool(cfg, domains, page_size=4,
                        dwp_config=DWPConfig(n=10 ** 6, c=1))  # tuner frozen
    sched = RequestScheduler(pool, max_batch=len(trace),
                             prefill_token_budget=64,
                             default_max_new=max_new)
    eng = ServeEngine(cfg, params, pool, scheduler=sched, wall_clock=False,
                      sim_step_s=sim_step_s, drafter=drafter)
    for t in trace:
        eng.submit(t.prompt, max_new=t.max_new, arrival_s=t.arrival_s)
    steps = 0
    while (eng.active or eng.waiting) and steps < 5000:
        eng.step()
        steps += 1
    slo = sched.slo.summary(sched.now)
    tel = pool.telemetry.snapshot()
    return {
        "speculative": drafter is not None,
        "finished": len(eng.finished),
        "requests": len(trace),
        "failed": len(trace) - len(eng.finished),
        "engine_steps": steps,
        "decode_steps": eng.decode_steps,
        "tokens_emitted": eng.tokens_emitted,
        "prefill_fwd_tokens": eng.prefill_tokens_computed,
        "makespan_s": sched.now,
        "goodput_tok_s": slo["goodput_tok_s"],
        "spec": tel["spec"],
        "tokens": {s.sid: list(s.tokens) for s in eng.finished},
    }


def speculative_compare(requests: int = 6, max_new: int = 32, seed: int = 0,
                        spec_tokens: int = 6, check: bool = True,
                        min_step_ratio: float = 1.3) -> dict:
    """Greedy vs speculative on one repetition-friendly trace; print the
    table, enforce the CI gates, dump BENCH_serve.json."""
    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = generate(WorkloadSpec(
        kind="poisson", num_requests=requests,
        mean_interarrival_s=0.05, prompt_mean=16, prompt_max=28,
        max_new=max_new, vocab_size=cfg.vocab_size, seed=seed,
        prompt_loop_len=4))

    greedy = _run(cfg, params, trace, max_new=max_new, drafter=None)
    spec = _run(cfg, params, trace, max_new=max_new,
                drafter=PromptLookupDrafter(max_tokens=spec_tokens,
                                            max_ngram=3))
    ratio = greedy["decode_steps"] / max(spec["decode_steps"], 1)
    for r in (greedy, spec):
        mode = "speculative" if r["speculative"] else "greedy"
        print(f"  {mode:12s} decode steps {r['decode_steps']:4d}  "
              f"tokens {r['tokens_emitted']:4d}  goodput "
              f"{r['goodput_tok_s']:7.1f} tok/s  makespan "
              f"{r['makespan_s']:.3f}s  failed {r['failed']}")
    acc = spec["spec"]["acceptance_rate"]
    print(f"-> speculation: {ratio:.2f}x fewer decode steps, acceptance "
          f"rate {acc:.0%}, goodput "
          f"{spec['goodput_tok_s'] / max(greedy['goodput_tok_s'], 1e-9):.2f}x")
    identical = greedy["tokens"] == spec["tokens"]
    if check:
        assert greedy["failed"] == 0 and spec["failed"] == 0, \
            "requests failed under the speculative benchmark"
        assert identical, \
            "speculative decode changed generated tokens vs greedy"
        assert ratio >= min_step_ratio, (
            f"speculation must cut decode steps >= {min_step_ratio}x on the "
            f"repetition-friendly trace (got {ratio:.2f}x)")
    rows = {
        "greedy": {k: v for k, v in greedy.items() if k != "tokens"},
        "speculative": {k: v for k, v in spec.items() if k != "tokens"},
        "decode_step_ratio": ratio,
        "decode_steps_saved": greedy["decode_steps"] - spec["decode_steps"],
        "acceptance_rate": acc,
        "token_identical": identical,
    }
    from benchmarks import artifacts
    artifacts.dump("BENCH_serve.json", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--spec-tokens", type=int, default=6)
    args = ap.parse_args()
    speculative_compare(args.requests, args.new, args.seed,
                        spec_tokens=args.spec_tokens)


if __name__ == "__main__":
    main()
