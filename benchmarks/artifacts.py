"""Canonical benchmark artifacts — one `BENCH_*.json` per suite, at the
repo root, written deterministically (sorted keys, fixed float coercion)
so two runs on the same seed diff clean.

Every suite calls :func:`dump` for its gate-carrying result table;
:func:`check` is the CI tripwire that fails the build when an expected
artifact is missing or unparseable:

    PYTHONPATH=src python -m benchmarks.artifacts          # check all
    PYTHONPATH=src python -m benchmarks.artifacts BENCH_serve.json
"""

from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

# the full artifact contract: benchmarks.run and CI both end by asserting
# each of these exists at the repo root and parses as JSON
EXPECTED = (
    "BENCH_placement.json",   # placement_bench: executor vs floor
    "BENCH_scheduler.json",   # scheduler_bench.compare: swap placement
    "BENCH_prefix.json",      # scheduler_bench.prefix_compare
    "BENCH_fabric.json",      # scheduler_bench.fabric_compare
    "BENCH_persist.json",     # scheduler_bench.persist_compare
    "BENCH_serve.json",       # serve_bench.speculative_compare
)


def dump(name: str, data) -> pathlib.Path:
    """Write one artifact to the repo root. `name` must be the full
    `BENCH_*.json` filename so greps for the contract stay trivial."""
    assert name.startswith("BENCH_") and name.endswith(".json"), name
    path = ROOT / name
    path.write_text(json.dumps(data, indent=1, sort_keys=True,
                               default=float) + "\n")
    print(f"[artifact {path}]")
    return path


def check(names=EXPECTED) -> None:
    """Fail (SystemExit) unless every named artifact exists at the repo
    root and round-trips through json.loads."""
    missing = [n for n in names if not (ROOT / n).is_file()]
    if missing:
        raise SystemExit(
            f"missing benchmark artifacts at {ROOT}: {', '.join(missing)}")
    broken = []
    for n in names:
        try:
            json.loads((ROOT / n).read_text())
        except ValueError:
            broken.append(n)
    if broken:
        raise SystemExit(
            f"unparseable benchmark artifacts: {', '.join(broken)}")
    print(f"[artifacts OK — {len(names)} present at {ROOT}]")


if __name__ == "__main__":
    check(tuple(sys.argv[1:]) or EXPECTED)
