"""Canonical benchmark artifacts — one `BENCH_*.json` per suite, at the
repo root, written deterministically (sorted keys, fixed float coercion)
so two runs on the same seed diff clean.

Every suite calls :func:`dump` for its gate-carrying result table;
:func:`check` is the CI tripwire that fails the build when an expected
artifact is missing, unparseable, missing its schema's required top-level
keys, or contains a non-finite number (NaN/Infinity serialize as JSON but
poison every downstream comparison):

    PYTHONPATH=src python -m benchmarks.artifacts check            # all
    PYTHONPATH=src python -m benchmarks.artifacts check BENCH_serve.json
    PYTHONPATH=src python -m benchmarks.artifacts                  # = check
"""

from __future__ import annotations

import json
import math
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

# the full artifact contract: required top-level keys per artifact.
# benchmarks.run and CI both end by validating each of these.
SCHEMAS: dict[str, tuple[str, ...]] = {
    # placement_bench: executor vs per-page floor
    "BENCH_placement.json": ("alloc", "migration", "policies"),
    # scheduler_bench.compare: swap placement policies
    "BENCH_scheduler.json": ("bwap_canonical", "local_first", "uniform"),
    # scheduler_bench.prefix_compare
    "BENCH_prefix.json": ("footprint_reduction", "reuse_off", "reuse_on"),
    # scheduler_bench.fabric_compare
    "BENCH_fabric.json": ("best_effort_goodput_ratio", "fabric",
                          "isolated"),
    # scheduler_bench.persist_compare
    "BENCH_persist.json": ("warm", "cold", "oracle",
                           "ttft_cold_over_warm", "token_identical"),
    # serve_bench.speculative_compare
    "BENCH_serve.json": ("greedy", "speculative", "decode_step_ratio",
                         "token_identical"),
    # obs_bench.suite: calibration loop + tracing overhead
    "BENCH_obs.json": ("calibration", "calibration_micro", "overhead"),
    # scheduler_bench.coda_compare: micro-batch decode + re-homing
    "BENCH_coda.json": ("coda", "global", "goodput_ratio",
                        "token_identical"),
    # scheduler_bench.zoo_compare: capacity market across page geometries
    "BENCH_zoo.json": ("market", "static", "goodput_ratio",
                       "token_identical"),
    # scheduler_bench.disagg_compare: prefill/decode split over the wire
    "BENCH_disagg.json": ("single", "disagg", "ttft_goodput_ratio",
                          "token_identical"),
}

EXPECTED = tuple(SCHEMAS)


def dump(name: str, data) -> pathlib.Path:
    """Write one artifact to the repo root. `name` must be the full
    `BENCH_*.json` filename so greps for the contract stay trivial."""
    assert name.startswith("BENCH_") and name.endswith(".json"), name
    path = ROOT / name
    path.write_text(json.dumps(data, indent=1, sort_keys=True,
                               default=float) + "\n")
    print(f"[artifact {path}]")
    return path


def _non_finite(value, path: str) -> list[str]:
    """Walk a parsed JSON value; return the paths of non-finite floats
    (json.loads admits NaN/Infinity, downstream diffs must not)."""
    if isinstance(value, bool):
        return []
    if isinstance(value, float) and not math.isfinite(value):
        return [path]
    if isinstance(value, dict):
        return [p for k, v in value.items()
                for p in _non_finite(v, f"{path}.{k}")]
    if isinstance(value, list):
        return [p for i, v in enumerate(value)
                for p in _non_finite(v, f"{path}[{i}]")]
    return []


def check(names=EXPECTED, root: pathlib.Path = ROOT) -> None:
    """Fail (SystemExit) unless every named artifact exists at the repo
    root, round-trips through json.loads, carries its schema's required
    top-level keys, and contains only finite numbers."""
    root = pathlib.Path(root)
    missing = [n for n in names if not (root / n).is_file()]
    if missing:
        raise SystemExit(
            f"missing benchmark artifacts at {root}: {', '.join(missing)}")
    errors: list[str] = []
    for n in names:
        try:
            data = json.loads((root / n).read_text())
        except ValueError as e:
            errors.append(f"{n}: unparseable ({e})")
            continue
        required = SCHEMAS.get(n, ())
        if required and not isinstance(data, dict):
            errors.append(f"{n}: expected a JSON object, got "
                          f"{type(data).__name__}")
            continue
        absent = [k for k in required if k not in data]
        if absent:
            errors.append(f"{n}: missing required keys "
                          f"{', '.join(absent)}")
        bad = _non_finite(data, n)
        if bad:
            errors.append(f"{n}: non-finite numbers at "
                          f"{', '.join(bad[:5])}")
    if errors:
        raise SystemExit("benchmark artifact check failed:\n  "
                         + "\n  ".join(errors))
    print(f"[artifacts OK — {len(names)} checked at {root}]")


if __name__ == "__main__":
    argv = sys.argv[1:]
    if argv and argv[0] == "check":
        argv = argv[1:]
    check(tuple(argv) or EXPECTED)
