"""BWAP on the TPU memory system (DESIGN.md §2): weighted KV-page placement
and weighted optimizer-tier placement vs the uniform/naive baselines, costed
with the paper's Eq.-1 max-parallel-transfer model over v5e bandwidths."""

from __future__ import annotations

import numpy as np

from repro.core import topology
from repro.sharding import zero


def kv_placement() -> dict:
    """Decode-time KV reads: weighted interleave across HBM/ICI/DCI/PCIe
    domains vs uniform-all, uniform-workers (=all-local), for a long-context
    sequence that exceeds local HBM budget."""
    from repro.core import interleave

    topo, names, workers = topology.tpu_domains_topology()
    bw = topo.bw[:, 0]                        # GB/s per domain
    canon = bw / bw.sum()

    # 500k-token KV cache, hymba-like: bytes per domain read per step
    kv_gb = 0.67      # 524288 x 5 kv-heads x 64 x 2 x 2B (per layer set)

    def read_time(weights):
        w = np.asarray(weights) / np.sum(weights)
        return float(np.max(w * kv_gb / bw))

    # local HBM can hold only 40% of this cache
    local_cap = 0.4
    uniform_all = np.full(len(bw), 1.0 / len(bw))
    spill_naive = np.zeros(len(bw))
    spill_naive[0] = local_cap                # fill local, spill rest to host
    spill_naive[-1] = 1.0 - local_cap
    bwap = canon.copy()
    if bwap[0] > local_cap:                   # capacity-clamped canonical
        extra = bwap[0] - local_cap
        bwap[0] = local_cap
        rest = bwap[1:] / bwap[1:].sum()
        bwap[1:] += extra * rest

    return {
        "domains": names,
        "bandwidths_gbps": bw.tolist(),
        "read_time_uniform_all_ms": read_time(uniform_all) * 1e3,
        "read_time_hbm_spill_host_ms": read_time(spill_naive) * 1e3,
        "read_time_bwap_ms": read_time(bwap) * 1e3,
        "speedup_vs_uniform": read_time(uniform_all) / read_time(bwap),
        "speedup_vs_spill": read_time(spill_naive) / read_time(bwap),
    }


def optimizer_tiers() -> dict:
    """Offloaded optimizer-state streaming: the compute chip's own HBM is
    fully budgeted (params + activations at the train shapes), so Adam
    pages live in REMOTE domains — pod-peer spare HBM over ICI, cross-pod
    spare HBM over DCI, host DRAM over PCIe. The single-worker Eq.-2 says
    stream from all of them ∝ bandwidth; the naive policies are peer-first
    spill (first-touch analogue) and uniform (uniform-workers analogue)."""
    page_bytes = 1 << 20
    state_gb = 240.0 / 256                   # per chip after ZeRO sharding
    num_pages = int(state_gb * 2 ** 30 / page_bytes)
    tiers = [
        zero.TierSpec("peer_hbm_ici", topology.V5E_ICI_BW,
                      int(num_pages * 0.5)),
        zero.TierSpec("pod1_hbm_dci", topology.V5E_DCI_BW, num_pages),
        zero.TierSpec("host_dram", topology.V5E_PCIE_BW, num_pages),
    ]
    t_bwap = zero.stream_update_time(
        zero.tier_split(num_pages, tiers), tiers, page_bytes)
    t_uniform = zero.stream_update_time(
        zero.uniform_split(num_pages, tiers), tiers, page_bytes)
    t_peer_first = zero.stream_update_time(
        zero.hbm_first_split(num_pages, tiers), tiers, page_bytes)
    return {
        "pages": num_pages,
        "update_ms_bwap": t_bwap * 1e3,
        "update_ms_uniform": t_uniform * 1e3,
        "update_ms_peer_first_spill": t_peer_first * 1e3,
        "speedup_vs_uniform": t_uniform / t_bwap,
        "speedup_vs_peer_first": t_peer_first / t_bwap,
    }
