"""Aggregate cached dry-run JSONs into the §Dry-run / §Roofline tables."""

from __future__ import annotations

import json
import pathlib

import numpy as np

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"


def model_flops(arch: str, shape: str) -> float:
    """MODEL_FLOPS: 6*N*D train (N=active params, D=tokens); decode: 2*N*D
    per generated token batch; prefill: 2*N*D."""
    from repro.configs import registry
    cfg = registry.get_config(arch)
    sh = registry.SHAPES[shape]
    n = cfg.param_counts()["active"]
    tokens = sh.global_batch * (sh.seq_len if sh.kind != "decode" else 1)
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[sh.kind]
    return mult * n * tokens


def load_cells() -> list[dict]:
    cells = []
    for f in sorted(RESULTS.glob("*.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def render(single_only_roofline: bool = True) -> str:
    cells = load_cells()
    if not cells:
        return "(no dry-run results yet — run repro.launch.dryrun)\n"
    lines = []
    lines.append("### Dry-run status (lower+compile per cell)\n")
    lines.append("| arch | shape | mesh | status | compile s | "
                 "mem/dev GiB | accum |")
    lines.append("|---|---|---|---|---|---|---|")
    n_ok = n_skip = n_fail = 0
    for c in cells:
        st = c.get("status")
        n_ok += st == "OK"
        n_skip += st == "SKIP"
        n_fail += st == "FAIL"
        mem = c.get("memory", {}).get("total_bytes_per_device", 0) / 2 ** 30
        note = st if st != "SKIP" else f"SKIP ({c.get('reason', '')[:40]}…)"
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {note} | "
            f"{c.get('lower_compile_s', 0):.1f} | {mem:.2f} | "
            f"{c.get('accum_steps', 1)} |")
    lines.append(f"\nTotals: **{n_ok} OK, {n_skip} SKIP, {n_fail} FAIL** "
                 f"of {len(cells)} cells\n")

    lines.append("\n### Roofline terms (single-pod, per §Roofline)\n")
    lines.append("| arch | shape | t_comp s | t_mem s | t_coll s | "
                 "bottleneck | MODEL/HLO flops | roofline frac |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c.get("status") != "OK" or "roofline" not in c:
            continue
        if single_only_roofline and c["mesh"] != "single":
            continue
        r = c["roofline"]
        try:
            mf = model_flops(c["arch"], c["shape"])
            useful = mf / max(r["flops"] * r["chips"], 1.0)
        except Exception:
            useful = float("nan")
        dom = max(r["t_compute"], r["t_memory"], r["t_collective"])
        frac = r["t_compute"] / max(dom, 1e-12)
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['t_compute']:.3f} | "
            f"{r['t_memory']:.3f} | {r['t_collective']:.3f} | "
            f"{r['bottleneck']} | {useful:.2f} | {frac:.3f} |")
    return "\n".join(lines) + "\n"


if __name__ == "__main__":
    print(render())
