"""Paper-claims reproduction: Figs. 1b/2/3/4, Table II, overhead (§IV).

The BWAP algorithms under test are the real implementations in repro.core;
the physical NUMA machines are replaced by the simulator built from the
paper's own performance model (DESIGN.md §3). Each function returns a dict
that run.py renders and persists.
"""

from __future__ import annotations

import numpy as np

from repro.core import interleave, topology
from repro.core.canonical import CanonicalTuner
from repro.core.dwp import DWPConfig
from repro.core.simulator import (PAPER_WORKLOADS, NumaSimulator,
                                  ndim_hill_climb)

POLICIES = ["first_touch", "autonuma", "uniform_workers", "uniform_all"]


def _scenarios(mach):
    if mach.num_nodes == 8:        # machine A
        return [[0, 1], [0, 1, 2, 3]], [[0], [0, 1], [0, 1, 2, 3]]
    return [[0]], [[0], [0, 1]]    # machine B


def fig1b_placement(seed: int = 0) -> dict:
    """Baseline policies vs offline N-dim hill climbing (2 workers, mach A).
    Paper: uniform-* improve on first-touch but stay clearly short of the
    hill-climbed optimum."""
    mach = topology.machine_a()
    sim = NumaSimulator(mach, seed)
    workers = [0, 1]
    out = {}
    for name, app in PAPER_WORKLOADS.items():
        best_w, best_t, traj = ndim_hill_climb(sim, app, workers,
                                               iters=180, seed=seed)
        row = {"hill_climb_time": best_t, "iters": len(traj) - 1}
        for pol in POLICIES:
            t = sim.run(app, workers, pol).time
            row[pol] = best_t / t         # performance normalized to optimum
        out[name] = row
    return out


def fig23_speedups(seed: int = 0) -> dict:
    """Speedup vs uniform-workers for BWAP / BWAP-uniform / baselines in the
    co-scheduled scenario, machines A and B, various worker counts."""
    results = {}
    for mach in (topology.machine_a(), topology.machine_b()):
        sim = NumaSimulator(mach, seed)
        tuner = CanonicalTuner(mach)
        co_sets = _scenarios(mach)[0] if mach.num_nodes == 8 else [[0], [0, 1]]
        for workers in co_sets:
            key = f"{mach.name}/{len(workers)}w"
            results[key] = {}
            for name, app in PAPER_WORKLOADS.items():
                t_uw = sim.run(app, workers, "uniform_workers").time
                t_ua = sim.run(app, workers, "uniform_all").time
                t_ft = sim.run(app, workers, "first_touch").time
                canon = tuner.weights_for(workers).weights
                t_bwap, dwp_b, _ = sim.run_with_tuner(
                    app, workers, canon, DWPConfig(n=8, c=2, t=0.05, rel_tolerance=0.02))
                uniform_all = sim.placement("uniform_all", workers)
                t_bwu, dwp_u, _ = sim.run_with_tuner(
                    app, workers, uniform_all, DWPConfig(n=8, c=2, t=0.05, rel_tolerance=0.02))
                results[key][name] = {
                    "bwap": t_uw / t_bwap,
                    "bwap_uniform": t_uw / t_bwu,
                    "uniform_all": t_uw / t_ua,
                    "first_touch": t_uw / t_ft,
                    "autonuma": t_uw / sim.run(app, workers,
                                               "autonuma").time,
                    "dwp_bwap": dwp_b,
                }
    return results


def table2_dwp(seed: int = 0) -> dict:
    """Ideal DWP values found by the iterative search (co-scheduled)."""
    out = {}
    for mach in (topology.machine_a(), topology.machine_b()):
        sim = NumaSimulator(mach, seed)
        tuner = CanonicalTuner(mach)
        sets = ([[0], [0, 1], [0, 1, 2, 3]] if mach.num_nodes == 8
                else [[0], [0, 1]])
        for workers in sets:
            canon = tuner.weights_for(workers).weights
            key = f"{mach.name}/{len(workers)}w"
            out[key] = {}
            for name, app in PAPER_WORKLOADS.items():
                _, dwp, _ = sim.run_with_tuner(app, workers, canon,
                                               DWPConfig(n=8, c=2, t=0.05, rel_tolerance=0.02))
                out[key][name] = round(dwp, 2)
    return out


def fig4_dwp_curve(seed: int = 0) -> dict:
    """Static-DWP sweep for Streamcluster on machine A (1 and 2 workers):
    checks (a) stall rate tracks execution time, (b) near-convexity, and
    (c) the tuner stops within one step of the static optimum."""
    mach = topology.machine_a()
    sim = NumaSimulator(mach, seed)
    tuner = CanonicalTuner(mach)
    app = PAPER_WORKLOADS["SC"]
    out = {}
    for workers in ([0], [0, 1]):
        canon = tuner.weights_for(workers).weights
        grid = np.round(np.arange(0.0, 1.0001, 0.1), 2)
        times, stalls = [], []
        for d in grid:
            w = interleave.dwp_weights(canon, workers, float(d))
            r = sim.run(app, workers, "weighted", w)
            times.append(r.time)
            stalls.append(r.stall_rate)
        _, dwp_found, _ = sim.run_with_tuner(app, workers, canon,
                                             DWPConfig(n=8, c=2, t=0.05, rel_tolerance=0.02))
        opt = float(grid[int(np.argmin(times))])
        corr = float(np.corrcoef(times, stalls)[0, 1])
        out[f"{len(workers)}w"] = {
            "grid": grid.tolist(), "times": times, "stalls": stalls,
            "static_opt_dwp": opt, "tuner_dwp": dwp_found,
            "within_one_step": abs(dwp_found - opt) <= 0.1 + 1e-9,
            "time_stall_correlation": corr,
        }
    return out


def overhead(seed: int = 0) -> dict:
    """DWP-tuner overhead vs running statically at the found optimum.
    Paper §IV-B: max 4% across apps."""
    mach = topology.machine_a()
    sim = NumaSimulator(mach, seed)
    tuner = CanonicalTuner(mach)
    out = {}
    for name, app in PAPER_WORKLOADS.items():
        workers = [0, 1]
        canon = tuner.weights_for(workers).weights
        t_tuned, dwp, _ = sim.run_with_tuner(app, workers, canon,
                                             DWPConfig(n=8, c=2, t=0.05, rel_tolerance=0.02))
        w = interleave.dwp_weights(canon, workers, dwp)
        t_static = sim.run(app, workers, "weighted", w).time
        out[name] = {"with_tuner": t_tuned, "static_at_found_dwp": t_static,
                     "overhead_pct": 100.0 * (t_tuned / t_static - 1.0)}
    return out


def observation3_scaling(seed: int = 0) -> dict:
    """Observation 3: scaling per-cluster weights between the best
    distributions of two apps reduces per-node variance by ~1/3."""
    mach = topology.machine_a()
    sim = NumaSimulator(mach, seed)
    workers = [0, 1]
    best = {}
    for name in ("SC", "SP.B", "OC"):
        w, _, _ = ndim_hill_climb(sim, PAPER_WORKLOADS[name], workers,
                                  iters=180, seed=seed)
        best[name] = w
    mask = np.zeros(mach.num_nodes, bool)
    mask[workers] = True
    cvs_raw, cvs_scaled = [], []
    names = list(best)
    for a in range(len(names)):
        for b_ in range(a + 1, len(names)):
            wa, wb = best[names[a]], best[names[b_]]
            raw = np.std(wa - wb) / max(np.mean(np.abs(wb)), 1e-9)
            parts = []
            for m in (mask, ~mask):
                scale = wa[m].sum() / max(wb[m].sum(), 1e-9)
                parts.append(np.std(wa[m] - wb[m] * scale))
            scaled = np.mean(parts) / max(np.mean(np.abs(wb)), 1e-9)
            cvs_raw.append(raw)
            cvs_scaled.append(scaled)
    return {"cv_raw": float(np.mean(cvs_raw)),
            "cv_scaled": float(np.mean(cvs_scaled)),
            "reduction": 1.0 - float(np.mean(cvs_scaled))
            / max(float(np.mean(cvs_raw)), 1e-9)}
