"""Canonical tuner, DWP tuner, contention model, and simulator behaviour."""

import numpy as np
import pytest

from repro.core import bwmodel, dwp, interleave, simulator, topology
from repro.core.canonical import CanonicalTuner
from repro.core.simulator import PAPER_WORKLOADS, NumaSimulator


@pytest.fixture(scope="module")
def machA():
    t = topology.machine_a()
    t.validate()
    return t


@pytest.fixture(scope="module")
def machB():
    t = topology.machine_b()
    t.validate()
    return t


# -- topology reconstruction matches the paper's stated ratios --------------

def test_machine_a_asymmetry_ratios(machA):
    local = machA.bw.diagonal().max()
    off = machA.bw[~np.eye(8, dtype=bool)]
    assert local / off.min() == pytest.approx(5.8, rel=0.02)   # amplitude
    assert local / off.max() == pytest.approx(1.7, rel=0.05)   # local:nearest
    # directional asymmetry exists
    assert (np.abs(machA.bw - machA.bw.T) > 1e-6).any()


def test_machine_b_asymmetry_ratios(machB):
    local = machB.bw.diagonal().max()
    off = machB.bw[~np.eye(4, dtype=bool)]
    assert local / off.min() == pytest.approx(2.3, rel=0.02)
    assert local / off.max() == pytest.approx(1.8, rel=0.05)


# -- Eq. 2/5 closed form ------------------------------------------------------

def test_optimal_weights_equalize_transfer_times():
    """With weights from Eq. 5, every node's transfer time is equal — the
    optimality argument of §III-A2 (no single slowest transfer to shave)."""
    prof = np.array([[10.0], [5.0], [2.5], [2.0]])
    w = bwmodel.optimal_weights(prof)
    times = w / prof[:, 0]
    np.testing.assert_allclose(times, times[0])


def test_optimal_weights_beat_uniform_in_model():
    prof = np.array([[10.0], [5.0], [2.5], [2.0]])
    w_opt = bwmodel.optimal_weights(prof)
    t_opt = bwmodel.transfer_time(1.0, w_opt, prof)
    t_uni = bwmodel.transfer_time(1.0, np.full(4, 0.25), prof)
    assert t_opt < t_uni


def test_multiworker_uses_minbw():
    prof = np.array([[10.0, 2.0], [5.0, 5.0]])
    m = bwmodel.minbw(prof)
    np.testing.assert_allclose(m, [2.0, 5.0])
    w = bwmodel.optimal_weights(prof)
    np.testing.assert_allclose(w, [2 / 7, 5 / 7])


# -- contention model ---------------------------------------------------------

def test_waterfill_respects_path_caps(machA):
    d = [bwmodel.Demand(0, 1, 1e9), bwmodel.Demand(1, 1, 1e9)]
    g = bwmodel.effective_bandwidth(machA, d)
    assert g[(0, 1)] <= machA.bw[0, 1] + 1e-9
    assert g[(1, 1)] <= machA.bw[1, 1] + 1e-9


def test_waterfill_respects_controller_cap(machA):
    # every node reads from node 0: grants must sum below node 0's MC bw
    d = [bwmodel.Demand(0, dst, 1e9) for dst in range(8)]
    g = bwmodel.effective_bandwidth(machA, d)
    assert sum(g.values()) <= machA.mc_bw[0] + 1e-6


def test_waterfill_fair_share_under_contention(machA):
    d = [bwmodel.Demand(0, 0, 1e9), bwmodel.Demand(0, 1, 1e9)]
    g = bwmodel.effective_bandwidth(machA, d)
    # both readers limited by their path; local path is faster
    assert g[(0, 0)] >= g[(0, 1)]


# -- canonical tuner ----------------------------------------------------------

def test_canonical_weights_sum_to_one_and_favour_fast_nodes(machA):
    tuner = CanonicalTuner(machA)
    e = tuner.weights_for([0, 1])
    assert e.weights.sum() == pytest.approx(1.0)
    assert (e.weights > 0).all()          # Observation 1: all nodes used
    # worker-local nodes get the largest weights (highest minbw)
    assert e.weights[0] >= e.weights.max() * 0.5
    # asymmetric: not uniform (Observation 2)
    assert e.weights.std() > 0.01


def test_canonical_symmetry_dedup(machB):
    tuner = CanonicalTuner(machB)
    sets = tuner.plausible_worker_sets(max_size=2)
    # machine B is symmetric between sockets: {0},{0,1} kept; {2},{2,3}
    # deduplicated; cross-socket 2-sets are filtered as irrational.
    assert (0,) in sets
    assert (2,) not in sets
    assert (0, 1) in sets and (2, 3) not in sets


def test_canonical_install_roundtrip(tmp_path, machB):
    tuner = CanonicalTuner(machB)
    n = tuner.install(tmp_path / "weights.json", max_size=2)
    assert n >= 2
    loaded = CanonicalTuner.load(tmp_path / "weights.json")
    for ws, w in loaded.items():
        np.testing.assert_allclose(w, tuner.weights_for(ws).weights)


# -- DWP tuner ----------------------------------------------------------------

def _drive(tuner, stall_of_dwp, max_periods=50):
    periods = 0
    while not tuner.done and periods < max_periods:
        for _ in range(tuner.cfg.n):
            tuner.record(stall_of_dwp(tuner.dwp))
        periods += 1
    return tuner


def test_dwp_tuner_finds_convex_optimum():
    """Stall rate convex in DWP with optimum at 0.3: the tuner must stop
    within one step (paper §IV-B: max error margin of 1 iterative step)."""
    rng = np.random.default_rng(0)
    canon = interleave.normalize(np.asarray([3.0, 2, 1, 1]))

    def stall(d):
        return (d - 0.3) ** 2 + 1.0 + rng.normal(0, 1e-4)

    t = dwp.DWPTuner(canon, workers=[0, 1], num_pages=2048)
    _drive(t, stall)
    assert t.done
    assert abs(t.dwp - 0.3) <= t.cfg.x + 1e-9


def test_dwp_tuner_monotone_decreasing_goes_to_one():
    canon = interleave.normalize(np.asarray([3.0, 2, 1, 1]))
    t = dwp.DWPTuner(canon, workers=[0, 1], num_pages=1024)
    _drive(t, lambda d: 2.0 - d)
    assert t.done and t.dwp == pytest.approx(1.0)


def test_dwp_tuner_stays_at_zero_when_increase_hurts():
    canon = interleave.normalize(np.asarray([3.0, 2, 1, 1]))
    t = dwp.DWPTuner(canon, workers=[0, 1], num_pages=1024)
    _drive(t, lambda d: 1.0 + d)
    assert t.done and t.dwp == pytest.approx(0.0)


def test_dwp_tuner_migrations_preserve_fractions():
    canon = interleave.normalize(np.asarray([5.0, 3, 2, 1, 1, 1, 1, 1]))
    moved = []
    t = dwp.DWPTuner(canon, workers=[0, 1], num_pages=4096,
                     on_migrate=lambda p: moved.append(p))
    _drive(t, lambda d: (d - 0.45) ** 2)
    frac = interleave.page_fractions(t.assignment, 8)
    target = interleave.dwp_weights(canon, [0, 1], t.dwp)
    np.testing.assert_allclose(frac, target, atol=0.01)
    assert moved  # migrations actually happened


def test_coscheduled_two_stage():
    """Stage 1 raises DWP while A improves; stage 2 optimizes B above bound."""
    canon = interleave.normalize(np.asarray([3.0, 2, 1, 1]))
    t = dwp.CoScheduledTuner(canon, workers_b=[0, 1], num_pages=2048)

    # A improves (stall drops) until B's DWP reaches 0.2, then flat;
    # B's stall is convex with optimum at 0.1 — *below* the bound: the final
    # DWP must respect the bound, not B's unconstrained optimum.
    def stall_a(d):
        return max(1.0 - 2 * d, 0.6)

    def stall_b(d):
        return (d - 0.1) ** 2 + 1.0

    periods = 0
    while not t.done and periods < 60:
        for _ in range(t.cfg.n):
            t.record(stall_a(t.dwp), stall_b(t.dwp))
        periods += 1
    assert t.done
    assert t.dwp_lower_bound >= 0.2 - 1e-9
    assert t.dwp >= t.dwp_lower_bound - 1e-9


# -- simulator: the paper's headline qualitative results ---------------------

def test_bwap_beats_uniform_workers_on_machine_a(machA):
    """Key claim: on asymmetric topologies with a small worker set, canonical
    weighted placement outperforms uniform-workers and first-touch."""
    sim = NumaSimulator(machA)
    tuner = CanonicalTuner(machA)
    app = PAPER_WORKLOADS["SC"]
    workers = [0, 1]
    canon = tuner.weights_for(workers).weights
    t_bwap = sim.run(app, workers, "weighted", canon).time
    t_uw = sim.run(app, workers, "uniform_workers").time
    t_ft = sim.run(app, workers, "first_touch").time
    assert t_bwap < t_uw
    assert t_bwap < t_ft
    assert t_ft > t_uw  # first-touch is the worst (paper §IV-A)


def test_uniform_all_beats_uniform_workers_for_bw_bound(machA):
    sim = NumaSimulator(machA)
    app = PAPER_WORKLOADS["SC"]
    t_ua = sim.run(app, [0, 1], "uniform_all").time
    t_uw = sim.run(app, [0, 1], "uniform_workers").time
    assert t_ua < t_uw  # Observation 1


def test_gains_shrink_with_more_workers(machA):
    sim = NumaSimulator(machA)
    tuner = CanonicalTuner(machA)
    app = PAPER_WORKLOADS["SC"]

    def gain(workers):
        canon = tuner.weights_for(workers).weights
        t_b = sim.run(app, workers, "weighted", canon).time
        t_u = sim.run(app, workers, "uniform_workers").time
        return t_u / t_b

    assert gain([0, 1]) > gain(list(range(8))) - 1e-9  # §IV-A trend
