"""Pallas kernel validation (interpret mode) against pure-jnp oracles:
shape/dtype sweeps + hypothesis-driven page-table cases."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (optional dep)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.paged_attention import ops as paged_ops
from repro.kernels.paged_attention.ref import paged_attention_ref


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


# -- flash attention: shape / dtype / window sweep ---------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,nq,nkv,h", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 192, 4, 1, 32),      # MQA, non-multiple seq vs blocks
    (1, 64, 2, 2, 128),      # small seq
])
def test_flash_matches_ref(b, s, nq, nkv, h, dtype):
    q = _rand(0, (b, s, nq, h), dtype)
    k = _rand(1, (b, s, nkv, h), dtype)
    v = _rand(2, (b, s, nkv, h), dtype)
    out = flash_ops.flash_attention(q, k, v, block_q=64, block_kv=64,
                                    interpret=True)
    ref = flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [1, 7, 64, 200])
def test_flash_sliding_window(window):
    b, s, nq, nkv, h = 1, 200, 4, 2, 32
    q = _rand(3, (b, s, nq, h), jnp.float32)
    k = _rand(4, (b, s, nkv, h), jnp.float32)
    v = _rand(5, (b, s, nkv, h), jnp.float32)
    out = flash_ops.flash_attention(q, k, v, window=window, block_q=64,
                                    block_kv=64, interpret=True)
    ref = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_noncausal():
    b, s, nq, nkv, h = 2, 128, 4, 4, 64
    q = _rand(6, (b, s, nq, h), jnp.float32)
    k = _rand(7, (b, s, nkv, h), jnp.float32)
    v = _rand(8, (b, s, nkv, h), jnp.float32)
    out = flash_ops.flash_attention(q, k, v, causal=False, block_q=64,
                                    block_kv=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# -- paged attention ----------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,nq,nkv,h,ps,pages_per_seq,pool", [
    (2, 4, 2, 64, 16, 4, 16),
    (1, 8, 8, 32, 8, 8, 64),
    (3, 6, 2, 64, 32, 2, 8),
])
def test_paged_matches_ref(b, nq, nkv, h, ps, pages_per_seq, pool, dtype):
    rng = np.random.default_rng(b * 7 + nq)
    q = _rand(9, (b, nq, h), dtype)
    k_pool = _rand(10, (pool, ps, nkv, h), dtype)
    v_pool = _rand(11, (pool, ps, nkv, h), dtype)
    # distinct pages per sequence (realistic allocator behaviour)
    table = np.stack([rng.choice(pool, pages_per_seq, replace=False)
                      for _ in range(b)]).astype(np.int32)
    lens = rng.integers(1, ps * pages_per_seq + 1, b).astype(np.int32)
    out = paged_ops.paged_attention(q, k_pool, v_pool, jnp.asarray(table),
                                    jnp.asarray(lens), interpret=True)
    ref = paged_attention_ref(q, k_pool, v_pool, jnp.asarray(table),
                              jnp.asarray(lens))
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@given(st.integers(min_value=1, max_value=4),     # batch
       st.integers(min_value=1, max_value=6),     # pages per seq
       st.integers(min_value=0, max_value=10_000))
@settings(max_examples=12, deadline=None)
def test_paged_property_random_tables(b, mp, seed):
    """Property: kernel == oracle for arbitrary tables/lengths (incl. len
    boundaries at page edges)."""
    nq, nkv, h, ps, pool = 4, 2, 32, 8, 12
    rng = np.random.default_rng(seed)
    q = _rand(seed % 97, (b, nq, h), jnp.float32)
    k_pool = _rand(seed % 89 + 1, (pool, ps, nkv, h), jnp.float32)
    v_pool = _rand(seed % 83 + 2, (pool, ps, nkv, h), jnp.float32)
    table = rng.integers(0, pool, (b, mp)).astype(np.int32)
    # hit page-boundary lengths often
    lens = np.minimum(rng.integers(1, mp * ps + 1, b)
                      // ps * ps + rng.integers(0, 2, b) * rng.integers(
                          1, ps + 1, b), mp * ps).astype(np.int32)
    lens = np.maximum(lens, 1).astype(np.int32)
    out = paged_ops.paged_attention(q, k_pool, v_pool, jnp.asarray(table),
                                    jnp.asarray(lens), interpret=True)
    ref = paged_attention_ref(q, k_pool, v_pool, jnp.asarray(table),
                              jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


# -- batched prefill-mode paged attention (speculative verify / fused prefill)

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,nq,nkv,h,ps,mp,pool", [
    (1, 4, 4, 2, 64, 8, 3, 16),      # single sequence, GQA
    (3, 5, 4, 2, 32, 4, 6, 24),      # batch with different q_starts
    (2, 1, 8, 8, 32, 8, 4, 16),      # T=1 degenerate (pure decode shape)
])
def test_paged_prefill_batch_matches_ref(b, t, nq, nkv, h, ps, mp, pool,
                                         dtype):
    rng = np.random.default_rng(b * 11 + t)
    q = _rand(12, (b, t, nq, h), dtype)
    k_pool = _rand(13, (pool, ps, nkv, h), dtype)
    v_pool = _rand(14, (pool, ps, nkv, h), dtype)
    table = np.stack([rng.choice(pool, mp, replace=False)
                      for _ in range(b)]).astype(np.int32)
    q_start = rng.integers(0, mp * ps - t + 1, b).astype(np.int32)
    out = paged_ops.paged_prefill_attention_batch(
        q, k_pool, v_pool, jnp.asarray(table), jnp.asarray(q_start),
        interpret=True)
    ref = paged_ops.paged_prefill_attention_batch(
        q, k_pool, v_pool, jnp.asarray(table), jnp.asarray(q_start),
        impl="reference")
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_paged_prefill_batch_trailing_page_invariance():
    """The rollback bit-identity property (DESIGN.md §7): extending a page
    table with lookahead pages whose keys are causally masked must not
    change the result in the last bit — the online-softmax page walk makes
    a fully-masked page an exact no-op."""
    nq, nkv, h, ps, pool = 4, 2, 32, 4, 16
    q = _rand(15, (1, 3, nq, h), jnp.float32)
    k_pool = _rand(16, (pool, ps, nkv, h), jnp.float32)
    v_pool = _rand(17, (pool, ps, nkv, h), jnp.float32)
    tbl = jnp.asarray([[5, 9, 2]], jnp.int32)           # covers 12 positions
    ext = jnp.asarray([[5, 9, 2, 7, 11]], jnp.int32)    # + lookahead pages
    qs = jnp.asarray([9], jnp.int32)                    # queries at 9..11
    base = paged_ops.paged_prefill_attention_batch(q, k_pool, v_pool, tbl,
                                                   qs, impl="reference")
    wide = paged_ops.paged_prefill_attention_batch(q, k_pool, v_pool, ext,
                                                   qs, impl="reference")
    assert (np.asarray(base) == np.asarray(wide)).all()
    # decode op agrees bitwise with verify row 0 (token-identity under the
    # scheduler relies on the two paths computing the same attention)
    dec = paged_ops.paged_attention(q[:, 0], k_pool, v_pool, tbl,
                                    jnp.asarray([10], jnp.int32),
                                    impl="reference")
    assert (np.asarray(dec[0]) == np.asarray(base[0, 0])).all()
