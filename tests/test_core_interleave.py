"""Property tests for Alg. 1 weighted interleaving and DWP scaling."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:      # bare env: property tests skip individually
    from _hypothesis_stub import given, settings, st

from repro.core import interleave


@st.composite
def weight_vectors(draw):
    n = draw(st.integers(min_value=1, max_value=12))
    w = draw(st.lists(st.floats(min_value=0.0, max_value=10.0,
                                allow_nan=False), min_size=n, max_size=n))
    if sum(w) <= 0:
        w[0] = 1.0
    return np.asarray(w)


@given(weight_vectors(), st.integers(min_value=64, max_value=8192))
@settings(max_examples=60, deadline=None)
def test_alg1_fractions_match_weights(w, pages):
    """Per-node page fractions reproduce the target weights (Alg. 1 claim)."""
    w = interleave.normalize(w)
    a = interleave.weighted_interleave(pages, w)
    frac = interleave.page_fractions(a, len(w))
    # accuracy is limited by round-robin granularity: one page per sub-range
    # boundary per node.
    tol = len(w) * 1.5 / pages + 1e-9
    np.testing.assert_allclose(frac, w, atol=tol)


@given(weight_vectors())
@settings(max_examples=40, deadline=None)
def test_alg1_zero_weight_nodes_get_no_pages(w):
    w = np.asarray(w)
    w[0] = 0.0
    if w.sum() <= 0:
        w[-1] = 1.0
    a = interleave.weighted_interleave(1024, w)
    assert not (a == 0).any() or w[0] > 0


def test_alg1_uniform_equals_round_robin():
    a = interleave.weighted_interleave(100, np.ones(4))
    frac = interleave.page_fractions(a, 4)
    np.testing.assert_allclose(frac, 0.25, atol=0.01)


@given(weight_vectors(), st.floats(min_value=0, max_value=1))
@settings(max_examples=60, deadline=None)
def test_dwp_weights_preserve_cluster_ratios(w, dwp):
    """DWP scaling preserves relative weights within worker/non-worker
    clusters (paper Observation 3)."""
    w = interleave.normalize(w)
    n = len(w)
    workers = list(range(max(1, n // 2)))
    out = interleave.dwp_weights(w, workers, dwp)
    assert abs(out.sum() - 1.0) < 1e-9
    # ratios inside the worker cluster preserved
    wi = [i for i in workers if w[i] > 1e-12 and out[i] > 1e-12]
    for a, b in zip(wi, wi[1:]):
        np.testing.assert_allclose(out[a] / out[b], w[a] / w[b], rtol=1e-6)
    nw = [i for i in range(n) if i not in workers
          and w[i] > 1e-12 and out[i] > 1e-12]
    for a, b in zip(nw, nw[1:]):
        np.testing.assert_allclose(out[a] / out[b], w[a] / w[b], rtol=1e-6)


def test_dwp_extremes():
    w = interleave.normalize(np.asarray([4.0, 3, 2, 1]))
    workers = [0, 1]
    w0 = interleave.dwp_weights(w, workers, 0.0)
    np.testing.assert_allclose(w0, w)
    w1 = interleave.dwp_weights(w, workers, 1.0)
    assert w1[2] == w1[3] == 0.0
    np.testing.assert_allclose(w1[:2].sum(), 1.0)
    np.testing.assert_allclose(w1[0] / w1[1], w[0] / w[1])


@given(weight_vectors(), st.floats(min_value=0.05, max_value=1))
@settings(max_examples=40, deadline=None)
def test_migration_plan_is_minimal_diff(w, dwp):
    w = interleave.normalize(w)
    if len(w) < 2:
        return
    workers = [0]
    a0 = interleave.weighted_interleave(
        2048, interleave.dwp_weights(w, workers, 0.0))
    plan = interleave.plan_migration(
        a0, interleave.dwp_weights(w, workers, dwp))
    # every move actually changes the node, and untouched pages are identical
    assert (plan.moves[:, 1] != plan.moves[:, 2]).all()
    untouched = np.setdiff1d(np.arange(2048), plan.moves[:, 0])
    np.testing.assert_array_equal(plan.old_assignment[untouched],
                                  plan.new_assignment[untouched])


def test_migration_moves_toward_workers_when_dwp_increases():
    w = interleave.normalize(np.asarray([3.0, 2.0, 1.0, 1.0]))
    workers = [0, 1]
    a0 = interleave.weighted_interleave(
        4096, interleave.dwp_weights(w, workers, 0.0))
    plan = interleave.plan_migration(
        a0, interleave.dwp_weights(w, workers, 0.4))
    frac0 = interleave.page_fractions(plan.old_assignment, 4)[:2].sum()
    frac1 = interleave.page_fractions(plan.new_assignment, 4)[:2].sum()
    assert frac1 > frac0


def test_capacity_capped_weights_waterfill():
    w = interleave.normalize(np.asarray([6.0, 3.0, 1.0]))
    cap = np.asarray([0.4, np.inf, np.inf])
    out = interleave.capacity_capped_weights(w, cap)
    assert out.sum() == pytest.approx(1.0)
    assert out[0] == pytest.approx(0.4)
    # excess redistributes proportionally to the unclamped weights (3:1)
    assert out[1] / out[2] == pytest.approx(3.0)
    # cascading clamp: redistribution may push another node over its cap
    out2 = interleave.capacity_capped_weights(
        w, np.asarray([0.4, 0.35, np.inf]))
    assert out2 == pytest.approx([0.4, 0.35, 0.25])
    # uncapped (all inf) is the identity
    np.testing.assert_allclose(
        interleave.capacity_capped_weights(w, np.full(3, np.inf)), w)
    # infeasible caps (sum < 1) degrade to the capacity shape
    out3 = interleave.capacity_capped_weights(w, np.asarray([0.2, 0.2, 0.1]))
    np.testing.assert_allclose(out3, np.asarray([0.4, 0.4, 0.2]))
    # every positive-weight node capped, excess landing on zero-weight
    # uncapped nodes: must water-fill evenly, not NaN (inf/inf)
    out4 = interleave.capacity_capped_weights(
        np.asarray([0.5, 0.5, 0.0]), np.asarray([0.3, 0.3, np.inf]))
    np.testing.assert_allclose(out4, np.asarray([0.3, 0.3, 0.4]))
