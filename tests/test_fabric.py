"""Memory-fabric API (ISSUE 5 / DESIGN.md §8): the single placement surface.

Covers the API boundary itself (grep-enforced: serve/scheduler modules only
touch FabricView, the attach back-channels are gone), per-view quota and
ownership ledgers, the cross-tenant read-only prefix tier, the swap-slot
loan broker (grant → use → reclaim with Eq.-1 accounting), Eq.-1
calibration, the reservation-aware occupancy fix, trie-aware admission, and
a hypothesis property test over random multi-tenant interleavings."""

import dataclasses
import pathlib
import re

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:      # bare env: property tests skip individually
    from _hypothesis_stub import given, settings, st

from repro.configs import registry
from repro.core import bwmodel
from repro.placement.arbiter import DomainArbiter, DomainSpec, Priority
from repro.placement.fabric import MemoryFabric, as_view
from repro.placement.pool import BwapPagePool, MemoryDomain
from repro.scheduler import KVSwapManager, RequestScheduler
from repro.serve.engine import ServeEngine

SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"

SPECS = [
    DomainSpec("hbm_local", 64, 819.0),
    DomainSpec("hbm_peer", 48, 50.0),
    DomainSpec("host", 64, 16.0),
]


@pytest.fixture(scope="module")
def small_cfg():
    cfg = registry.get_smoke_config("qwen2-0.5b")
    return dataclasses.replace(cfg, num_layers=1, compute_dtype="float32")


@pytest.fixture(scope="module")
def small_lm(small_cfg):
    from repro.models.lm import LM
    params = LM(small_cfg).init(jax.random.PRNGKey(0))
    return small_cfg, params


def _domains(fast=32, peer=24, host=24):
    return [MemoryDomain("hbm_local", fast, 819.0, True),
            MemoryDomain("hbm_peer", peer, 50.0, False),
            MemoryDomain("host", host, 16.0, False)]


def two_views(cfg, *, share_prefix=True, quota_a=(24, 18, 18),
              quota_b=(8, 6, 6)):
    fab = MemoryFabric(cfg, _domains(), page_size=4, seed=0)
    a = fab.view("A", quota=quota_a, home=(0,), level=10,
                 share_prefix=share_prefix)
    b = fab.view("B", quota=quota_b, home=(1,), level=0,
                 share_prefix=share_prefix)
    return fab, a, b


# ---------------------------------------------------------------------------
# API boundary (grep-enforced acceptance criterion)
# ---------------------------------------------------------------------------

def test_serve_scheduler_layers_only_touch_fabric_views():
    """No serve/scheduler module imports the pool or page-table internals —
    all placement access goes through FabricView. The old compat shims
    (serve/kvcache.py, serve/pagetable.py) re-export only."""
    banned = re.compile(
        r"from repro\.placement\.(pool|pagetable) import"
        r"|from repro\.serve\.(kvcache|pagetable) import"
        r"|import repro\.placement\.(pool|pagetable)\b"
        r"|BwapPagePool\(")
    shims = {"kvcache.py", "pagetable.py"}
    for pkg in ("serve", "scheduler"):
        for f in sorted((SRC / pkg).glob("*.py")):
            if f.name in shims:
                text = f.read_text()
                assert "class " not in text and "def " not in text, \
                    f"{f} must stay a pure re-export shim"
                continue
            text = f.read_text()
            m = banned.search(text)
            assert m is None, f"{f} touches pool internals: {m.group(0)!r}"


def test_attach_backchannels_are_gone():
    """attach_engine / attach_pagetable / set_reserved_counts — the four
    subsystems' pairwise glue — are neither defined nor called anywhere in
    src/ (docstrings may still name them as the design they replaced)."""
    pat = re.compile(
        r"def (attach_engine|attach_pagetable|set_reserved_counts)\b"
        r"|\.(attach_engine|attach_pagetable|set_reserved_counts)\(")
    hits = [f"{f}: {m.group(0)}" for f in SRC.rglob("*.py")
            if (m := pat.search(f.read_text()))]
    assert not hits, f"back-channel survives: {hits}"


# ---------------------------------------------------------------------------
# ledgers: quota, ownership, adoption
# ---------------------------------------------------------------------------

def test_view_quota_caps_allocation(small_cfg):
    fab, a, b = two_views(small_cfg, quota_b=(2, 1, 1))
    pages = []
    for _ in range(4):                     # B's whole quota
        b.append_page(pages)
    assert b.free_count() == 0
    with pytest.raises(RuntimeError, match="quota exhausted"):
        b.append_page(pages)
    # A is unaffected by B's exhaustion
    other = []
    a.append_page(other)
    fab.check_invariants()
    b.release(pages)
    a.release(other)
    fab.check_invariants()
    assert not fab.owner and not fab.table.ref


def test_adopted_pool_matches_direct_driving(small_cfg):
    """as_view over a bare pool delegates placement to the pool's own
    cycle: allocation order is bit-identical to pool.alloc_page."""
    mk = lambda: BwapPagePool(small_cfg, _domains(), page_size=4, seed=0)
    direct, adopted = mk(), mk()
    view = as_view(adopted)
    assert as_view(adopted) is view        # cached, one fabric per pool
    got = []
    want = [direct.alloc_page() for _ in range(20)]
    pages = []
    for _ in range(20):
        got.append(view.append_page(pages))
    assert got == want
    view.fabric.check_invariants()


def test_ownership_follows_last_holder(small_cfg):
    """A page allocated by A but shared into B survives A's release with
    ownership (and the quota charge) moving to B."""
    fab, a, b = two_views(small_cfg)
    ps = fab.pool.page_size
    tokens = list(range(100, 100 + ps))
    pages_a = []
    a.append_page(pages_a)
    a.register_prefix(tokens, pages_a, ps)
    pages_b = []
    assert b.probe_prefix(tokens, pages_b) == ps
    pid = pages_b[0]
    assert fab.owner[pid] == "A"
    a.release(pages_a)
    assert fab.owner[pid] == "B"           # re-owned, not freed
    assert fab.table.ref[pid] == 1
    fab.check_invariants()
    b.release(pages_b)
    assert pid not in fab.table.ref
    fab.check_invariants()


# ---------------------------------------------------------------------------
# cross-tenant prefix tier
# ---------------------------------------------------------------------------

def test_cross_tenant_prefix_sharing_is_gated(small_cfg):
    ps = 4
    tokens = list(range(7, 7 + 2 * ps))

    def donor_and_probe(share):
        fab, a, b = two_views(small_cfg, share_prefix=share)
        pages_a = []
        a.append_page(pages_a)
        a.append_page(pages_a)
        a.register_prefix(tokens, pages_a, 2 * ps)
        pages_b = []
        matched = b.probe_prefix(tokens, pages_b)
        return fab, matched, pages_a, pages_b

    fab, matched, pages_a, pages_b = donor_and_probe(True)
    assert matched == 2 * ps               # opted in: full cross-match
    assert pages_b == pages_a              # same physical pages
    assert fab.cross_shared_pages() == 2
    fab.check_invariants()

    fab, matched, _, pages_b = donor_and_probe(False)
    assert matched == 0 and not pages_b    # opted out: tier closed
    assert fab.cross_shared_pages() == 0


def test_share_events_fire_on_cross_tenant_match(small_cfg):
    fab, a, b = two_views(small_cfg)
    events = []
    fab.subscribe("share", lambda **kw: events.append(kw))
    ps = fab.pool.page_size
    tokens = list(range(50, 50 + ps))
    pages_a = []
    a.append_page(pages_a)
    a.register_prefix(tokens, pages_a, ps)
    pages_b = []
    b.probe_prefix(tokens, pages_b)
    assert [e for e in events if e.get("kind") == "prefix"
            and e["owner"] == "A" and e["view"] == "B"]


# ---------------------------------------------------------------------------
# swap-slot loans: grant -> use -> reclaim
# ---------------------------------------------------------------------------

def test_loan_cycle_grant_use_reclaim(small_cfg):
    fab, a, b = two_views(small_cfg, quota_a=(20, 16, 16),
                          quota_b=(10, 8, 8))
    swap_a = KVSwapManager(a, reserve_fraction=0.5)      # idle lender
    swap_b = KVSwapManager(b, reserve_pages={"host": 2})
    lender_free = swap_a.slots_free()
    assert b.borrowable() > 0
    # s1 fits B's own 2 slots; s2 (3 pages) must borrow 3 from A
    s1, s2 = [], []
    for _ in range(2):
        b.append_page(s1)
    for _ in range(3):
        b.append_page(s2)
    fab.pool.k_pool = fab.pool.k_pool.at[:, s2].set(7.25)
    p1, _ = swap_b.swap_out(list(s1))
    assert swap_a.slots_free() == lender_free            # no loan yet
    assert swap_b.can_swap_out(3)
    p2, _ = swap_b.swap_out(list(s2))
    assert swap_a.slots_free() == lender_free - 3        # grant
    loan = fab.loans[0]
    assert (loan.lender, loan.borrower) == ("A", "B")
    assert loan.granted == 3 and len(loan.slots) == 3
    fab.check_invariants()
    # use: parked KV sits in borrowed slots
    assert sum(1 for p in p2 if p in swap_b._borrowed) > 0
    # s1 swaps back in: B's own slots are free again
    s1b, _ = swap_b.swap_in(p1)
    # reclaim while s2 is parked: B vacates a loaned slot by relocating
    # the bytes into its own reservation (one copy, Eq.-1 accounted)
    got, secs = a.recall_loans(1)
    assert got == 1 and secs > 0.0
    assert loan.reclaimed == 1 and loan.reclaim_seconds == secs
    assert len(loan.slots) == 2
    fab.check_invariants()
    # ...and s2 still swaps in bit-intact through the forwarding map
    s2b, _ = swap_b.swap_in(p2)
    assert (np.asarray(fab.pool.k_pool)[:, s2b] == 7.25).all()
    fab.check_invariants()
    # idle loaned slots return instantly on recall
    got, secs = a.recall_loans(99)
    assert got == 2 and secs == 0.0
    assert not loan.slots and swap_a.slots_free() == lender_free
    b.release(s1b)
    b.release(s2b)
    fab.check_invariants()


def test_loans_respect_lend_optout(small_cfg):
    fab, a, b = two_views(small_cfg)
    KVSwapManager(a, reserve_fraction=0.5, lend=False)
    KVSwapManager(b, reserve_pages={"host": 1})
    assert b.borrowable() == 0
    assert fab.request_loan(b, 4) == 0


# ---------------------------------------------------------------------------
# Eq.-1 calibration (ROADMAP real-machine calibration)
# ---------------------------------------------------------------------------

def test_calibrate_ewma_tracks_measured_transfer_times(small_cfg):
    fab = MemoryFabric(small_cfg, _domains(), page_size=4, seed=0,
                       calibration_alpha=0.5)
    view = fab.view("t", quota=(8, 8, 8), home=(0,))
    pages = []
    for _ in range(3):
        view.append_page(pages)
    analytic = view.stall_cost(pages)
    assert analytic == pytest.approx(bwmodel.stall_cost(
        view.footprint(pages), np.asarray([819.0, 50.0, 16.0])))
    # the machine is 10x slower than the analytic profile says: feed
    # measured seconds-per-page samples until the EWMA converges
    measured = [10 * fab.pool.page_bytes / (bw * 1e9)
                for bw in (819.0, 50.0, 16.0)]
    prev = analytic
    for _ in range(12):
        fab.calibrate(measured)
        cur = view.stall_cost(pages)
        assert cur >= prev - 1e-18         # EWMA approaches monotonically
        prev = cur
    assert view.stall_cost(pages) == pytest.approx(10 * analytic, rel=0.01)
    # None skips a domain; partial samples only move their own domain
    bw_before = fab.bw_effective.copy()
    fab.calibrate([None, measured[1], None])
    assert fab.bw_effective[0] == bw_before[0]
    assert fab.bw_effective[2] == bw_before[2]
    # swap transfer estimates read the calibrated bandwidths too: one page
    # read from domain 1, written to (slower) domain 2 — Eq.-1 takes the
    # slower side under the *effective* bandwidths
    sw = KVSwapManager(view, reserve_fraction=0.2)
    assert sw._transfer_seconds([1], [2]) == pytest.approx(
        fab.pool.page_bytes / (fab.bw_effective[2] * 1e9), rel=1e-6)


# ---------------------------------------------------------------------------
# occupancy regression (reserved slots are not free headroom)
# ---------------------------------------------------------------------------

def test_occupancy_counts_reserved_pages_per_domain(small_cfg):
    pool = BwapPagePool(small_cfg, _domains(peer=20), page_size=4)
    # reserving alone is not utilization: occupancy stays zero
    pool.reserve_pages(1, 10)
    assert pool.occupancy()["hbm_peer"] == 0.0
    assert pool.used_pages()[1] == 0
    # fill everything the domain can still allocate: occupancy must read
    # 1.0 — the old num_pages denominator reported 0.5 free headroom on a
    # domain with nothing left, and capacity readers over-allocated into it
    taken = [pool.free[1].pop() for _ in range(len(pool.free[1]))]
    assert pool.occupancy()["hbm_peer"] == 1.0
    assert pool.used_pages()[1] == len(taken)


# ---------------------------------------------------------------------------
# trie-aware admission (ROADMAP)
# ---------------------------------------------------------------------------

def test_trie_aware_admission_admits_shared_prefix_concurrently(small_lm):
    """Conservative admission bounds a request by its *physical* remaining
    footprint: trie-shared pages are already resident, so a second
    same-prefix request joins the batch even though the pair's logical
    worst case (2 x 11 = 22 pages) exceeds the 16-page pool. With sharing
    off, the identical trace serializes — the second request stays queued
    until the first finishes."""
    cfg, params = small_lm
    ps = 4
    prefix = list(range(1, 1 + 8 * ps))    # 8 pages of shared prompt

    def run(reuse: bool):
        pool = BwapPagePool(cfg, _domains(fast=8, peer=4, host=4),
                            page_size=ps)
        sched = RequestScheduler(pool, max_batch=2, default_max_new=8,
                                 conservative_admission=True)
        eng = ServeEngine(cfg, params, pool, scheduler=sched,
                          wall_clock=False, sim_step_s=0.001,
                          prefix_reuse=reuse)
        eng.submit(prefix + [7, 7])
        eng.submit(prefix + [9, 9])
        peak_running = steps = 0
        while (eng.active or eng.waiting) and steps < 200:
            eng.step()
            steps += 1
            peak_running = max(peak_running, len(eng.scheduler.running))
        assert len(eng.finished) == 2
        return peak_running, steps, pool

    concurrent, steps_on, pool = run(True)
    assert concurrent == 2                 # physically fits: batched
    assert pool.table.prefix_hit_pages >= 8
    serialized, steps_off, _ = run(False)
    assert serialized == 1                 # logical worst case: queued
    assert steps_on < steps_off


# ---------------------------------------------------------------------------
# multi-tenant property test: alloc/share/loan/reclaim/migrate
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 10 ** 6)),
                min_size=1, max_size=40),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_fabric_invariants_under_random_interleavings(ops, seed):
    """Random multi-tenant interleavings of alloc / cross-tenant share /
    swap (loan) / reclaim / migrate / release hold the fabric invariants
    after every operation: refcounts == view holds, per-domain ledgers ==
    ownership map, page ids conserved — and unregister leaks nothing."""
    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    fab = MemoryFabric(cfg, _domains(), page_size=4, seed=0)
    views = {
        "A": fab.view("A", quota=(12, 9, 9), home=(0,)),
        "B": fab.view("B", quota=(12, 9, 9), home=(1,)),
    }
    swaps = {n: KVSwapManager(v, reserve_pages={"host": 2})
             for n, v in views.items()}
    rng = np.random.default_rng(seed)
    ps = fab.pool.page_size
    streams = {g: list(range(1000 * (g + 1), 1000 * (g + 1) + 3 * ps))
               for g in range(3)}
    seqs = []                              # {view, pages, parked}

    def pick_view():
        return "A" if rng.integers(2) == 0 else "B"

    for op, arg in ops:
        name = pick_view()
        v, sw = views[name], swaps[name]
        mine = [s for s in seqs if s["view"] == name]
        if op == 0:                        # alloc a fresh sequence
            if v.free_count() < 3:
                continue
            pages = []
            v.grow(pages, int(rng.integers(1, 4)))
            seqs.append({"view": name, "pages": pages, "parked": False})
        elif op == 1:                      # share: probe + register prefix
            toks = streams[arg % 3]
            if v.free_count() < 3:
                continue
            pages = []
            matched = v.probe_prefix(toks, pages) // ps
            for _ in range(matched, 3):
                v.append_page(pages)
            v.register_prefix(toks, pages, 3 * ps)
            seqs.append({"view": name, "pages": pages, "parked": False})
        elif op == 2 and mine:             # swap out (may borrow slots)
            s = mine[arg % len(mine)]
            if s["parked"]:
                continue
            excl = len(v.exclusive(s["pages"]))
            if excl and sw.can_swap_out(excl):
                s["pages"], _ = sw.swap_out(s["pages"])
                s["parked"] = True
        elif op == 3 and mine:             # swap in / lender reclaim
            s = mine[arg % len(mine)]
            if s["parked"]:
                if v.free_count() >= sw.parked_count(s["pages"]):
                    s["pages"], _ = sw.swap_in(s["pages"])
                    s["parked"] = False
            else:
                v.recall_loans(int(rng.integers(1, 4)))
        elif op == 4 and mine:             # migrate live pages
            s = mine[arg % len(mine)]
            if not s["parked"]:
                s["pages"] = v.migrate(s["pages"])
        elif op == 5 and mine:             # release
            s = mine[arg % len(mine)]
            if not s["parked"]:
                v.release(s["pages"])
                seqs.remove(s)
        fab.check_invariants()

    # unregister B: drain it first — live sequences release, parked ones
    # swap in when capacity allows and otherwise discard in place
    # (release_parked), then the fabric closes B's swap manager (loans
    # settle, reservation returns) as part of unregister
    for s in [s for s in seqs if s["view"] == "B"]:
        if s["parked"]:
            if views["B"].free_count() >= swaps["B"].parked_count(
                    s["pages"]):
                s["pages"], _ = swaps["B"].swap_in(s["pages"])
                s["parked"] = False
            else:
                live = swaps["B"].release_parked(s["pages"])
                views["B"].release(live)
                seqs.remove(s)
                continue
        views["B"].release(s["pages"])
        seqs.remove(s)
    fab.check_invariants()
    fab.unregister("B")
    # no cross-tenant page leaks: every live page is owned by A (or parked
    # by A's swap manager), none by the ghost tenant
    assert all(o == "A" for o in fab.owner.values())
    a_parked = set(swaps["A"].parked_ids())
    for pid in fab.table.ref:
        held = views["A"]._held.get(pid, 0)
        assert held > 0 or pid in a_parked, f"page {pid} leaked"
    assert not any(ln.slots for ln in fab.loans), "loan slots dangling"
