"""Compute-follows-data (DESIGN.md §11): per-domain micro-batch decode
partitioning, heat-driven re-homing of hot shared pages, per-launch drift
billing, and bytes-weighted heat — micro-batched execution must be
token-identical and leak-free vs the global-batch oracle."""

import dataclasses
import types

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:      # bare env: property tests skip individually
    from _hypothesis_stub import given, settings, st

from repro.configs import registry
from repro.core import bwmodel
from repro.core.dwp import DWPConfig
from repro.obs.drift import DriftLedger
from repro.obs.heat import PageHeat
from repro.obs.observatory import Observatory
from repro.placement.fabric import as_view
from repro.scheduler import RequestScheduler, WorkloadSpec, generate
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BwapPagePool, MemoryDomain


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    from repro.models.lm import LM
    model = LM(cfg)
    params = model.init(__import__("jax").random.PRNGKey(0))
    return cfg, params


def _pool(cfg, fast=8, peer=24, host=40, page_size=4, policy="bwap_dwp"):
    """Small fast domain; slow bandwidths in the engine-latency range so
    Eq.-1 terms (and re-homing savings) are visible; tuner frozen."""
    return BwapPagePool(cfg, [
        MemoryDomain("hbm_local", fast, 819.0, True),
        MemoryDomain("hbm_peer", peer, 0.00125, False),
        MemoryDomain("host", host, 0.0004, False),
    ], page_size=page_size, policy=policy,
        dwp_config=DWPConfig(n=10 ** 6, c=1))


# ---------------------------------------------------------------------------
# bwmodel.move_cost
# ---------------------------------------------------------------------------

def test_move_cost_read_and_write_bottlenecks():
    bw = np.array([2.0, 1.0])
    # 2 GB from domain 0: read 2/2 = 1 s, write into domain 1: 2/1 = 2 s
    assert bwmodel.move_cost(np.array([2e9, 0.0]), bw, 1) \
        == pytest.approx(2.0)
    # same bytes into domain 0: write 2/2 = 1 s, read from 1: 2/1 = 2 s
    assert bwmodel.move_cost(np.array([0.0, 2e9]), bw, 0) \
        == pytest.approx(2.0)
    # reads overlap across sources (Eq.-1 shape), writes serialize
    assert bwmodel.move_cost(np.array([2e9, 1e9]), bw, 0) \
        == pytest.approx(1.5)
    assert bwmodel.move_cost(np.zeros(2), bw, 0) == 0.0


# ---------------------------------------------------------------------------
# scheduler: launch partitioning + remap patching
# ---------------------------------------------------------------------------

def _grow(pool, n):
    pages = []
    pool.table.grow(pages, n)
    return pages


def test_launch_groups_partition_by_bottleneck_domain(tiny_lm):
    cfg, _ = tiny_lm
    pool = _pool(cfg, fast=4, peer=8, host=8)
    sched = RequestScheduler(pool, max_batch=8, default_max_new=4,
                             micro_batch=True)
    fast_pages = _grow(pool, 4)              # fills hbm_local exactly
    slow_pages = _grow(pool, 3)              # spills to a slow domain
    assert {pool.domain_of(p) for p in fast_pages} == {0}
    assert 0 not in {pool.domain_of(p) for p in slow_pages}
    r_fast = types.SimpleNamespace(pages=fast_pages)
    r_slow = types.SimpleNamespace(pages=slow_pages)
    r_none = types.SimpleNamespace(pages=[])

    groups = sched._launch_groups([r_fast, r_slow])
    assert groups is not None and len(groups) == 2
    by_dom = dict(groups)
    assert by_dom[0] == [r_fast]
    assert [r_slow] in [g for d, g in groups if d != 0]

    # all requests bottlenecked on one domain -> no partition (None)
    assert sched._launch_groups([r_fast, r_fast]) is None
    # empty footprint falls back to the fastest domain
    assert sched._launch_groups([r_fast, r_none]) is None


def test_apply_page_remap_patches_every_queue(tiny_lm):
    cfg, _ = tiny_lm
    pool = _pool(cfg)
    sched = RequestScheduler(pool, max_batch=4, default_max_new=4)
    mk = lambda *pages: types.SimpleNamespace(pages=list(pages))
    a, b, c, d = mk(1, 2), mk(2, 3), mk(7), mk()
    sched.queued, sched.prefilling = [a], [b]
    sched.running, sched.swapped = [c], [d]
    sched._apply_page_remap({2: 20, 7: 70})
    assert a.pages == [1, 20] and b.pages == [20, 3]
    assert c.pages == [70] and d.pages == []


# ---------------------------------------------------------------------------
# fabric: re-home candidate ranking + budgeted migration
# ---------------------------------------------------------------------------

def _shared_slow_setup(cfg, *, n_prefix=3):
    """Fill fast with exclusive pages, then land a shared prefix chain in
    the slow domains — all through the fabric view, so the ownership map
    the re-homer consults is live. Returns (pool, view, filler, prefix,
    holder)."""
    pool = _pool(cfg, fast=4, peer=16, host=16)
    view = as_view(pool)
    ps = pool.page_size
    filler: list = []
    view.grow(filler, 4)
    prefix: list = []
    view.grow(prefix, n_prefix)
    assert all(pool.domain_of(p) != 0 for p in prefix)
    tokens = list(range(1, 1 + n_prefix * ps))
    view.register_prefix(tokens, prefix, len(tokens))
    holder: list = []
    assert view.probe_prefix(tokens, holder) == n_prefix * ps
    assert all(view.shared(p) for p in prefix)
    return pool, view, filler, prefix, holder


def test_rehome_candidates_only_hot_shared_slow_pages(tiny_lm):
    cfg, _ = tiny_lm
    pool, view, filler, prefix, _ = _shared_slow_setup(cfg)
    heat = PageHeat(pool)
    # filler (exclusive, fast) and prefix[2] (shared, cold) must not rank
    heat.touch(filler)
    heat.touch(prefix[:2], weights=[4.0, 1.0])
    heat.step()
    cands = view.rehome_candidates(heat)
    assert [pid for pid, _, _ in cands] == [prefix[0], prefix[1]]
    ranks = [rank for _, _, rank in cands]
    assert ranks == sorted(ranks, reverse=True)     # hotter-x-saving first


def test_rehome_hot_respects_budget_and_profitability(tiny_lm):
    cfg, _ = tiny_lm
    pool, view, filler, prefix, _ = _shared_slow_setup(cfg)
    heat = PageHeat(pool)
    heat.touch(prefix, weights=[8.0, 8.0, 0.5])     # third page barely warm
    heat.step()
    bw = view.fabric.bw_effective
    pb = float(view.page_bytes)
    one_page = max(pb / (bw[pool.domain_of(prefix[0])] * 1e9),
                   pb / (bw[0] * 1e9))
    # room in fast but budget covers only one page's transfer
    view.release(filler)
    moves, cost = view.rehome_hot(heat, budget_s=one_page * 1.5)
    assert len(moves) == 1 and cost <= one_page * 1.5
    assert set(moves) <= {prefix[0], prefix[1]}      # a hot page, not warm
    view.fabric.check_invariants()
    # ample budget: the other hot page moves, the barely-warm one is
    # skipped (its heat x per-read saving does not pay for the transfer)
    moves2, _ = view.rehome_hot(heat, budget_s=10.0)
    assert set(moves) | set(moves2) == {prefix[0], prefix[1]}
    assert prefix[2] not in moves2
    view.fabric.check_invariants()


def test_rehome_hot_all_holders_remap_preserves_kv(tiny_lm):
    cfg, _ = tiny_lm
    pool, view, filler, prefix, holder = _shared_slow_setup(cfg)
    pool.k_pool = pool.k_pool.at[:, prefix].set(3.5)
    pool.v_pool = pool.v_pool.at[:, prefix].set(-3.5)
    heat = PageHeat(pool)
    heat.touch(prefix, weights=[9.0, 9.0, 9.0])
    heat.step()
    seen = []
    view.on_page_remap(seen.append)
    view.release(filler)                             # fast frees up
    free0 = pool.free_count()
    moves, _ = view.rehome_hot(heat, budget_s=10.0)
    assert set(moves) == set(prefix)
    assert all(pool.domain_of(new) == 0 for new in moves.values())
    assert seen == [moves]                           # holders were notified
    view.fabric.check_invariants()
    assert pool.free_count() == free0                # old ids recycled
    new = [moves[p] for p in prefix]
    assert (np.asarray(pool.k_pool)[:, new] == 3.5).all()
    assert (np.asarray(pool.v_pool)[:, new] == -3.5).all()
    # both holders still release cleanly through the remapped ids
    view.release([moves.get(p, p) for p in holder])
    view.release(new)
    view.fabric.check_invariants()


# ---------------------------------------------------------------------------
# drift: per-launch billing
# ---------------------------------------------------------------------------

def test_observe_launches_bills_only_read_domains(tiny_lm):
    cfg, _ = tiny_lm
    pool = _pool(cfg)
    view = as_view(pool)
    led = DriftLedger(view.fabric, calibrate_every=10 ** 9)
    bw = view.fabric.bw_effective

    def probe(kind, bpd):
        return np.asarray(bpd) / (bw * 1e9)

    launches = [(np.array([4096.0, 0.0, 0.0]), 1e-8),
                (np.array([0.0, 8192.0, 0.0]), 1e-3),
                (np.zeros(3), 0.5)]                  # zero bytes: skipped
    assert led.observe_launches("batch_read", launches, probe) == 2
    assert led.summary()["domain_samples"] == [1, 1, 0]


# ---------------------------------------------------------------------------
# heat: bytes-weighted touches + Prometheus export
# ---------------------------------------------------------------------------

def test_heat_touch_weights(tiny_lm):
    cfg, _ = tiny_lm
    pool = _pool(cfg)
    pages = _grow(pool, 2)
    heat = PageHeat(pool)
    heat.touch(pages, weights=[1.0, 0.25])
    assert heat.value(pages[0]) == pytest.approx(1.0)
    assert heat.value(pages[1]) == pytest.approx(0.25)
    heat.touch([pages[1]])                           # default weight 1.0
    assert heat.value(pages[1]) == pytest.approx(1.25)


def test_engine_page_read_weights_partial_tail(tiny_lm):
    cfg, params = tiny_lm
    pool = _pool(cfg)
    eng = ServeEngine(cfg, params, pool, wall_clock=False, sim_step_s=0.01)
    # 6 tokens over page_size 4: full first page, half-full tail page
    seq = types.SimpleNamespace(pages=[10, 11], length=6)
    w = eng._page_read_weights([seq])
    assert w == {10: 1.0, 11: pytest.approx(0.5)}
    # a second holder reading deeper takes the max
    seq2 = types.SimpleNamespace(pages=[11], length=4)
    w = eng._page_read_weights([seq, seq2])
    assert w[11] == 1.0


def test_heat_histograms_in_prometheus_text(tiny_lm):
    cfg, _ = tiny_lm
    pool = _pool(cfg)
    obs = Observatory(pool, tracer=False, drift=False)
    pages = _grow(pool, 3)
    obs.heat.touch(pages, weights=[2.0, 1.0, 0.5])
    obs.heat.step()
    obs.refresh_heat_gauges()
    text = obs.metrics.prometheus_text()
    assert 'repro_page_heat{domain="hbm_local",stat="pages"} 3' in text
    assert 'stat="max"' in text and 'stat="p95"' in text


# ---------------------------------------------------------------------------
# workload: domain_skew and hot_prefix traces
# ---------------------------------------------------------------------------

def test_domain_skew_trace_shape():
    spec = WorkloadSpec(kind="domain_skew", num_requests=8, skew_frac=0.5,
                        mean_interarrival_s=0.02, prompt_mean=4,
                        prompt_max=24, max_new=8, vocab_size=500, seed=3,
                        prefix_len=8, prefix_groups=1, prefix_frac=1.0)
    trace = generate(spec)
    again = generate(spec)
    assert [t.prompt for t in trace] == [t.prompt for t in again]
    flood, tail = trace[:4], trace[4:]
    # the flood: prompt_max-length prompts, back-to-back, no prefix
    prefix = tail[0].prompt[:8]
    assert all(len(t.prompt) == 24 for t in flood)
    assert all(t.prompt[:8] != prefix for t in flood)
    assert flood[-1].arrival_s < 4 * 0.02 / 50      # gaps at mean/100
    # the steady tail all carries the shared template
    assert all(t.prompt[:8] == prefix for t in tail)
    assert all(len(t.prompt) < 24 for t in tail)


def test_hot_prefix_trace_defaults_one_hot_template():
    spec = WorkloadSpec(kind="hot_prefix", num_requests=6,
                        mean_interarrival_s=0.01, prompt_mean=6,
                        prompt_max=40, max_new=4, vocab_size=500, seed=1)
    trace = generate(spec)
    head = trace[0].prompt[:12]                     # 2 * prompt_mean tokens
    assert all(t.prompt[:12] == head for t in trace)
    arrivals = [t.arrival_s for t in trace]
    assert arrivals == sorted(arrivals) and arrivals[0] > 0


# ---------------------------------------------------------------------------
# engine: micro-batch + re-homing vs the global-batch oracle
# ---------------------------------------------------------------------------

def _contention_trace(cfg, seed=0):
    """Fillers claim the fast domain first; sharers of one hot 16-token
    template arrive while it is full, so the template lands slow."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, cfg.vocab_size, 16).tolist()
    fillers = [(rng.integers(1, cfg.vocab_size, 16).tolist(), 4, 0.0)
               for _ in range(3)]
    sharers = [(prefix + rng.integers(1, cfg.vocab_size, 4).tolist(),
                24, 0.02 + 0.01 * i) for i in range(3)]
    return fillers + sharers


def _run_policy(cfg, params, policy, trace, *, invariants_every_step=False):
    pool = _pool(cfg, fast=8, peer=24, host=40, policy=policy)
    view = as_view(pool)
    obs = Observatory(pool, tracer=False, drift=False)
    sched = RequestScheduler(pool, max_batch=8, prefill_token_budget=32,
                             default_max_new=24)
    eng = ServeEngine(cfg, params, pool, scheduler=sched,
                      wall_clock=False, sim_step_s=0.01)
    free0 = pool.free_count()
    for prompt, max_new, arr in trace:
        eng.submit(list(prompt), max_new=max_new, arrival_s=arr)
    steps = 0
    while (eng.active or eng.waiting) and steps < 600:
        eng.step()
        if invariants_every_step:
            view.fabric.check_invariants()
        steps += 1
    view.fabric.check_invariants()
    assert len(eng.finished) == len(trace)
    assert pool.free_count() == free0, "run leaked pages"
    assert pool.table.ref == {}, "run leaked page-table holds"
    return ({s.sid: list(s.tokens) for s in eng.finished}, eng, obs)


def test_coda_token_identical_rehomes_and_counts_launches(tiny_lm):
    cfg, params = tiny_lm
    trace = _contention_trace(cfg)
    toks_coda, eng_coda, obs = _run_policy(
        cfg, params, "coda", trace, invariants_every_step=True)
    toks_glob, eng_glob, _ = _run_policy(cfg, params, "bwap_dwp", trace)
    assert toks_coda == toks_glob, \
        "micro-batching/re-homing changed generated tokens"
    assert eng_coda.rehome and eng_coda.scheduler.micro_batch
    assert not eng_glob.rehome and not eng_glob.scheduler.micro_batch
    assert eng_coda.rehomed_pages > 0 and eng_glob.rehomed_pages == 0
    text = obs.metrics.prometheus_text()
    assert 'repro_decode_launches_total{view="default",domain=' in text
    assert 'repro_rehomed_pages_total{view="default"}' in text


def _random_schedule_roundtrip(cfg, params, seed):
    trace = generate(WorkloadSpec(
        kind="domain_skew", num_requests=5, skew_frac=0.4,
        mean_interarrival_s=0.02, prompt_mean=4, prompt_max=16,
        max_new=6, vocab_size=cfg.vocab_size, seed=seed,
        prefix_len=8, prefix_groups=1, prefix_frac=1.0))
    rows = [(t.prompt, t.max_new, t.arrival_s) for t in trace]
    toks_coda, _, _ = _run_policy(cfg, params, "coda", rows,
                                  invariants_every_step=True)
    toks_glob, _, _ = _run_policy(cfg, params, "bwap_dwp", rows)
    assert toks_coda == toks_glob


@pytest.mark.parametrize("seed", [1, 7])
def test_coda_random_schedules_match_oracle(tiny_lm, seed):
    cfg, params = tiny_lm
    _random_schedule_roundtrip(cfg, params, seed)


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 20))
def test_coda_random_schedules_match_oracle_property(tiny_lm, seed):
    cfg, params = tiny_lm
    _random_schedule_roundtrip(cfg, params, seed)
