"""Persistence tier under the fabric (DESIGN.md §9): Eq.-1 tier pricing,
cold demotion / promotion bit-exactness, the restart-surviving prefix
store, peer page export/import, tier telemetry, and the property test
over demote → restart → promote → free interleavings."""

from __future__ import annotations

import dataclasses
import pathlib
import re

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:                  # optional dep (see stub)
    from _hypothesis_stub import given, settings, st

from repro.configs import registry
from repro.core import bwmodel
from repro.core.dwp import DWPConfig
from repro.placement.fabric import as_view
from repro.placement.persist import (PersistentTier, deserialize_range,
                                     kv_layout_metadata, serialize_range)
from repro.placement.pool import BwapPagePool, MemoryDomain
from repro.scheduler import KVSwapManager

SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                               num_layers=2, compute_dtype="float32")


def _pool(cfg, fast=12, peer=12, host=16, page_size=4):
    return BwapPagePool(cfg, [
        MemoryDomain("hbm_local", fast, 819.0, True),
        MemoryDomain("hbm_peer", peer, 0.05, False),
        MemoryDomain("host", host, 0.016, False),
    ], page_size=page_size, dwp_config=DWPConfig(n=10 ** 6, c=1))


def _rig(cfg, **tier_kw):
    tier_kw.setdefault("bw_gbps", 0.008)
    tier_kw.setdefault("capacity_pages", 32)
    pool = _pool(cfg)
    view = as_view(pool)
    tier = PersistentTier(**tier_kw)
    view.fabric.attach_persist(tier)
    return pool, view, tier


def _fill(pool, pid, val):
    pool.k_pool = pool.k_pool.at[:, pid].set(float(val))
    pool.v_pool = pool.v_pool.at[:, pid].set(float(-val))


def _chain(view, pool, tokens, val):
    """Register one page-aligned prompt chain with recognizable bytes."""
    pages = []
    for i in range(len(tokens) // pool.page_size):
        view.append_page(pages)
        _fill(pool, pages[-1], val + i)
    view.register_prefix(list(tokens), pages, len(tokens))
    return pages


# ---------------------------------------------------------------------------
# Eq. 1 with the tier row
# ---------------------------------------------------------------------------

def test_stall_cost_tier_row():
    b, bw = np.array([8e9]), np.array([8.0])
    assert bwmodel.stall_cost(b, bw) == pytest.approx(1.0)
    # the tier is just one more (slow) row under the same max
    assert bwmodel.stall_cost(b, bw, tier_bytes=8e9, tier_bw_gbps=0.8) \
        == pytest.approx(10.0)
    # a fast tier row never dominates a slow domain row
    assert bwmodel.stall_cost(b, bw, tier_bytes=8e9, tier_bw_gbps=80.0) \
        == pytest.approx(1.0)
    # tier_bytes=0 keeps the pre-tier behaviour exactly
    assert bwmodel.stall_cost(b, bw, tier_bytes=0.0) == pytest.approx(1.0)
    with pytest.raises(AssertionError):
        bwmodel.stall_cost(b, bw, tier_bytes=1.0)       # no tier bandwidth


# ---------------------------------------------------------------------------
# demotion / promotion through the swap path
# ---------------------------------------------------------------------------

def test_demote_promote_bit_exact(cfg):
    pool, view, tier = _rig(cfg)
    swap = KVSwapManager(pool, reserve_fraction=0.5)
    pages = []
    for i in range(4):
        view.append_page(pages)
        _fill(pool, pages[-1], 10 + i)
    orig_k = np.asarray(pool.k_pool[:, pages]).copy()
    orig_v = np.asarray(pool.v_pool[:, pages]).copy()

    parked, _ = swap.swap_out(pages)
    demoted, secs = swap.demote_cold(2)
    assert demoted == 2 and secs > 0
    view.fabric.check_invariants()
    # handles are negative, never physical pages, and the admission path
    # counts them as promotable footprint while parked_count excludes them
    assert swap.demoted_count() == 2
    assert swap.promotable_count(parked) == 4
    assert swap.parked_count(parked) == 2
    assert sorted(tier.persisted_ids()) == sorted(
        h for h in (swap._resolve(p) for p in parked) if h < 0)

    back, _ = swap.swap_in(parked)
    view.fabric.check_invariants()
    assert np.array_equal(np.asarray(pool.k_pool[:, back]), orig_k)
    assert np.array_equal(np.asarray(pool.v_pool[:, back]), orig_v)
    assert tier.used_pages() == 0 and swap.demoted_count() == 0
    view.release(back)
    swap.close()
    view.fabric.check_invariants()


def test_demote_pricing_matches_eq1(cfg):
    """Demotion seconds equal Eq. 1 over {source domains} ∪ {tier row} —
    with the tier far slower than every slow domain, the tier row is the
    max: total_bytes / tier_bw."""
    pool, view, tier = _rig(cfg)
    swap = KVSwapManager(pool, reserve_fraction=0.5)
    pages = []
    for _ in range(3):
        view.append_page(pages)
    parked, _ = swap.swap_out(pages)
    n, secs = swap.demote_cold(3)
    assert n == 3
    expect = 3 * pool.page_bytes / (tier.bw_gbps * 1e9)
    assert secs == pytest.approx(expect)
    back, _ = swap.swap_in(parked)
    view.release(back)
    swap.close()


def test_demoted_page_dies_cold(cfg):
    """release_parked on a demoted page drops the tier bytes in place —
    no promotion copy, no leaked handle, empty tier at close."""
    pool, view, tier = _rig(cfg)
    swap = KVSwapManager(pool, reserve_fraction=0.5)
    pages = []
    for _ in range(2):
        view.append_page(pages)
    moved, _ = swap.swap_out(pages)
    swap.demote_cold(2)
    assert tier.used_pages() == 2
    live = swap.release_parked(moved)
    assert live == [] and tier.used_pages() == 0
    view.fabric.check_invariants()
    swap.close()


# ---------------------------------------------------------------------------
# restart-surviving prefix store
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("on_disk", [False, True])
def test_prefix_store_restart_roundtrip(cfg, tmp_path, on_disk):
    directory = tmp_path if on_disk else None
    pool, view, tier = _rig(cfg, directory=directory)
    tokens = list(range(500, 512))
    pages = _chain(view, pool, tokens, 40)
    orig_k = np.asarray(pool.k_pool[:, pages]).copy()
    assert tier.pin(view, tokens) is not None
    manifest = tier.export_prefixes(view)
    assert len(manifest["chains"]) == 1
    assert manifest["staging"]["drain_time_s"] > 0
    view.release(pages)
    tier.release_pins()
    view.fabric.check_invariants()

    if on_disk:
        store = tmp_path / "prefix_store"
        assert (store / "manifest.json").exists()
        # restart = a brand-new tier object bound to the same directory
        tier = PersistentTier(bw_gbps=0.008, capacity_pages=32,
                              directory=directory)
    else:
        tier.fabric = None                 # rebind the surviving object

    pool2 = _pool(cfg)
    view2 = as_view(pool2)
    view2.fabric.attach_persist(tier)
    restored, secs = tier.import_prefixes(view2)
    assert restored == 3 and secs > 0
    view2.fabric.check_invariants()
    got = []
    assert view2.probe_prefix(tokens, got) == 12
    assert np.array_equal(np.asarray(pool2.k_pool[:, got]), orig_k)
    view2.release(got)


def test_prefix_store_geometry_mismatch(cfg):
    pool, view, tier = _rig(cfg)
    pages = _chain(view, pool, list(range(300, 308)), 7)
    tier.pin(view, list(range(300, 308)))
    tier.export_prefixes(view)
    other = _pool(cfg, page_size=8)        # different geometry
    view8 = as_view(other)
    tier.fabric = None
    view8.fabric.attach_persist(tier)
    with pytest.raises(ValueError, match="geometry"):
        tier.import_prefixes(view8)


def test_prefix_store_quota_full_never_aborts(cfg):
    """A store bigger than the importing view's quota restores what fits
    and keeps the fabric consistent — never raises."""
    pool, view, tier = _rig(cfg)
    for i in range(3):                     # three 2-page chains, 6 pages
        toks = [1000 * (i + 1) + t for t in range(8)]
        _chain(view, pool, toks, 50 + 10 * i)
        tier.pin(view, toks)
    tier.export_prefixes(view)

    tiny = BwapPagePool(cfg, [MemoryDomain("hbm_local", 3, 819.0, True)],
                        page_size=4, dwp_config=DWPConfig(n=10 ** 6, c=1))
    tview = as_view(tiny)
    tier.fabric = None
    tview.fabric.attach_persist(tier)
    restored, _ = tier.import_prefixes(tview)
    assert restored == 2                   # first chain fits, second breaks
    tview.fabric.check_invariants()


def test_prefix_store_corruption_detected(cfg, tmp_path):
    pool, view, tier = _rig(cfg, directory=tmp_path)
    pages = _chain(view, pool, list(range(700, 708)), 3)
    tier.pin(view, list(range(700, 708)))
    tier.export_prefixes(view)
    victim = sorted((tmp_path / "prefix_store").glob("chain_*_k.npy"))[0]
    arr = np.load(victim)
    arr.flat[0] += 1.0
    np.save(victim, arr)
    fresh = PersistentTier(bw_gbps=0.008, capacity_pages=32,
                           directory=tmp_path)
    pool2 = _pool(cfg)
    view2 = as_view(pool2)
    view2.fabric.attach_persist(fresh)
    with pytest.raises(IOError, match="checksum"):
        fresh.import_prefixes(view2)


# ---------------------------------------------------------------------------
# peer page export / import
# ---------------------------------------------------------------------------

def test_peer_export_import_bit_exact(cfg):
    pool, view, tier = _rig(cfg)
    tokens = list(range(900, 912))
    pages = _chain(view, pool, tokens, 60)
    orig_k = np.asarray(pool.k_pool[:, pages]).copy()
    used_before = view.used.copy()

    blob = deserialize_range(serialize_range(tier.export_range(view, pages)))
    assert blob["layout"]["mesh_axes"] == {"data": 4, "model": 2}
    assert blob["ledger"]["bytes"] == len(pages) * pool.page_bytes

    poolB = _pool(cfg)
    viewB = as_view(poolB)
    tierB = PersistentTier(bw_gbps=0.008, capacity_pages=32)
    viewB.fabric.attach_persist(tierB)
    new_ids, secs = tierB.import_range(viewB, blob)
    assert secs > 0
    # bit-exact adoption, balanced ledgers on both fabrics
    assert np.array_equal(np.asarray(poolB.k_pool[:, new_ids]), orig_k)
    assert np.array_equal(view.used, used_before)      # exporter unchanged
    assert int(viewB.used.sum()) == len(new_ids)
    view.fabric.check_invariants()
    viewB.fabric.check_invariants()
    # the trie chain arrived under remapped ids: same prompt, new pages
    got = []
    assert viewB.probe_prefix(tokens, got) == 12
    assert got == new_ids
    viewB.release(got)
    viewB.release(new_ids)
    viewB.fabric.check_invariants()


def test_peer_import_rejects_tampered_blob(cfg):
    pool, view, tier = _rig(cfg)
    pages = _chain(view, pool, list(range(20, 28)), 5)
    blob = deserialize_range(serialize_range(tier.export_range(view, pages)))
    blob["k"] = blob["k"].copy()
    blob["k"].flat[0] += 1.0
    poolB = _pool(cfg)
    viewB = as_view(poolB)
    tierB = PersistentTier()
    viewB.fabric.attach_persist(tierB)
    with pytest.raises(IOError, match="checksum"):
        tierB.import_range(viewB, blob)


def test_kv_layout_metadata_defaults(cfg):
    meta = kv_layout_metadata(cfg, page_size=4)
    assert meta["mesh_axes"] == {"data": 4, "model": 2}
    assert meta["dp_axes"] == ["data"]
    assert meta["mp_axis"] == "model"
    assert len(meta["kv_pool_spec"]) == 5  # [L, page, slot, kv_head, dim]


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_tier_telemetry_counters(cfg):
    pool, view, tier = _rig(cfg)
    swap = KVSwapManager(pool, reserve_fraction=0.5)
    pages = []
    for _ in range(2):
        view.append_page(pages)
    parked, _ = swap.swap_out(pages)
    swap.demote_cold(2)
    back, _ = swap.swap_in(parked)
    snap = pool.telemetry.snapshot()
    ops = snap["tiers"]["ops"]
    assert ops["demote"]["pages"] == 2 and ops["demote"]["seconds"] > 0
    assert ops["promote"]["pages"] == 2 and ops["promote"]["seconds"] > 0
    occ = snap["tiers"]["occupancy"]
    assert occ["pmem"]["used"] == 0 and occ["pmem"]["capacity"] == 32
    assert set(occ) >= {"fast_domains", "swap_slots", "pmem"}
    view.release(back)
    swap.close()


def test_restore_telemetry(cfg):
    pool, view, tier = _rig(cfg)
    toks = list(range(40, 48))
    _chain(view, pool, toks, 9)
    tier.pin(view, toks)
    tier.export_prefixes(view)
    tier.release_pins()
    pool2 = _pool(cfg)
    view2 = as_view(pool2)
    tier.fabric = None
    view2.fabric.attach_persist(tier)
    tier.import_prefixes(view2)
    ops = pool2.telemetry.snapshot()["tiers"]["ops"]
    assert ops["restore"]["pages"] == 2 and ops["restore"]["seconds"] > 0


# ---------------------------------------------------------------------------
# prefix-store capacity: LRU eviction among pins, surfaced skips
# ---------------------------------------------------------------------------

def test_prefix_store_lru_evicts_stalest_pin(cfg):
    """Over the byte cap, pinned chains are kept most-recently-touched
    first; the LRU loser is unpinned, counted, and emitted as ``evict``."""
    pool, view, tier = _rig(cfg, capacity_pages=4)   # room for 2 chains
    toks = [[1000 * (i + 1) + t for t in range(8)] for i in range(3)]
    keys = []
    for i in range(3):                               # stamps 1, 2, 3
        _chain(view, pool, toks[i], 10 * i)
        keys.append(tier.pin(view, toks[i]))
    tier.touch_pin(keys[0])                          # chain 0 now newest
    events = []
    view.fabric.subscribe("evict", lambda **kw: events.append(kw))

    manifest = tier.export_prefixes(view)
    kept = [tuple(ch["tokens"]) for ch in manifest["chains"]]
    assert kept == [tuple(toks[0]), tuple(toks[2])]  # stalest (1) evicted
    assert tier.evicted_chains == 1 and tier.skipped_chains == 0
    assert keys[1] not in tier._pins and keys[0] in tier._pins
    assert events == [{"view": view.name, "pages": 2, "chains": 1}]
    assert tier.stats()["evicted_chains"] == 1
    # the eviction shows up in the tier telemetry like any other tier op
    assert pool.telemetry.snapshot()["tiers"]["ops"]["evict"]["pages"] == 2
    view.fabric.check_invariants()


def test_prefix_store_capacity_skips_are_surfaced(cfg):
    """Unpinned shared chains rejected at the cap emit ``export_skip`` and
    are counted — in the tier and in the observatory — not dropped
    silently (pinned chains always outrank them)."""
    from repro.obs.observatory import Observatory

    pool, view, tier = _rig(cfg, capacity_pages=4)
    obs = Observatory(pool, tracer=False, drift=False)
    held = []
    for i in range(3):                     # three shared (ref-2) chains
        toks = [2000 * (i + 1) + t for t in range(8)]
        _chain(view, pool, toks, 20 + i)
        got = []
        view.probe_prefix(toks, got)       # second reader: ref -> 2
        held.append(got)
    events = []
    view.fabric.subscribe("export_skip", lambda **kw: events.append(kw))

    manifest = tier.export_prefixes(view)
    assert len(manifest["chains"]) == 2
    assert tier.skipped_chains == 1 and tier.evicted_chains == 0
    assert events == [{"view": view.name, "pages": 2, "chains": 1}]
    assert tier.stats()["skipped_chains"] == 1
    assert obs.metrics.get("repro_tier_export_skips_total").value(
        view.name) == 1
    for got in held:
        view.release(got)
    view.fabric.check_invariants()


# ---------------------------------------------------------------------------
# PR-5 shim retirement (grep-enforced)
# ---------------------------------------------------------------------------

def test_no_internal_shim_imports():
    """Nothing under src/repro imports through the serve/kvcache or
    serve/pagetable compat shims — internal code goes to the placement
    package (or the fabric); the shims exist for external callers only."""
    pat = re.compile(r"from repro\.serve\.(kvcache|pagetable) import"
                     r"|import repro\.serve\.(kvcache|pagetable)\b")
    hits = [f"{f}: {m.group(0)}" for f in sorted(SRC.rglob("*.py"))
            if (m := pat.search(f.read_text()))]
    assert not hits, f"internal shim import survives: {hits}"
    # ...while the external paths keep working
    from repro.serve.kvcache import BwapPagePool as compat_pool
    from repro.serve.pagetable import PageTable as compat_table  # noqa: F401
    assert compat_pool is BwapPagePool


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["out", "demote", "in", "free"]),
                          st.integers(min_value=0, max_value=2)),
                max_size=14))
def test_property_demote_interleavings(ops):
    """Random park → demote → promote → free interleavings against a
    never-demoted oracle fabric: invariants hold after every op, surviving
    K/V is bit-identical, and the ledgers drain to zero."""
    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    pool, view, tier = _rig(cfg)
    opool = _pool(cfg)
    oview = as_view(opool)
    swap = KVSwapManager(pool, reserve_fraction=0.5)
    oswap = KVSwapManager(opool, reserve_fraction=0.5)

    seqs, oseqs, state = [], [], []
    for s in range(3):
        pages, opages = [], []
        for i in range(2):
            view.append_page(pages)
            oview.append_page(opages)
            _fill(pool, pages[-1], 10 * s + i)
            _fill(opool, opages[-1], 10 * s + i)
        seqs.append(pages)
        oseqs.append(opages)
        state.append("live")

    for act, s in ops:
        if act == "out" and state[s] == "live":
            seqs[s], _ = swap.swap_out(seqs[s])
            oseqs[s], _ = oswap.swap_out(oseqs[s])
            state[s] = "parked"
        elif act == "demote":
            swap.demote_cold(2)            # oracle never demotes
        elif act == "in" and state[s] == "parked":
            seqs[s], _ = swap.swap_in(seqs[s])
            oseqs[s], _ = oswap.swap_in(oseqs[s])
            state[s] = "live"
        elif act == "free" and state[s] != "freed":
            if state[s] == "parked":
                swap.release_parked(seqs[s])
                oswap.release_parked(oseqs[s])
            else:
                view.release(seqs[s])
                oview.release(oseqs[s])
            state[s] = "freed"
        view.fabric.check_invariants()
        oview.fabric.check_invariants()

    for s in range(3):                     # drain: promote + compare + free
        if state[s] == "parked":
            seqs[s], _ = swap.swap_in(seqs[s])
            oseqs[s], _ = oswap.swap_in(oseqs[s])
            state[s] = "live"
        if state[s] == "live":
            assert np.array_equal(np.asarray(pool.k_pool[:, seqs[s]]),
                                  np.asarray(opool.k_pool[:, oseqs[s]]))
            assert np.array_equal(np.asarray(pool.v_pool[:, seqs[s]]),
                                  np.asarray(opool.v_pool[:, oseqs[s]]))
            view.release(seqs[s])
            oview.release(oseqs[s])
    swap.close()
    oswap.close()
    assert tier.used_pages() == 0
    assert int(view.used.sum()) == 0 and int(oview.used.sum()) == 0
    view.fabric.check_invariants()
    oview.fabric.check_invariants()


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=3),
                min_size=1, max_size=3))
def test_property_restart_roundtrip(lens):
    """Random chain shapes survive export → fabric teardown → import with
    bit-identical bytes and a consistent importing fabric."""
    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    pool, view, tier = _rig(cfg)
    chains = []
    for i, npages in enumerate(lens):
        toks = [10_000 * (i + 1) + t for t in range(4 * npages)]
        pages = _chain(view, pool, toks, 100 * (i + 1))
        chains.append((toks, np.asarray(pool.k_pool[:, pages]).copy()))
        tier.pin(view, toks)
        view.release(pages)                # only the pin keeps it alive
    tier.export_prefixes(view)
    tier.release_pins()
    view.fabric.check_invariants()

    pool2 = _pool(cfg)
    view2 = as_view(pool2)
    tier.fabric = None
    view2.fabric.attach_persist(tier)
    restored, _ = tier.import_prefixes(view2)
    assert restored == sum(lens)
    view2.fabric.check_invariants()
    for toks, orig in chains:
        got = []
        assert view2.probe_prefix(toks, got) == len(toks)
        assert np.array_equal(np.asarray(pool2.k_pool[:, got]), orig)
        view2.release(got)
    view2.fabric.check_invariants()


# ---------------------------------------------------------------------------
# wire-format round-trip over every page geometry (cluster satellite)
# ---------------------------------------------------------------------------

GEOMETRY_KINDS = ("paged_kv", "mla_latent", "ssm_state", "encoder_kv")


def _geom_pool(kind, page_size=4):
    from repro.placement.geometry import encoder_kv_geometry
    name = {"paged_kv": "qwen2-0.5b", "mla_latent": "deepseek-v3-671b",
            "ssm_state": "xlstm-125m", "encoder_kv": "whisper-tiny"}[kind]
    gcfg = registry.get_smoke_config(name)
    if kind == "paged_kv":
        gcfg = dataclasses.replace(gcfg, num_layers=1,
                                   compute_dtype="float32")
    geometry = encoder_kv_geometry(gcfg, page_size) \
        if kind == "encoder_kv" else None
    pool = BwapPagePool(gcfg, [
        MemoryDomain("hbm_local", 12, 819.0, True),
        MemoryDomain("host", 12, 0.016, False),
    ], page_size=page_size, geometry=geometry,
        dwp_config=DWPConfig(n=10 ** 6, c=1))
    assert pool.geometry.kind == kind
    return pool


def _rand_fill(pool, pages, seed):
    rng = np.random.default_rng(seed)
    dt = np.asarray(pool.k_pool).dtype
    k = rng.standard_normal(pool.k_pool[:, pages].shape).astype(dt)
    v = rng.standard_normal(pool.v_pool[:, pages].shape).astype(dt)
    pool.k_pool = pool.k_pool.at[:, pages].set(k)
    pool.v_pool = pool.v_pool.at[:, pages].set(v)
    return k, v


def _wire_roundtrip(kind, npages, seed):
    """serialize → deserialize → import on a same-geometry peer is
    bit-exact for any geometry, any page count, any bytes."""
    pool = _geom_pool(kind)
    view = as_view(pool)
    tier = PersistentTier()
    view.fabric.attach_persist(tier)
    pages = []
    for _ in range(npages):
        view.append_page(pages)
    k, v = _rand_fill(pool, pages, seed)
    toks = None
    if pool.geometry.shareable and npages * pool.page_size >= 1:
        toks = [seed % 97 + t for t in range(npages * pool.page_size)]
        view.register_prefix(toks, pages, len(toks))
    blob = deserialize_range(serialize_range(tier.export_range(
        view, pages, tokens=toks,
        ntokens=npages * pool.page_size)))
    assert blob["geometry"]["kind"] == kind

    pool2 = _geom_pool(kind)
    view2 = as_view(pool2)
    tier2 = PersistentTier()
    view2.fabric.attach_persist(tier2)
    new_ids, _ = tier2.import_range(view2, blob)
    assert np.array_equal(np.asarray(pool2.k_pool[:, new_ids]), k)
    assert np.array_equal(np.asarray(pool2.v_pool[:, new_ids]), v)
    view.fabric.check_invariants()
    view2.fabric.check_invariants()
    view2.release(new_ids)


def _convert_roundtrip(ps_src, ps_dst, ntokens, seed):
    """A paged_kv range re-chunks across page sizes through the channel,
    bit-exact per valid token, with balanced ledgers on both fabrics."""
    from repro.cluster import Interconnect, Link, PageChannel

    pool_a = _geom_pool("paged_kv", page_size=ps_src)
    view_a = as_view(pool_a)
    view_a.fabric.attach_persist(PersistentTier())
    pool_b = _geom_pool("paged_kv", page_size=ps_dst)
    view_b = as_view(pool_b)
    view_b.fabric.attach_persist(PersistentTier())
    npages = -(-ntokens // ps_src)
    pages = []
    for _ in range(npages):
        view_a.append_page(pages)
    k, v = _rand_fill(pool_a, pages, seed)
    toks = [seed % 89 + t for t in range(ntokens)]

    ch = PageChannel(Interconnect([Link("wire", 0.1)]), chunk_bytes=1 << 14)
    ch.send(view_a, pages, now=0.0, tokens=toks, ntokens=ntokens)
    new_ids, _, _ = ch.recv(view_b)
    assert len(new_ids) == -(-ntokens // ps_dst)
    assert ch.converted_imports == (1 if ps_src != ps_dst else 0)

    def tokens_of(arr, npg, ps):
        a = np.asarray(arr)
        return a.reshape(a.shape[0], npg * ps, *a.shape[3:])[:, :ntokens]

    assert np.array_equal(tokens_of(pool_b.k_pool[:, new_ids],
                                    len(new_ids), ps_dst),
                          tokens_of(k, npages, ps_src))
    assert np.array_equal(tokens_of(pool_b.v_pool[:, new_ids],
                                    len(new_ids), ps_dst),
                          tokens_of(v, npages, ps_src))
    view_a.fabric.check_invariants()
    view_b.fabric.check_invariants()
    view_b.release(new_ids)


@pytest.mark.parametrize("kind", GEOMETRY_KINDS)
def test_wire_roundtrip_each_geometry(kind):
    _wire_roundtrip(kind, npages=2, seed=7)


@pytest.mark.parametrize("ps_src,ps_dst,ntokens",
                         [(4, 8, 14), (8, 4, 9), (2, 8, 7), (4, 4, 12)])
def test_convert_on_import_each_direction(ps_src, ps_dst, ntokens):
    _convert_roundtrip(ps_src, ps_dst, ntokens, seed=11)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(GEOMETRY_KINDS), st.integers(1, 3),
       st.integers(0, 2 ** 31 - 1))
def test_property_wire_roundtrip_all_geometries(kind, npages, seed):
    _wire_roundtrip(kind, npages, seed)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([2, 4, 8]), st.sampled_from([2, 4, 8]),
       st.integers(1, 24), st.integers(0, 2 ** 31 - 1))
def test_property_convert_on_import(ps_src, ps_dst, ntokens, seed):
    _convert_roundtrip(ps_src, ps_dst, ntokens, seed)
