"""Scheduler subsystem: priority continuous batching, chunked prefill,
bandwidth-aware KV swap (preemption round-trips must be bit-exact)."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:      # bare env: property tests skip individually
    from _hypothesis_stub import given, settings, st

from repro.configs import registry
from repro.core.dwp import DWPConfig
from repro.placement.arbiter import DomainArbiter, DomainSpec, Priority
from repro.scheduler import (KVSwapManager, PriorityClass, RequestScheduler,
                             SloSpec, SloTracker, State, WorkloadSpec,
                             generate, total_kv_pages)
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BwapPagePool, MemoryDomain


@pytest.fixture(scope="module")
def small_lm():
    cfg = registry.get_smoke_config("qwen2-0.5b")
    cfg = dataclasses.replace(cfg, num_layers=2, compute_dtype="float32")
    from repro.models.lm import LM
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _pool(cfg, fast=8, peer=8, host=60, page_size=4, n=100):
    """Small fast domain, large slow domains (slow bw in the engine-latency
    range so Eq.-1 terms are visible); tuner effectively frozen (n large)."""
    domains = [
        MemoryDomain("hbm_local", fast, 819.0, True),
        MemoryDomain("hbm_peer", peer, 0.05, False),
        MemoryDomain("host", host, 0.016, False),
    ]
    return BwapPagePool(cfg, domains, page_size=page_size,
                        dwp_config=DWPConfig(n=n, c=1))


def _drain(eng, max_steps=500):
    steps = 0
    while (eng.active or eng.waiting) and steps < max_steps:
        eng.step()
        steps += 1
    return steps


# ---------------------------------------------------------------------------
# workload generation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["poisson", "bursty", "heavy_tail"])
def test_workload_deterministic_and_bounded(kind):
    spec = WorkloadSpec(kind=kind, num_requests=40, prompt_mean=10,
                        prompt_max=32, vocab_size=500, seed=3,
                        class_mix=(("a", 0.5), ("b", 0.5)))
    t1, t2 = generate(spec), generate(spec)
    assert t1 == t2                                   # seeded determinism
    t3 = generate(dataclasses.replace(spec, seed=4))
    assert t1 != t3
    arr = [r.arrival_s for r in t1]
    assert arr == sorted(arr) and arr[0] >= 0
    for r in t1:
        assert 1 <= len(r.prompt) <= 32
        assert all(1 <= t < 500 for t in r.prompt)
        assert r.cls in ("a", "b")
    assert total_kv_pages(t1, 4) == sum(
        -(-(len(r.prompt) + r.max_new) // 4) for r in t1)


def test_workload_kind_shapes():
    n, mean = 200, 0.1
    bursty = generate(WorkloadSpec(kind="bursty", num_requests=n,
                                   mean_interarrival_s=mean, seed=0,
                                   burst_len=4, burst_factor=8.0))
    gaps = np.diff([0.0] + [r.arrival_s for r in bursty])
    # within-burst gaps are ~mean/8; burst starts are ~8x longer
    assert np.percentile(gaps, 50) < mean
    assert gaps.max() > 2 * mean
    heavy = generate(WorkloadSpec(kind="heavy_tail", num_requests=n,
                                  prompt_mean=8, prompt_max=64, seed=0))
    lens = np.asarray([len(r.prompt) for r in heavy])
    assert lens.max() > 4 * np.percentile(lens, 50)   # a heavy tail exists


def test_workload_shared_prefixes():
    spec = WorkloadSpec(kind="heavy_tail", num_requests=40, prompt_mean=6,
                        prompt_max=24, vocab_size=500, seed=2,
                        prefix_len=12, prefix_groups=2, prefix_frac=0.8)
    t1, t2 = generate(spec), generate(spec)
    assert t1 == t2                                   # still deterministic
    heads = {}
    for r in t1:
        heads.setdefault(r.prompt[:12], 0)
        heads[r.prompt[:12]] += 1
    # at most 2 shared heads dominate; the rest are unique leading tokens
    shared = sorted(heads.values(), reverse=True)[:2]
    assert sum(shared) >= 0.5 * len(t1)
    assert len([h for h, n in heads.items() if n > 1]) <= 2
    # prefix_len=0 keeps the original generator byte-for-byte
    base = WorkloadSpec(kind="heavy_tail", num_requests=8, seed=3)
    assert generate(base) == generate(dataclasses.replace(
        base, prefix_groups=4, prefix_frac=0.5))


# ---------------------------------------------------------------------------
# SLO accounting
# ---------------------------------------------------------------------------

def test_slo_tracker_deadlines_and_goodput():
    tr = SloTracker({"fast": SloSpec(ttft_s=1.0, tpot_s=0.5),
                     "free": SloSpec()})
    tr.on_submit(0, "fast", arrival_s=0.0)
    tr.on_first_token(0, now=0.5)                 # ttft 0.5 <= 1.0
    tr.on_finish(0, now=2.0, produced=4)          # tpot 0.5 <= 0.5
    tr.on_submit(1, "fast", arrival_s=0.0)
    tr.on_first_token(1, now=3.0)                 # ttft miss
    tr.on_finish(1, now=4.0, produced=2)
    tr.on_submit(2, "free", arrival_s=0.0)
    tr.on_first_token(2, now=9.0)                 # inf deadlines: always good
    tr.on_finish(2, now=10.0, produced=3)
    s = tr.summary(now=10.0)
    fast = s["classes"]["fast"]
    assert fast["completed"] == 2 and fast["good"] == 1
    assert math.isclose(fast["ttft_mean_s"], (0.5 + 3.0) / 2)
    assert s["classes"]["free"]["good"] == 1
    assert s["good_tokens"] == 4 + 3
    assert math.isclose(s["goodput_tok_s"], 7 / 10.0)
    assert tr.counters.get("fast", "ttft_missed") == 1
    assert tr.counters.get("fast", "goodput_tokens") == 4


# ---------------------------------------------------------------------------
# swap manager: reservation, placement, exact round-trips
# ---------------------------------------------------------------------------

def test_swap_roundtrip_preserves_exact_kv(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg, fast=8, peer=8, host=16)
    swap = KVSwapManager(pool, reserve_fraction=0.5)
    reserved = swap.reserved_total
    assert reserved > 0
    assert pool.free_count() + reserved == pool.total_pages
    pages = [pool.alloc_page() for _ in range(5)]
    rng = np.random.default_rng(0)
    for p in pages:      # distinct recognizable content per page
        pool.k_pool = pool.k_pool.at[:, p].set(
            jnp.asarray(rng.normal(size=pool.k_pool.shape[2:]), jnp.float32))
        pool.v_pool = pool.v_pool.at[:, p].set(
            jnp.asarray(rng.normal(size=pool.v_pool.shape[2:]), jnp.float32))
    k_ref = np.asarray(pool.k_pool)[:, pages].copy()
    v_ref = np.asarray(pool.v_pool)[:, pages].copy()
    free_before = pool.free_count()

    parked, secs_out = swap.swap_out(list(pages))
    assert secs_out > 0
    assert pool.free_count() == free_before + len(pages)  # sources freed
    for p in parked:
        assert pool.domain_of(p) in pool.slow_domains
    np.testing.assert_array_equal(np.asarray(pool.k_pool)[:, parked], k_ref)
    np.testing.assert_array_equal(np.asarray(pool.v_pool)[:, parked], v_ref)

    back, secs_in = swap.swap_in(parked)
    assert secs_in > 0
    assert swap.slots_free() == reserved               # slots all returned
    np.testing.assert_array_equal(np.asarray(pool.k_pool)[:, back], k_ref)
    np.testing.assert_array_equal(np.asarray(pool.v_pool)[:, back], v_ref)
    tel = pool.telemetry.snapshot()
    assert tel["swap_outs"] == 5 and tel["swap_ins"] == 5


def test_swap_placement_follows_policy(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg, fast=4, peer=24, host=24)
    # bwap: spread over slow domains proportional to bandwidth
    bwap = KVSwapManager(pool, placement="bwap_canonical",
                         reserve_fraction=0.9)
    counts = bwap._slot_counts(20)
    assert counts.sum() == 20
    # peer (0.05 GB/s) gets ~3x host's share (0.016 GB/s)
    assert counts[0] > 2 * counts[1]
    # local_first: everything into the fastest slow domain while it fits
    pool2 = _pool(cfg, fast=4, peer=24, host=24)
    lf = KVSwapManager(pool2, placement="local_first", reserve_fraction=0.9)
    counts2 = lf._slot_counts(10)
    assert counts2[0] == 10 and counts2[1] == 0


@given(st.lists(st.integers(min_value=1, max_value=6), min_size=1,
                max_size=5),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_swap_random_interleavings_preserve_kv(footprints, seed):
    """Random sequences of swap-out/swap-in (random preemption points at the
    page level) never corrupt or cross-wire K/V contents."""
    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    pool = _pool(cfg, fast=8, peer=10, host=24)
    swap = KVSwapManager(pool, reserve_fraction=0.8)
    rng = np.random.default_rng(seed)
    seqs = []
    for i, n in enumerate(footprints):
        pages = [pool.alloc_page() for _ in range(n)]
        fill = float(i + 1)
        pool.k_pool = pool.k_pool.at[:, pages].set(fill)
        pool.v_pool = pool.v_pool.at[:, pages].set(-fill)
        seqs.append({"pages": pages, "fill": fill, "parked": False})
    for _ in range(12):                      # random preemption points
        s = seqs[int(rng.integers(len(seqs)))]
        if s["parked"]:
            s["pages"], _ = swap.swap_in(s["pages"])
        elif swap.can_swap_out(len(s["pages"])):
            s["pages"], _ = swap.swap_out(s["pages"])
        s["parked"] = not s["parked"]
    for s in seqs:
        got_k = np.asarray(pool.k_pool)[:, s["pages"]]
        got_v = np.asarray(pool.v_pool)[:, s["pages"]]
        assert (got_k == s["fill"]).all() and (got_v == -s["fill"]).all()


def test_unreserve_returns_slots_to_allocator(small_lm):
    """Dropping part of the reservation through the fabric view hands the
    slots back to the allocator and keeps every ledger consistent (the
    incremental reserve/unreserve API that replaced the old bulk
    set_reserved_counts resync)."""
    cfg, _ = small_lm
    pool = _pool(cfg, fast=8, peer=8, host=8)
    swap = KVSwapManager(pool, reserve_fraction=1.0)
    assert swap.reserved_total == 16
    view = swap.view
    free_before = view.free_count()
    give_back = [swap.slots[1].pop() for _ in range(4)]   # peer slots
    for pid in give_back:
        view.unreserve(pid)
    swap.reserved_total -= 4
    assert view.free_count() == free_before + 4
    assert int(pool.reserved.sum()) == 12
    assert swap.slots_free() == 12
    assert swap.can_swap_out(12) and not swap.can_swap_out(13)
    assert swap._slot_counts(12).sum() == 12      # placeable = claimed


# ---------------------------------------------------------------------------
# scheduler: chunked prefill, priority, capacity preemption
# ---------------------------------------------------------------------------

def test_chunked_prefill_respects_token_budget(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg, fast=32, peer=8, host=8)
    # the second prompt is a prefix of the first: disable trie matching so
    # the budget accounting below counts every prompt token (sharing has
    # its own tests in test_pagetable.py)
    pool.table.prefix_reuse = False
    sched = RequestScheduler(pool, max_batch=4, prefill_token_budget=5,
                             default_max_new=4)
    sched.submit(list(range(1, 18)))          # prompt 17 -> target 16 tokens
    sched.submit(list(range(1, 8)))           # prompt 7  -> target 6 tokens
    seen = []
    for _ in range(10):
        plan = sched.schedule()
        total = sum(hi - lo for _, lo, hi in plan.prefill_chunks)
        assert total <= 5
        seen.append(total)
        for r, lo, hi in plan.prefill_chunks:   # stand in for the engine
            r.length = hi
        if not sched.queued and not sched.prefilling:
            break
    assert sum(seen) == 16 + 6                # every prompt token admitted
    assert len(sched.running) == 2


def test_priority_class_preempts_lower(small_lm):
    cfg, params = small_lm
    pool = _pool(cfg, fast=8, peer=8, host=60)
    swap = KVSwapManager(pool, reserve_fraction=0.9)
    sched = RequestScheduler(
        pool, max_batch=4, prefill_token_budget=64,
        classes=[PriorityClass("hi", 5, SloSpec(1.0, 1.0)),
                 PriorityClass("lo", 0)],
        default_class="lo", default_max_new=6, swap=swap)
    eng = ServeEngine(cfg, params, pool, scheduler=sched, wall_clock=False,
                      sim_step_s=0.01)
    rng = np.random.default_rng(0)
    for _ in range(4):                         # fill every batch slot
        eng.submit(rng.integers(1, cfg.vocab_size, 10).tolist(), cls="lo")
    eng.step()
    assert len(sched.running) == 4
    eng.submit(rng.integers(1, cfg.vocab_size, 10).tolist(), cls="hi")
    eng.step()                                 # must evict a "lo" victim
    assert any(r.cls == "hi" for r in sched.running)
    assert len(sched.swapped) >= 1
    assert all(r.cls == "lo" for r in sched.swapped)
    _drain(eng)
    assert len(eng.finished) == 5
    slo = pool.telemetry.snapshot()["slo"]
    assert slo["lo"]["preemptions"] >= 1
    assert slo["hi"]["preemptions"] == 0
    assert slo["hi"]["swap_out_pages"] == 0


def test_oversubscribed_completes_with_zero_failures(small_lm):
    """Total KV footprint >> hbm_local (and > unreserved pool): everything
    still completes, via parking cold sequences in reserved slow slots."""
    cfg, params = small_lm
    pool = _pool(cfg, fast=10, peer=10, host=50)
    swap = KVSwapManager(pool, reserve_fraction=0.9)
    sched = RequestScheduler(pool, max_batch=6, prefill_token_budget=24,
                             default_max_new=8, swap=swap)
    eng = ServeEngine(cfg, params, pool, scheduler=sched, wall_clock=False,
                      sim_step_s=0.01)
    trace = generate(WorkloadSpec(
        kind="bursty", num_requests=12, mean_interarrival_s=0.005,
        prompt_mean=12, prompt_max=20, max_new=8,
        vocab_size=cfg.vocab_size, seed=1))
    assert total_kv_pages(trace, pool.page_size) > 10   # oversubscribed
    for t in trace:
        eng.submit(t.prompt, max_new=t.max_new, arrival_s=t.arrival_s)
    _drain(eng)
    assert len(eng.finished) == len(trace)              # zero failures
    assert all(s.produced == s.max_new for s in eng.finished)
    assert pool.telemetry.swap_outs > 0                 # swap did the work
    assert pool.telemetry.swap_outs == pool.telemetry.swap_ins
    # every page accounted for: free pool + untouched reservation
    assert pool.free_count() + swap.reserved_total == pool.total_pages
    assert swap.slots_free() == swap.reserved_total


def test_infeasible_request_rejected_at_submit(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg, fast=2, peer=1, host=1)
    sched = RequestScheduler(pool, max_batch=2, default_max_new=4)
    with pytest.raises(ValueError, match="allocatable"):
        sched.submit(list(range(1, 40)))      # footprint > whole pool
    # a swap reservation shrinks what one sequence may hold
    pool2 = _pool(cfg, fast=4, peer=8, host=8)
    swap = KVSwapManager(pool2, reserve_fraction=1.0)   # all slow reserved
    sched2 = RequestScheduler(pool2, max_batch=2, default_max_new=4,
                              swap=swap)
    assert sched2.allocatable_pages() == 4
    with pytest.raises(ValueError, match="allocatable"):
        sched2.submit(list(range(1, 20)))


def test_joint_exhaustion_raises_not_spins(small_lm):
    """Individually feasible requests that jointly exceed the pool must
    fail loudly once no step can make progress (no swap to fall back on)."""
    cfg, _ = small_lm
    pool = _pool(cfg, fast=4, peer=2, host=2)     # 8 pages, 2 seqs x 3+
    sched = RequestScheduler(pool, max_batch=2, prefill_token_budget=6,
                             default_max_new=20)
    sched.submit(list(range(1, 14)))              # 8 pages each at full
    sched.submit(list(range(1, 14)))              # length: jointly 16 > 8
    with pytest.raises(RuntimeError, match="exhausted|grow"):
        for _ in range(60):                       # simulate engine decode
            plan = sched.schedule()
            for r in plan.batch:
                if r.length % pool.page_size == 0:
                    r.pages.append(pool.alloc_page())
                r.tokens.append(1)
                r.length += 1


def test_stall_preemption_evicts_read_time_hog(small_lm):
    """A sequence whose pages sit in a glacial domain dominates the batch's
    Eq.-1 read time: the stall trigger must evict exactly it (and only when
    the trigger is enabled)."""
    cfg, _ = small_lm

    def setup(frac):
        pool = _pool(cfg, fast=16, peer=12, host=12)
        swap = KVSwapManager(pool, reserve_fraction=0.8)
        sched = RequestScheduler(pool, max_batch=4, prefill_token_budget=64,
                                 default_max_new=8, swap=swap,
                                 stall_preempt_fraction=frac,
                                 stall_preempt_cooldown_s=10.0)
        sched.submit([1, 2, 3, 4, 5])
        sched.submit([6, 7, 8, 9, 10])
        plan = sched.schedule()                  # both prefill + run
        for r, lo, hi in plan.prefill_chunks:
            r.length = hi
        hog, other = sched.running
        # drag the hog's pages into the slowest domain by hand (domain 2),
        # carrying the page-table refs along like a real mover would
        new = [pool.free[2].pop() for _ in hog.pages]
        for old, n in zip(hog.pages, new):
            pool.free[pool.domain_of(old)].append(old)
            pool.table.remap_physical(old, n)
        hog.pages[:] = new
        return pool, sched, hog, other

    pool, sched, hog, other = setup(0.5)
    sched.schedule()
    assert hog in sched.swapped                  # evicted: it gated reads
    assert other in sched.running
    assert hog.resume_after > sched.now          # cooldown armed
    sched.schedule()
    assert hog in sched.swapped                  # cooldown blocks thrash

    pool2, sched2, hog2, _ = setup(None)         # trigger disabled
    sched2.schedule()
    assert hog2 in sched2.running


def test_swap_aware_dwp_respects_reservation(small_lm):
    """Reserved swap slots must leave the capacities the DWP tuner sees:
    with every slow page reserved, the allocation cycle may only promise
    worker-domain pages (roadmap: swap-aware DWP)."""
    cfg, _ = small_lm
    pool = _pool(cfg, fast=8, peer=8, host=8, n=4)
    assert pool.tuner.capacity_fractions is None     # no reservation yet
    swap = KVSwapManager(pool, reserve_fraction=1.0)
    assert swap.reserved_total == 16
    np.testing.assert_array_equal(pool.reserved, [0, 8, 8])
    # effective capacities reach placement decisions (policy context)...
    np.testing.assert_array_equal(pool._ctx(0.0).capacities, [8, 0, 0])
    # ...and the tuner's cycle stops promising reserved-away pages
    assert set(int(d) for d in pool.tuner.assignment) == {0}
    # partial reservation: only the reserved domain's share is capped (at
    # its unreserved fraction of the allocatable pool); others stay free
    pool2 = _pool(cfg, fast=8, peer=8, host=8, n=4)
    KVSwapManager(pool2, reserve_pages={"hbm_peer": 6})
    np.testing.assert_array_equal(pool2.reserved, [0, 6, 0])
    np.testing.assert_array_equal(pool2._ctx(0.0).capacities, [8, 2, 8])
    frac = pool2.tuner.capacity_fractions
    assert frac is not None and frac[1] == pytest.approx(2 / 18)
    assert np.isinf(frac[0]) and np.isinf(frac[2])


# ---------------------------------------------------------------------------
# preemption round-trip: decode must be bit-exact
# ---------------------------------------------------------------------------

def test_preempted_decode_matches_unpressured_reference(small_lm):
    """Chunked prefill + swap-out/swap-in round-trips must not change a
    single token vs a run with no memory pressure."""
    cfg, params = small_lm
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, int(n)).tolist()
               for n in (9, 14, 5, 11, 7, 13)]

    def run(pressured):
        if pressured:
            pool = _pool(cfg, fast=8, peer=8, host=60)
            swap = KVSwapManager(pool, reserve_fraction=0.85)
            sched = RequestScheduler(pool, max_batch=6,
                                     prefill_token_budget=7,
                                     default_max_new=8, swap=swap)
        else:
            pool = _pool(cfg, fast=64, peer=16, host=16)
            swap = None
            sched = RequestScheduler(pool, max_batch=6,
                                     prefill_token_budget=256,
                                     default_max_new=8)
        eng = ServeEngine(cfg, params, pool, scheduler=sched,
                          wall_clock=False)
        for p in prompts:
            eng.submit(list(p))
        _drain(eng)
        assert len(eng.finished) == len(prompts)
        return ({s.sid: s.tokens for s in eng.finished},
                pool.telemetry.swap_outs)

    ref, _ = run(False)
    got, swaps = run(True)
    assert swaps > 0                           # pressure actually preempted
    assert got == ref


@pytest.mark.parametrize("preempt_step", [0, 2, 5])
def test_forced_preemption_at_point_is_exact(small_lm, preempt_step):
    """Force a swap-out at a specific decode step, resume, and compare the
    full generation against the dense-path reference engine."""
    cfg, params = small_lm
    prompt = [3, 17, 29, 5, 41, 11]
    max_new = 8

    def reference():
        pool = _pool(cfg, fast=64, peer=8, host=8)
        eng = ServeEngine(cfg, params, pool, max_batch=1, max_new=max_new)
        eng.submit(list(prompt))
        _drain(eng)
        return eng.finished[0].tokens

    pool = _pool(cfg, fast=16, peer=8, host=40)
    swap = KVSwapManager(pool, reserve_fraction=0.8)
    sched = RequestScheduler(pool, max_batch=1, prefill_token_budget=64,
                             default_max_new=max_new, swap=swap)
    eng = ServeEngine(cfg, params, pool, scheduler=sched, wall_clock=False)
    eng.submit(list(prompt))
    for _ in range(preempt_step + 1):
        eng.step()
    victim = sched.running[0]
    sched._swap_out(victim)                    # forced preemption point
    assert victim.state is State.SWAPPED
    _drain(eng)
    assert len(eng.finished) == 1
    assert eng.finished[0].tokens == reference()


# ---------------------------------------------------------------------------
# arbiter integration: tenants as priority classes
# ---------------------------------------------------------------------------

def test_fabric_views_register_tenants_as_priority_classes(small_lm):
    """Schedulers built on named fabric views pick up the tenant's class
    level and default class from the view itself — the wiring the old
    arbiter.attach_engine back-channel used to reach in and do."""
    cfg, params = small_lm
    arb = DomainArbiter([DomainSpec("hbm_local", 48, 819.0),
                         DomainSpec("hbm_peer", 32, 0.05),
                         DomainSpec("host", 64, 0.016)], page_size=4)
    ta = arb.register("prod", cfg, priority=Priority.HIGH, share=0.5)
    tb = arb.register("bulk", cfg, priority=Priority.BEST_EFFORT, share=0.5)
    sched_a = RequestScheduler(
        ta.view, max_batch=2, default_max_new=4,
        classes=[PriorityClass("prod", 0, SloSpec(ttft_s=0.5, tpot_s=0.1))])
    eng_a = ServeEngine(cfg, params, ta.view, scheduler=sched_a)
    eng_b = ServeEngine(cfg, params, tb.view, max_batch=2, max_new=4)
    assert eng_a.scheduler.classes["prod"].level \
        > eng_b.scheduler.classes["bulk"].level
    assert eng_a.scheduler.default_class == "prod"
    # operator-configured deadlines survive the arbiter's level override
    assert eng_a.scheduler.classes["prod"].slo.ttft_s == 0.5
    assert eng_a.scheduler.slo.specs["prod"].tpot_s == 0.1
    # submits land in the tenant's class and serve normally
    eng_a.submit([5, 9, 2])
    eng_b.submit([7, 1, 8])
    _drain(eng_a)
    _drain(eng_b)
    assert eng_a.finished[0].cls == "prod"
    assert eng_b.finished[0].cls == "bulk"
    # one shared fabric telemetry carries both tenants' SLO rows
    snap = ta.view.snapshot()
    assert sorted(snap.get("slo", {})) == ["bulk", "prod"]
    arb.fabric.check_invariants()
