"""Fabric observatory (DESIGN.md §10): metrics registry, span tracer,
Eq.-1 drift ledger, page heat, event-payload contracts, emit hardening,
and the benchmark-artifact schema check."""

import ast
import dataclasses
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core.dwp import DWPConfig
from repro.obs import DEFAULT_BUCKETS, MetricsRegistry, Observatory
from repro.obs.drift import DriftLedger
from repro.obs.heat import PageHeat
from repro.placement.fabric import (EVENT_FIELDS, EVENTS, SHARE_KIND_FIELDS,
                                    MemoryFabric)
from repro.placement.telemetry import ClassSloCounters, DomainTelemetry, Ring
from repro.scheduler import (KVSwapManager, PriorityClass, RequestScheduler,
                             SloSpec, WorkloadSpec, generate)
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BwapPagePool, MemoryDomain

SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"


# ---------------------------------------------------------------------------
# Ring.quantile
# ---------------------------------------------------------------------------

def test_ring_quantile_matches_numpy():
    r = Ring(capacity=64)
    vals = [3.0, 1.0, 4.0, 1.5, 9.0, 2.6, 5.0]
    for v in vals:
        r.push(v)
    for q in (0.0, 0.5, 0.95, 1.0):
        assert r.quantile(q) == pytest.approx(np.quantile(vals, q))


def test_ring_quantile_empty_and_wrapped():
    r = Ring(capacity=4)
    assert r.quantile(0.5) == 0.0
    for v in range(10):          # wraps: window is the last 4 pushes
        r.push(float(v))
    assert r.quantile(0.5) == pytest.approx(np.quantile([6, 7, 8, 9], 0.5))
    assert r.quantile(1.0) == 9.0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_labels_and_snapshot():
    m = MetricsRegistry()
    c = m.counter("reqs_total", "Requests.", ("view", "cls"))
    c.labels("A", "hi").inc()
    c.labels("A", "hi").inc(2)
    c.labels("B", "lo").inc(5)
    assert c.value("A", "hi") == 3
    assert c.value("B", "lo") == 5
    assert c.value("B", "hi") == 0          # unobserved child reads 0
    assert c.total() == 8
    g = m.gauge("occupancy", "Pages.", ("tier",))
    g.labels("fast").set(7)
    g.labels("fast").set(4)
    assert g.value("fast") == 4
    snap = m.snapshot()
    assert snap["reqs_total"]["type"] == "counter"
    assert {"labels": {"view": "A", "cls": "hi"}, "value": 3.0} \
        in snap["reqs_total"]["series"]
    # idempotent re-registration returns the same family
    assert m.counter("reqs_total", "Requests.", ("view", "cls")) is c
    with pytest.raises(AssertionError):
        m.counter("reqs_total", "Requests.", ("other",))


def test_histogram_buckets_and_quantile():
    m = MetricsRegistry()
    h = m.histogram("lat", "Latency.", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    child = h.labels()
    assert child.count == 5
    assert child.sum == pytest.approx(56.05)
    assert list(child.counts) == [1, 2, 1, 1]     # last = +Inf bucket
    # p50 lands in the (0.1, 1.0] bucket; +Inf clamps to the top edge
    assert 0.1 <= child.quantile(0.5) <= 1.0
    assert child.quantile(1.0) == 10.0
    assert all(b > 0 for b in DEFAULT_BUCKETS)


def test_prometheus_text_format():
    m = MetricsRegistry()
    m.counter("a_total", 'Help with "quotes".', ("dom",)).labels(
        'x"y\\z').inc(2)
    m.histogram("h_seconds", "H.", buckets=(1.0, 2.0)).observe(1.5)
    text = m.prometheus_text()
    assert "# HELP a_total" in text and "# TYPE a_total counter" in text
    assert r'a_total{dom="x\"y\\z"} 2' in text
    assert 'h_seconds_bucket{le="1"} 0' in text
    assert 'h_seconds_bucket{le="2"} 1' in text
    assert 'h_seconds_bucket{le="+Inf"} 1' in text
    assert "h_seconds_sum 1.5" in text and "h_seconds_count 1" in text


# ---------------------------------------------------------------------------
# telemetry migrated onto the registry (snapshot contract unchanged)
# ---------------------------------------------------------------------------

def test_telemetry_mirrors_registry():
    tel = DomainTelemetry(["fast", "slow"])
    tel.record_alloc(0, 3)
    tel.record_free(1, 2)
    tel.record_migration(0, 1, 4, 4096)
    tel.record_swap("out", 5, 0.25)
    tel.record_latency(0.02)
    tel.record_stall(1, 0.004)
    tel.record_tier("demote", 6, 0.5)
    tel.record_tier_occupancy("fast_domains", 10, 20)
    m = tel.metrics
    assert m.get("repro_pages_allocated_total").value("fast") == 3
    assert m.get("repro_pages_freed_total").value("slow") == 2
    assert m.get("repro_migrated_pages_total").value("fast", "out") == 4
    assert m.get("repro_migrated_bytes_total").value("slow", "in") == 4096
    assert m.get("repro_executed_moves_total").total() == 4
    assert m.get("repro_swap_pages_total").value("out") == 5
    assert m.get("repro_swap_seconds_total").total() == pytest.approx(0.25)
    assert m.get("repro_tier_pages_total").value("demote") == 6
    assert m.get("repro_tier_occupancy_pages").value(
        "fast_domains", "used") == 10
    # legacy snapshot shape intact, plus the new quantile fields
    snap = tel.snapshot()
    assert snap["domains"]["fast"]["allocs"] == 3
    assert snap["swap_outs"] == 5 and snap["executed_moves"] == 4
    assert snap["latency_p50_s"] == pytest.approx(0.02)
    assert snap["domains"]["slow"]["stall_p95_s"] == pytest.approx(0.004)
    assert snap["subscriber_errors"] == 0
    text = tel.prometheus_text()
    assert 'repro_pages_allocated_total{domain="fast"} 3' in text


def test_slo_counters_back_the_registry():
    tel = DomainTelemetry(["d0"])
    slo = tel.attach_slo()
    assert isinstance(slo, ClassSloCounters)
    slo.add("interactive", "submitted")
    slo.add("interactive", "goodput_tokens", 12)
    fam = tel.metrics.get("repro_slo_events_total")
    assert fam.value("interactive", "submitted") == 1
    assert fam.value("interactive", "goodput_tokens") == 12
    assert slo.snapshot()["interactive"]["goodput_tokens"] == 12


# ---------------------------------------------------------------------------
# satellite: emit hardening
# ---------------------------------------------------------------------------

def _cfg():
    return dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                               num_layers=1, compute_dtype="float32")


def _fabric():
    return MemoryFabric(_cfg(), [
        MemoryDomain("fast", 8, 819.0, True),
        MemoryDomain("slow", 16, 0.016, False),
    ], page_size=4, policy="bwap_dwp")


def test_emit_isolates_raising_subscriber():
    fab = _fabric()
    view = fab.view("A", quota=[8, 16], home=(0,))
    seen = []

    def boom(**kw):
        raise RuntimeError("broken observer")

    fab.subscribe("alloc", boom)
    fab.subscribe("alloc", lambda **kw: seen.append(kw))
    pages = []
    view.append_page(pages)          # must not raise through the hot path
    assert len(seen) == 1            # later subscribers still ran
    assert fab.telemetry.subscriber_errors == 1
    assert fab.telemetry.metrics.get(
        "repro_subscriber_errors_total").value("alloc") == 1
    view.release(pages)
    fab.check_invariants()


# ---------------------------------------------------------------------------
# satellite: event payload contracts
# ---------------------------------------------------------------------------

def _emit_calls(path: pathlib.Path):
    """Every ``*.emit("<event>", ...)`` call site in one source file."""
    tree = ast.parse(path.read_text())
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "emit"
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            continue
        yield path.name, node


def test_every_emit_call_site_carries_the_contract_fields():
    files = [SRC / "placement" / "fabric.py",
             SRC / "placement" / "persist.py",
             SRC / "cluster" / "transport.py"]
    sites = [c for f in files for c in _emit_calls(f)]
    assert len(sites) >= 10, "emit call sites went missing"
    seen_events = set()
    for fname, call in sites:
        event = call.args[0].value
        assert event in EVENT_FIELDS, \
            f"{fname}:{call.lineno}: undocumented event {event!r}"
        seen_events.add(event)
        kws = {k.arg for k in call.keywords if k.arg is not None}
        missing = set(EVENT_FIELDS[event]) - kws
        assert not missing, (f"{fname}:{call.lineno}: emit({event!r}) "
                             f"missing contract fields {sorted(missing)}")
        if event == "share":
            kind = next(k.value.value for k in call.keywords
                        if k.arg == "kind")
            assert kind in SHARE_KIND_FIELDS, \
                f"{fname}:{call.lineno}: undocumented share kind {kind!r}"
            missing = set(SHARE_KIND_FIELDS[kind]) - kws
            assert not missing, \
                (f"{fname}:{call.lineno}: share kind={kind!r} missing "
                 f"{sorted(missing)}")
    # the contract documents exactly the bus vocabulary
    assert set(EVENT_FIELDS) == set(EVENTS)
    assert "alloc" in seen_events and "share" in seen_events


def test_live_events_honor_the_contract():
    fab = _fabric()
    violations = []

    def validator(event):
        def check(**kw):
            need = set(EVENT_FIELDS[event])
            if event == "share":
                need |= set(SHARE_KIND_FIELDS[kw["kind"]])
            if not need <= set(kw):
                violations.append((event, sorted(need - set(kw))))
        return check

    for ev in EVENTS:
        fab.subscribe(ev, validator(ev))
    a = fab.view("A", quota=[8, 16], home=(0,), level=1)
    b = fab.view("B", quota=[0, 0], home=(1,))
    pages = []
    for _ in range(3):
        a.append_page(pages)            # alloc
    a.register_prefix([1, 2, 3, 4, 5, 6, 7, 8], pages[:2], 8)
    got = []
    b.probe_prefix([1, 2, 3, 4, 5, 6, 7, 8], got)    # share kind=prefix
    a.migrate(pages)                    # migrate (may be a no-op move)
    a.record_latency(0.01)              # latency
    b.release(got)
    a.release(pages)                    # free
    assert not violations, violations
    assert fab.telemetry.subscriber_errors == 0, \
        "contract validator raised instead of recording"


# ---------------------------------------------------------------------------
# drift ledger
# ---------------------------------------------------------------------------

def test_drift_vector_observation_converges_bw():
    fab = _fabric()                      # profile: fast 819, slow 0.016
    bw_true = np.array([819.0, 0.032])   # slow domain is 2x the profile
    led = DriftLedger(fab, calibrate_every=1)
    pb = float(fab.pool.page_bytes)
    bpd = np.array([4 * pb, 8 * pb])
    for _ in range(40):
        measured = bpd / (bw_true * 1e9)
        predicted = float((bpd / (fab.bw_effective * 1e9)).max())
        led.observe("batch_read", bpd, predicted, measured)
    bw = fab.bw_effective
    assert abs(bw[1] - bw_true[1]) / bw_true[1] < 0.01
    s = led.summary()
    assert s["calibrations"] == 40
    assert s["kinds"]["batch_read"]["count"] == 40
    # drift ratio EWMA heads toward measured/predicted = profile-error
    assert s["domain_drift"][1] < 1.0    # faster than predicted


def test_drift_scalar_attributes_to_bottleneck_domain():
    fab = _fabric()
    led = DriftLedger(fab, calibrate_every=100)
    pb = float(fab.pool.page_bytes)
    # slow domain dominates the predicted per-domain time by construction
    bpd = np.array([pb, 4 * pb])
    led.observe("swap_transfer", bpd, 0.001, 0.002)   # scalar measurement
    assert list(led.domain_samples) == [0, 1]         # bottleneck only
    assert len(led.ratio["swap_transfer"]) == 1
    assert led.ratio["swap_transfer"].last() == pytest.approx(2.0)
    led.observe_scalar("tier_copy", 0.5, 1.0)
    assert led.ratio["tier_copy"].last() == pytest.approx(2.0)


def test_drift_flush_without_samples_is_a_noop():
    fab = _fabric()
    led = DriftLedger(fab)
    before = fab.calibration_samples
    assert led.flush() is False
    assert fab.calibration_samples == before


# ---------------------------------------------------------------------------
# page heat
# ---------------------------------------------------------------------------

def test_heat_touch_decay_and_free():
    fab = _fabric()
    heat = PageHeat(fab.pool, decay=0.5)
    heat.touch([0, 1])
    assert heat.value(0) == 1.0
    heat.step()
    assert heat.value(0) == 0.5          # lazy decay on read
    heat.touch([0])
    assert heat.value(0) == 1.5
    heat.on_free(page=1)
    assert heat.value(1) == 0.0 and heat.live_pages() == 1
    assert heat.hottest(5) == [(0, 1.5)]
    pd = heat.per_domain()
    dom = fab.pool.domains[fab.pool.domain_of(0)].name
    assert pd[dom]["pages"] == 1 and pd[dom]["max"] == 1.5
    snap = heat.snapshot()
    assert snap["live_pages"] == 1 and snap["touches"] == 3


def test_observatory_counts_bus_events_and_purges_heat():
    fab = _fabric()
    obs = Observatory(fab, drift=False)
    view = fab.view("A", quota=[8, 16], home=(0,))
    pages = []
    for _ in range(2):
        view.append_page(pages)
    obs.heat.touch(pages)
    assert obs.heat.live_pages() == 2
    view.release(pages)
    assert obs.heat.live_pages() == 0    # free events purge heat
    ev = obs.metrics.get("repro_fabric_events_total")
    assert ev.value("alloc") == 2 and ev.value("free") == 2
    assert obs.metrics.get("repro_page_events_total").value(
        "alloc", "A", "fast") + obs.metrics.get(
        "repro_page_events_total").value("alloc", "A", "slow") == 2
    with pytest.raises(AssertionError):
        fab.attach_obs(obs)              # one observatory per fabric


# ---------------------------------------------------------------------------
# tracer + engine integration (shared run; preemption + token identity)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_runs():
    cfg = _cfg()
    from repro.models.lm import LM
    params = LM(cfg).init(jax.random.PRNGKey(0))
    trace = generate(WorkloadSpec(
        kind="poisson", num_requests=5, mean_interarrival_s=0.005,
        prompt_mean=10, prompt_max=20, max_new=6,
        vocab_size=cfg.vocab_size,
        class_mix=(("hi", 0.4), ("lo", 0.6)), seed=0))

    def run(with_obs):
        pool = BwapPagePool(cfg, [
            MemoryDomain("hbm_local", 8, 819.0, True),
            MemoryDomain("hbm_peer", 8, 0.05, False),
            MemoryDomain("host", 40, 0.016, False),
        ], page_size=4, dwp_config=DWPConfig(n=10 ** 6, c=1))
        swap = KVSwapManager(pool, placement="bwap_canonical",
                             reserve_fraction=0.9)
        sched = RequestScheduler(
            pool, max_batch=3, prefill_token_budget=16,
            classes=[PriorityClass("hi", 2, SloSpec(ttft_s=0.5,
                                                    tpot_s=0.1)),
                     PriorityClass("lo", 0)],
            default_class="lo", default_max_new=6, swap=swap)
        eng = ServeEngine(cfg, params, pool, scheduler=sched,
                          wall_clock=False, sim_step_s=0.01)
        obs = Observatory(pool, drift=False) if with_obs else None
        for t in trace:
            eng.submit(t.prompt, cls=t.cls, max_new=t.max_new,
                       arrival_s=t.arrival_s)
        steps = 0
        while (eng.active or eng.waiting) and steps < 300:
            eng.step()
            steps += 1
        tokens = [tuple(s.tokens) for s in sorted(eng.finished,
                                                  key=lambda s: s.sid)]
        return tokens, obs, pool

    base_tokens, _, _ = run(False)
    tokens, obs, pool = run(True)
    return base_tokens, tokens, obs, pool


def test_tracing_is_token_identical(traced_runs):
    base_tokens, tokens, _, _ = traced_runs
    assert tokens == base_tokens


def test_preempted_request_has_full_span_set(traced_runs):
    _, _, obs, pool = traced_runs
    assert pool.telemetry.swap_outs > 0, "workload must preempt"
    preempted = sorted({e["tid"] - 1
                        for e in obs.tracer.spans("swap_out")})
    assert preempted
    sid = preempted[0]
    for name in ("admit", "prefill", "decode", "swap_out", "swap_in",
                 "finish"):
        assert obs.tracer.spans(name, sid=sid), \
            f"preempted request {sid} missing {name!r}"
    # queued span closes at first work, never negative
    for ev in obs.tracer.spans("queued"):
        assert ev["dur"] >= 0
    # virtual clock ordering within the request's track
    spans = sorted((e for e in obs.tracer.spans(sid=sid)),
                   key=lambda e: e["ts"])
    assert spans[0]["name"] == "admit"
    assert spans[-1]["name"] == "finish"


def test_trace_export_is_perfetto_loadable(traced_runs, tmp_path):
    _, _, obs, _ = traced_runs
    path = obs.tracer.export(tmp_path / "trace.json")
    data = json.loads(path.read_text())
    assert data["displayTimeUnit"] == "ms"
    evs = data["traceEvents"]
    assert evs and all("ph" in e and "pid" in e and "tid" in e
                       for e in evs)
    names = {e["name"] for e in evs if e["ph"] == "M"}
    assert "process_name" in names and "thread_name" in names
    for e in evs:
        if e["ph"] == "X":
            assert e["dur"] >= 0 and e["ts"] >= 0


def test_request_lifecycle_counters(traced_runs):
    _, _, obs, _ = traced_runs
    req = obs.metrics.get("repro_requests_total")
    admits = sum(req.value("admit", "default", c) for c in ("hi", "lo"))
    finishes = sum(req.value("finish", "default", c) for c in ("hi", "lo"))
    assert admits == 5 and finishes == 5
    # the bus-side latency histogram saw every decode step
    lat = obs.metrics.get("repro_step_latency_seconds").labels("default")
    assert lat.count > 0 and lat.quantile(0.5) > 0


# ---------------------------------------------------------------------------
# satellite: benchmark artifact schema check
# ---------------------------------------------------------------------------

def test_artifacts_check_validates_schema_and_finiteness(tmp_path):
    from benchmarks import artifacts
    name = "BENCH_obs.json"
    # missing file
    with pytest.raises(SystemExit, match="missing"):
        artifacts.check([name], root=tmp_path)
    # unparseable
    (tmp_path / name).write_text("{nope")
    with pytest.raises(SystemExit, match="unparseable"):
        artifacts.check([name], root=tmp_path)
    # missing required keys
    (tmp_path / name).write_text(json.dumps({"calibration": {}}))
    with pytest.raises(SystemExit, match="overhead"):
        artifacts.check([name], root=tmp_path)
    # non-finite numbers
    (tmp_path / name).write_text(
        '{"calibration": {"x": NaN}, "calibration_micro": {}, '
        '"overhead": {}}')
    with pytest.raises(SystemExit, match="non-finite"):
        artifacts.check([name], root=tmp_path)
    # valid
    (tmp_path / name).write_text(
        json.dumps({"calibration": {"x": 1.0}, "calibration_micro": {},
                    "overhead": {"y": 2}}))
    artifacts.check([name], root=tmp_path)
    # every schema name is covered by EXPECTED and vice versa
    assert set(artifacts.EXPECTED) == set(artifacts.SCHEMAS)
