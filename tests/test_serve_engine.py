"""BWAP page pool + serving engine integration tests (CPU, small model)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.dwp import DWPConfig
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BwapPagePool, MemoryDomain


def _pool(cfg, pages=64, page_size=8):
    domains = [
        MemoryDomain("hbm_local", pages // 2, 819.0, True),
        MemoryDomain("hbm_peer", pages // 4, 50.0, False),
        MemoryDomain("host", pages - pages // 2 - pages // 4, 16.0, False),
    ]
    return BwapPagePool(cfg, domains, page_size=page_size,
                        dwp_config=DWPConfig(n=4, c=1))


@pytest.fixture(scope="module")
def small_lm():
    cfg = registry.get_smoke_config("qwen2-0.5b")
    cfg = dataclasses.replace(cfg, num_layers=2, compute_dtype="float32")
    from repro.models.lm import LM
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def test_pool_placement_follows_weights(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg, pages=64)
    ids = [pool.alloc_page() for _ in range(32)]
    domains = np.asarray([pool.domain_of(i) for i in ids])
    frac_local = (domains == 0).mean()
    # canonical weights put most pages on the fast domain
    assert frac_local > 0.7
    # but slower domains are used too (Observation 1)
    assert (domains != 0).any()


def test_pool_alloc_free_roundtrip(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg, pages=16, page_size=4)
    ids = [pool.alloc_page() for _ in range(16)]
    assert len(set(ids)) == 16
    with pytest.raises(RuntimeError):
        pool.alloc_page()
    pool.free_pages(ids)
    assert sum(len(f) for f in pool.free) == 16


def test_engine_generates_and_respects_pages(small_lm):
    cfg, params = small_lm
    pool = _pool(cfg, pages=128, page_size=4)
    eng = ServeEngine(cfg, params, pool, max_batch=3, max_new=6)
    rng = np.random.default_rng(0)
    sids = [eng.submit(rng.integers(1, cfg.vocab_size, 5).tolist())
            for _ in range(3)]
    for _ in range(30):
        info = eng.step()
        if not eng.active and not eng.waiting:
            break
    assert len(eng.finished) == 3
    for s in eng.finished:
        assert s.produced == 6
        assert all(np.isfinite(t) for t in s.tokens)
    # pool fully reclaimed
    assert sum(len(f) for f in pool.free) == pool.total_pages


def test_engine_decode_matches_dense_decode(small_lm):
    """Paged decode must produce the same logits as the dense cache path."""
    cfg, params = small_lm
    pool = _pool(cfg, pages=64, page_size=4)
    eng = ServeEngine(cfg, params, pool, max_batch=1, max_new=1)
    prompt = [3, 17, 29, 5]
    eng.submit(list(prompt))
    eng.step()  # prefill + 1 decode
    paged_next = eng.finished[0].tokens[len(prompt)] if eng.finished else \
        eng.active[0].tokens[len(prompt)]

    # dense reference: full forward, argmax of last position
    from repro.models.lm import LM
    model = LM(cfg)
    toks = jnp.asarray([prompt], jnp.int32)
    logits = model.prefill(params, {"tokens": toks})
    dense_next = int(jnp.argmax(logits[0, -1]))
    assert paged_next == dense_next


def test_dwp_migration_changes_allocation(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg, pages=64, page_size=4)
    w0 = pool.weights.copy()
    # feed decreasing latencies -> tuner raises DWP -> more worker-local mass
    lat = 1.0
    while not pool.tuner.done and lat > 0.2:
        pool.record_latency(lat)
        lat -= 0.02
    assert pool.tuner.dwp > 0
    assert pool.weights[0] > w0[0]
