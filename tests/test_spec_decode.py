"""Speculative multi-token decode (ISSUE 4 / DESIGN.md §7): drafter
behavior, token-identity vs greedy, exact rollback of rejected speculation
(pool bytes, refcounts, free lists, allocation cycle), the Eq.-1
latency-signal regression fixes, and scheduler spec accounting."""

import dataclasses

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:      # bare env: property tests skip individually
    from _hypothesis_stub import given, settings, st

from repro.configs import registry
from repro.core.dwp import DWPConfig
from repro.scheduler import RequestScheduler
from repro.scheduler.scheduler import Request
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BwapPagePool, MemoryDomain
from repro.serve.spec import PromptLookupDrafter


@pytest.fixture(scope="module")
def small_lm():
    cfg = registry.get_smoke_config("qwen2-0.5b")
    cfg = dataclasses.replace(cfg, num_layers=1, compute_dtype="float32")
    from repro.models.lm import LM
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _pool(cfg, fast=32, peer=16, host=16, page_size=4):
    domains = [
        MemoryDomain("hbm_local", fast, 819.0, True),
        MemoryDomain("hbm_peer", peer, 50.0, False),
        MemoryDomain("host", host, 16.0, False),
    ]
    return BwapPagePool(cfg, domains, page_size=page_size,
                        dwp_config=DWPConfig(n=10 ** 6, c=1))


def _drain(eng, cap=500):
    steps = 0
    while (eng.active or eng.waiting) and steps < cap:
        eng.step()
        steps += 1
    assert not eng.active and not eng.waiting, "engine did not drain"


def _state(pool):
    """Everything speculative rollback must leave bit-identical to greedy."""
    return (np.asarray(pool.k_pool).copy(), np.asarray(pool.v_pool).copy(),
            [list(f) for f in pool.free], dict(pool.table.ref),
            {nid: (n.parent, n.block, n.phys)
             for nid, n in pool.table._nodes.items()},
            pool._cycle_pos)


def _assert_states_equal(a, b):
    ak, av, afree, aref, atrie, acyc = a
    bk, bv, bfree, bref, btrie, bcyc = b
    assert (ak == bk).all(), "k_pool bytes differ from greedy"
    assert (av == bv).all(), "v_pool bytes differ from greedy"
    assert afree == bfree, "free lists differ from greedy"
    assert aref == bref, "refcounts differ from greedy"
    assert atrie == btrie, "trie nodes differ from greedy"
    assert acyc == bcyc, "allocation cycle position differs from greedy"


# ---------------------------------------------------------------------------
# drafter
# ---------------------------------------------------------------------------

def test_drafter_unrolls_runs_and_cycles():
    d = PromptLookupDrafter(max_tokens=4, max_ngram=3)
    # constant run: full-depth draft even when the recorded continuation is
    # one token long
    assert d.draft([7, 7, 7]) == [7, 7, 7, 7]
    # short cycle unrolls past the end of history
    assert d.draft([1, 2, 3, 1, 2, 3]) == [1, 2, 3, 1]
    # no repeated n-gram anywhere -> no proposal
    assert d.draft([1, 2, 3, 4, 5]) == []
    assert d.draft([9]) == []
    # deterministic
    toks = [4, 1, 4, 1, 4]
    assert d.draft(toks) == d.draft(list(toks))


def test_drafter_prefers_longest_ngram():
    d = PromptLookupDrafter(max_tokens=2, max_ngram=2)
    # 1-gram [2] would match position 1 (-> 9), but the 2-gram [1, 2]
    # matches earlier with continuation [5, ...]
    assert d.draft([1, 2, 5, 9, 1, 2]) == [5, 9]


# ---------------------------------------------------------------------------
# token identity + exact rollback vs greedy
# ---------------------------------------------------------------------------

LOOP_PROMPT = [5, 9, 3, 5, 9, 3, 5, 9, 3, 7]


def _run_engine(cfg, params, drafter, prompts, max_new=12, max_batch=4):
    pool = _pool(cfg)
    eng = ServeEngine(cfg, params, pool, max_batch=max_batch,
                      max_new=max_new, wall_clock=False, sim_step_s=0.001,
                      drafter=drafter)
    for p in prompts:
        eng.submit(list(p))
    _drain(eng)
    return eng, pool


def test_spec_token_identical_and_fewer_steps(small_lm):
    cfg, params = small_lm
    g_eng, _ = _run_engine(cfg, params, None, [LOOP_PROMPT], max_new=16)
    s_eng, s_pool = _run_engine(cfg, params,
                                PromptLookupDrafter(max_tokens=4),
                                [LOOP_PROMPT], max_new=16)
    assert g_eng.finished[0].tokens == s_eng.finished[0].tokens
    assert s_eng.decode_steps < g_eng.decode_steps
    sp = s_pool.telemetry.snapshot()["spec"]
    assert sp["accepted"] > 0
    assert s_eng.tokens_emitted == 16          # greedy + verify steps
    # verify steps emit their accepted drafts plus one bonus token each
    assert sp["emitted"] == sp["accepted"] + sp["steps"]
    assert sp["emitted"] <= s_eng.tokens_emitted


def test_spec_batch_token_identical(small_lm):
    """Mixed batch: drafting and non-drafting sequences verify together."""
    cfg, params = small_lm
    prompts = [LOOP_PROMPT, [2, 11, 2, 11, 2, 11, 4],
               [17, 23, 31, 40, 8]]          # last one: nothing to draft
    g_eng, _ = _run_engine(cfg, params, None, prompts, max_new=10)
    s_eng, _ = _run_engine(cfg, params, PromptLookupDrafter(max_tokens=3),
                           prompts, max_new=10)
    g = {s.sid: s.tokens for s in g_eng.finished}
    s = {s.sid: s.tokens for s in s_eng.finished}
    assert g == s


def test_spec_rollback_bit_identical_to_greedy(small_lm):
    """The tentpole guarantee: a speculative run leaves pool bytes, free
    lists, refcounts, trie, and the allocation cycle exactly where a greedy
    run leaves them — rejected speculation is invisible."""
    cfg, params = small_lm
    _, g_pool = _run_engine(cfg, params, None, [LOOP_PROMPT], max_new=16)
    _, s_pool = _run_engine(cfg, params, PromptLookupDrafter(max_tokens=4),
                            [LOOP_PROMPT], max_new=16)
    _assert_states_equal(_state(s_pool), _state(g_pool))


class ScriptedDrafter:
    """Proposes ``good`` greedy-consistent tokens then ``bad`` wrong ones,
    per call, from a fixed plan — drives every accept/reject boundary the
    rollback path has (``oracle`` = the greedy run's full token stream)."""

    def __init__(self, oracle, vocab, plan, max_tokens=6):
        self.oracle = list(oracle)
        self.vocab = vocab
        self.plan = list(plan)
        self.calls = 0
        self.max_tokens = max_tokens

    def draft(self, tokens):
        good, bad = self.plan[self.calls % len(self.plan)]
        self.calls += 1
        pos = len(tokens)
        assert self.oracle[:pos] == list(tokens), \
            "speculative run diverged from the greedy oracle"
        out = self.oracle[pos:pos + good]
        good = len(out)                       # oracle may run out near the end
        for i in range(bad):
            true = self.oracle[pos + good + i] \
                if pos + good + i < len(self.oracle) else 0
            out.append((true + 7) % self.vocab or 1)   # guaranteed mismatch
        return out[:self.max_tokens]


@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 2)),
                min_size=1, max_size=8))
@settings(max_examples=10, deadline=None)
def test_spec_rollback_property(plan):
    """Random accept/reject prefixes over random draft lengths leave the
    pagetable (refcounts, trie nodes, free lists) and the pool bit-identical
    to having decoded the accepted tokens greedily (ISSUE 4)."""
    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    from repro.models.lm import LM
    params = LM(cfg).init(jax.random.PRNGKey(0))
    g_eng, g_pool = _run_engine(cfg, params, None, [LOOP_PROMPT], max_new=12)
    oracle = list(g_eng.finished[0].tokens)
    drafter = ScriptedDrafter(oracle, cfg.vocab_size, plan)
    s_eng, s_pool = _run_engine(cfg, params, drafter, [LOOP_PROMPT],
                                max_new=12)
    assert s_eng.finished[0].tokens == oracle
    _assert_states_equal(_state(s_pool), _state(g_pool))


class AlwaysWrongDrafter:
    """Proposes tokens guaranteed to mismatch the model's argmax — every
    draft rejects, so every lookahead allocation must roll back."""

    max_tokens = 6

    def __init__(self, vocab):
        self.vocab = vocab

    def draft(self, tokens):
        # the engine never emits vocab-1 for these prompts (checked by the
        # oracle assertion in the tests below via token identity)
        return [self.vocab - 1] * self.max_tokens \
            if tokens[-1] != self.vocab - 1 else [1] * self.max_tokens


def test_spec_multiseq_all_rejected_restores_allocator(small_lm):
    """Two sequences speculating past page boundaries in one *mid-page*
    step (no kept pages): every allocation of the step is rejected, so the
    unwind — which must run in reverse batch order, the step's allocations
    being one stack across sequences — has to restore pool bytes, free
    lists, and the allocation cycle exactly. A forward unwind leaves the
    free lists permuted and the cycle advanced."""
    cfg, params = small_lm
    # targets 6 and 5: first decode writes land at positions 6 and 5 with
    # page_size 4 — both mid-page for two consecutive steps
    prompts = [[5, 9, 3, 5, 9, 3, 7], [2, 11, 2, 11, 2, 11]]

    def mk(drafter):
        pool = _pool(cfg)
        eng = ServeEngine(cfg, params, pool, max_batch=4, max_new=8,
                          wall_clock=False, sim_step_s=0.001,
                          drafter=drafter)
        for p in prompts:
            eng.submit(list(p))
        while len(eng.scheduler.running) < 2:   # drain prefill only
            eng.step()
        return eng, pool

    g_eng, g_pool = mk(None)
    s_eng, s_pool = mk(AlwaysWrongDrafter(cfg.vocab_size))
    _assert_states_equal(_state(s_pool), _state(g_pool))   # same start
    for _ in range(2):                          # both mid-page both steps
        g_eng.step()
        s_eng.step()
        assert [s.tokens for s in g_eng.scheduler.running] == \
            [s.tokens for s in s_eng.scheduler.running]
        _assert_states_equal(_state(s_pool), _state(g_pool))
    assert s_pool.telemetry.snapshot()["spec"]["drafted"] > 0
    assert s_pool.telemetry.snapshot()["spec"]["accepted"] == 0


def test_spec_multiseq_token_identical_and_leak_free(small_lm):
    """Several sequences accepting different amounts per step: page ids
    may permute vs greedy (kept lookahead pages pin the allocation cycle),
    but tokens are identical and every page is reclaimed."""
    cfg, params = small_lm
    prompts = [LOOP_PROMPT, [2, 11, 2, 11, 2, 11, 4], [8, 8, 8, 8, 8]]
    g_eng, _ = _run_engine(cfg, params, None, prompts, max_new=10)
    s_eng, s_pool = _run_engine(cfg, params,
                                PromptLookupDrafter(max_tokens=4),
                                prompts, max_new=10)
    assert {s.sid: s.tokens for s in g_eng.finished} == \
        {s.sid: s.tokens for s in s_eng.finished}
    assert s_pool.telemetry.snapshot()["spec"]["accepted"] > 0
    assert sum(len(f) for f in s_pool.free) == s_pool.total_pages
    assert not s_pool.table.ref


def test_spec_respects_max_new(small_lm):
    """Acceptance clamps at the token allowance: a deep draft near the end
    must not overshoot max_new (greedy produces exactly max_new tokens)."""
    cfg, params = small_lm
    g_eng, _ = _run_engine(cfg, params, None, [LOOP_PROMPT], max_new=3)
    s_eng, _ = _run_engine(cfg, params, PromptLookupDrafter(max_tokens=6),
                           [LOOP_PROMPT], max_new=3)
    assert s_eng.finished[0].produced == 3
    assert g_eng.finished[0].tokens == s_eng.finished[0].tokens


# ---------------------------------------------------------------------------
# Eq.-1 latency-signal regression tests
# ---------------------------------------------------------------------------

def test_eq1_read_set_includes_finishing_sequences(small_lm):
    """A sequence producing its final token was read by that decode step —
    its pages must be billed (the old expression dropped them, feeding the
    DWP tuner an underestimated stall signal on every completing step)."""
    cfg, params = small_lm
    pool = _pool(cfg)
    eng = ServeEngine(cfg, params, pool, max_batch=1, max_new=1,
                      wall_clock=False, sim_step_s=0.001)
    seen = []
    orig = eng.view.expected_read_time
    eng.view.expected_read_time = lambda pages: (seen.append(list(pages)),
                                                 orig(pages))[1]
    eng.submit([3, 17, 29, 5, 8])
    _drain(eng)
    assert len(eng.finished) == 1
    # the only decode step finished the sequence; its pages were billed
    decode_reads = [p for p in seen if p]
    assert decode_reads, "finishing step billed no pages (Eq.-1 regression)"
    assert len(decode_reads[-1]) == 2          # ceil(5/4) prompt pages + decode page


def test_eq1_read_set_dedups_shared_pages(small_lm):
    """Two sequences sharing a trie prefix bill each shared physical page
    once per step, not once per holder (Eq. 1 models resident bytes; the
    kernel reads each physical page once per launch)."""
    cfg, params = small_lm
    pool = _pool(cfg)
    eng = ServeEngine(cfg, params, pool, max_batch=2, max_new=4,
                      wall_clock=False, sim_step_s=0.001)
    seen = []
    orig = eng.view.expected_read_time
    eng.view.expected_read_time = lambda pages: (seen.append(list(pages)),
                                                 orig(pages))[1]
    prompt = [3, 17, 29, 5, 8, 2, 40, 11, 9]   # target 8 = 2 full pages
    eng.submit(list(prompt))
    eng.step()                                 # A prefills + registers
    eng.submit(list(prompt))                   # B matches A's prefix
    shared_seen = False
    for _ in range(30):
        if not (eng.active or eng.waiting):
            break
        both = len(eng.scheduler.running) == 2
        eng.step()
        if both and seen and seen[-1]:
            reads = seen[-1]
            assert len(reads) == len(set(reads)), \
                "shared trie pages double-billed in bytes_per_domain"
            shared_seen = True
    assert shared_seen
    # sharing actually happened (the dedup mattered): both requests matched
    assert pool.table.prefix_hit_pages >= 2


def test_request_equality_is_identity():
    """The hot-path membership fix: two field-identical requests are
    distinct; ``in``/``remove`` on request lists are pointer compares, not
    O(tokens) deep compares."""
    a = Request(sid=0, tokens=[1, 2], pages=[])
    b = Request(sid=0, tokens=[1, 2], pages=[])
    assert a != b and a == a
    assert a in [a] and a not in [b]
    assert len({a, b}) == 2                    # hashable again (identity)


# ---------------------------------------------------------------------------
# scheduler speculative accounting
# ---------------------------------------------------------------------------

def test_scheduler_spec_growth_need(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg)                          # page_size 4
    sched = RequestScheduler(pool, spec_tokens=4)
    # length 8, 2 pages: a verify step writes positions 8..12 -> needs
    # ceil(13/4) = 4 pages -> 2 fresh ones
    assert sched._seq_growth(8, [0, 1]) == 2
    # mid-page with room for the whole span
    assert sched._seq_growth(6, [0, 1]) == 1   # positions 6..10 -> 3 pages
    sched0 = RequestScheduler(pool)
    assert sched0._seq_growth(8, [0, 1]) == 1  # plain decode: one boundary page
    assert sched0._seq_growth(6, [0, 1]) == 0


def test_scheduler_spec_budget_charges_verify_tokens(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg, fast=64, peer=32, host=32)

    def first_chunk(spec_tokens):
        sched = RequestScheduler(pool.__class__(
            cfg, [MemoryDomain("hbm_local", 64, 819.0, True),
                  MemoryDomain("host", 32, 16.0, False)], page_size=4,
            dwp_config=DWPConfig(n=10 ** 6, c=1)),
            max_batch=4, prefill_token_budget=16,
            default_max_new=4, spec_tokens=spec_tokens)
        # one running sequence that will decode this step
        sched.submit(list(range(1, 6)))        # target 4 -> fits one chunk
        plan = sched.schedule()
        for r, lo, hi in plan.prefill_chunks:  # stand in for the engine
            r.length = hi
        assert len(sched.running) == 1
        # a long prompt now shares the step budget with the running decode
        sched.submit(list(range(1, 40)))
        plan = sched.schedule()
        return sum(hi - lo for _, lo, hi in plan.prefill_chunks)

    assert first_chunk(0) == 16                # full budget for prefill
    # one running sequence charges 1 + spec_tokens verify tokens first
    assert first_chunk(4) == 16 - 5


def test_scheduler_spec_footprint_margin(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg, fast=2, peer=2, host=2)  # 6 pages, page_size 4
    sched = RequestScheduler(pool, spec_tokens=0, default_max_new=4)
    sched.submit(list(range(1, 22)))           # 20 target + 4 new = 6 pages
    spec = RequestScheduler(pool, spec_tokens=4, default_max_new=4)
    with pytest.raises(ValueError):            # lookahead page doesn't fit
        spec.submit(list(range(1, 22)))


# ---------------------------------------------------------------------------
# fused (batched) incremental prefill
# ---------------------------------------------------------------------------

def test_fused_prefill_matches_recompute_oracle(small_lm):
    """Same-step chunks of different sequences fuse into one launch; tokens
    must equal the per-sequence recompute-oracle path bit-for-bit."""
    cfg, params = small_lm
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
               for n in (19, 11, 7)]

    def run(incremental):
        pool = _pool(cfg, fast=64, peer=32, host=32)
        eng = ServeEngine(cfg, params, pool, max_batch=3, max_new=4,
                          wall_clock=False, sim_step_s=0.001,
                          incremental_prefill=incremental)
        # small budget: chunks of several sequences share steps
        eng.scheduler.prefill_token_budget = 8
        for p in prompts:
            eng.submit(list(p))
        _drain(eng)
        assert eng.prefill_chunks_run > len(prompts)   # chunking happened
        return {s.sid: s.tokens for s in eng.finished}

    assert run(True) == run(False)
